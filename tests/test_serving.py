"""Serving engine: greedy decode equals argmax teacher-forcing on the full
forward; eos early-exit; works across architecture families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.serving.engine import Engine, ServeConfig


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "mamba2_1_3b", "zamba2_2_7b"])
def test_greedy_generation_consistent_with_forward(arch):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_new_tokens=6))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = engine.generate({"tokens": prompts})
    assert out.shape == (2, 6)

    # teacher-forced check of the FIRST generated token: the engine's
    # sample must equal argmax of the full forward at the last prompt pos
    logits, _ = model.forward(
        params, {"tokens": prompts, "labels": prompts}
    )
    want = np.asarray(jnp.argmax(logits[:, 7], axis=-1))
    np.testing.assert_array_equal(out[:, 0], want)


def test_eos_early_exit():
    cfg = configs.get_reduced("qwen1_5_0_5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    # pick the actual first greedy token as "eos" → generation stops at once
    e0 = Engine(model, params, ServeConfig(max_new_tokens=8))
    first = e0.generate({"tokens": prompts})[:, 0]
    eos = int(first[0])
    e1 = Engine(model, params, ServeConfig(max_new_tokens=8, eos_id=eos))
    out = e1.generate({"tokens": prompts})
    assert out.shape[1] <= 8
    assert (out[0] == eos).all() or out.shape[1] < 8


def test_temperature_sampling_changes_output():
    cfg = configs.get_reduced("qwen1_5_0_5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
    greedy = Engine(model, params, ServeConfig(max_new_tokens=8)).generate(
        {"tokens": prompts}
    )
    hot = Engine(
        model, params, ServeConfig(max_new_tokens=8, temperature=5.0, seed=3)
    ).generate({"tokens": prompts})
    assert not np.array_equal(greedy, hot)

"""Multi-device (8 fake CPU devices) equivalence tests, run in subprocesses
so the main pytest process keeps its single-device view."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess-based: own CI job

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_check(name: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._dist_checks", name],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"{name} failed:\n{out.stdout}\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_solve_pool_matches_single_device():
    res = _run_check("solve_pool")
    assert res["bitstrings_equal"], res
    assert res["exp_close"], res


def test_sharded_statevector_matches_single_device():
    res = _run_check("sharded_qaoa")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"


def test_engine_gradient_parity():
    """jax.grad through the sharded evolution == single-device gradient
    within float32 tolerance (emulated 2- and 4-device meshes), and the
    sharded Adam ascent beats the linear ramp, landing on the flat
    optimizer's parameters (DESIGN.md §2.6)."""
    res = _run_check("engine_grad")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"


def test_engine_ops_dispatch_per_shard():
    """The sharded hot loop has no direct `ref.*` calls: every
    phase/mixer/cutvals/expectation op reaches the `kernels.ops`-
    dispatched kernels under `pallas_interpret`, agreeing with the xla
    path (cut tables bitwise; evolved state ulp-tight). This is the
    runtime half of the contract; the static half is reprolint's
    `dispatch-purity` rule (src/repro/analysis, docs/ANALYSIS.md)."""
    res = _run_check("engine_interpret")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"


def test_merge_sharded_matches_exact():
    res = _run_check("merge_sharded")
    assert res["val_matches_exact"], res
    assert res["assignment_achieves_val"], res


def test_problem_families_distributed_parity():
    """QUBO and penalty-MIS `Problem`s through `solve_distributed` on an
    emulated data mesh: exact cut/assignment parity with single-device
    `solve` on the same problem, and the MIS result is a valid
    independent set (DESIGN.md §9)."""
    res = _run_check("problem_distributed")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"


def test_service_mesh_backend_parity():
    """The solve service over `MeshBackend` (solve_pool on an emulated
    4-device `data` mesh) returns bit-identical cuts/assignments to the
    single-device `LocalBackend` — and to solo `core.solve` — on the
    parity mix, with per-tenant accounting and the async dispatch window
    engaged (DESIGN.md §6.5)."""
    res = _run_check("service_mesh")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"


def test_solve_distributed_matches_single_device():
    """End-to-end pipeline parity on emulated devices (DESIGN.md §2.4):
    same cut value as single-device `solve` on a small fixed graph, for
    both the data-only pool mesh and the data+model routing mesh."""
    res = _run_check("solve_distributed")
    for key, ok in res.items():
        assert ok, f"{key}: {res}"

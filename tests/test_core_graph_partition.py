"""Unit + property tests: graph representation and CPP partitioning."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph, cut_value, cut_value_batch, subgraph
from repro.core.partition import (
    alg1_ranges,
    balanced_ranges,
    connectivity_preserving_partition,
    partition_for_solver,
    random_partition,
)


def test_graph_from_edges_padding():
    g = Graph.from_edges(4, [(0, 1), (1, 2)], pad_to=5)
    assert g.edges.shape == (5, 2)
    assert g.n_edges == 2
    assert float(g.total_weight()) == 2.0


def test_cut_value_simple():
    # triangle: best cut = 2
    g = Graph.from_edges(3, [(0, 1), (1, 2), (0, 2)])
    assert float(cut_value(g, jnp.array([0, 1, 0]))) == 2.0
    assert float(cut_value(g, jnp.array([0, 0, 0]))) == 0.0
    assert float(cut_value(g, jnp.array([1, 1, 1]))) == 0.0


def test_cut_value_batch_matches_single():
    g = Graph.erdos_renyi(12, 0.5, seed=0)
    rng = np.random.default_rng(0)
    batch = rng.integers(0, 2, size=(7, 12))
    vb = np.asarray(cut_value_batch(g, jnp.asarray(batch)))
    for i in range(7):
        assert vb[i] == pytest.approx(float(cut_value(g, jnp.asarray(batch[i]))))


def test_padding_edges_never_contribute():
    g1 = Graph.from_edges(4, [(0, 1)], pad_to=1)
    g2 = Graph.from_edges(4, [(0, 1)], pad_to=64)
    a = jnp.array([1, 0, 1, 0])
    assert float(cut_value(g1, a)) == float(cut_value(g2, a))


@given(
    n=st.integers(6, 60),
    m=st.integers(2, 6),
)
@settings(max_examples=40, deadline=None)
def test_balanced_ranges_properties(n, m):
    if n // m < 2:
        return
    ranges = balanced_ranges(n, m)
    assert len(ranges) == m
    assert ranges[0][0] == 0 and ranges[-1][1] == n
    sizes = [hi - lo for lo, hi in ranges]
    # adjacent ranges share exactly one vertex
    for (l0, h0), (l1, h1) in zip(ranges, ranges[1:]):
        assert l1 == h0 - 1
    # sizes differ by at most 1
    assert max(sizes) - min(sizes) <= 1


def test_alg1_ranges_paper_example_overflow():
    # documents the verbatim-Alg.1 defect: |V|=400, M=16 → last partition 40
    ranges = alg1_ranges(400, 16)
    sizes = [hi - lo for lo, hi in ranges]
    assert sizes[-1] == 40  # violates the 26-qubit cap the paper assumes
    bsizes = [hi - lo for lo, hi in balanced_ranges(400, 16)]
    assert max(bsizes) <= 27


@given(n=st.integers(10, 80), p=st.floats(0.1, 0.9), m=st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_partition_covers_every_edge_exactly_once(n, p, m):
    if n // m < 2:
        return
    g = Graph.erdos_renyi(n, p, seed=42)
    part = connectivity_preserving_partition(g, m)
    total_sub = sum(sg.n_edges for sg in part.subgraphs)
    assert total_sub + part.inter_edges.shape[0] == g.n_edges
    # every subgraph respects its range width
    for sg, (lo, hi) in zip(part.subgraphs, part.ranges):
        assert sg.n == hi - lo
        e = np.asarray(sg.edges)[: sg.n_edges]
        if e.size:
            assert e.min() >= 0 and e.max() < sg.n


def test_partition_for_solver_respects_qubit_cap():
    for n in (50, 100, 257, 400, 1001):
        g = Graph.erdos_renyi(n, 0.3, seed=1)
        part = partition_for_solver(g, 26)
        assert max(part.sizes) <= 26
        assert part.m >= int(np.ceil(n / 25))


def test_subgraph_extraction():
    g = Graph.from_edges(6, [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5)])
    sg = subgraph(g, 1, 4)  # vertices 1,2,3 → edges (1,2),(2,3) relabelled
    assert sg.n == 3
    assert sg.n_edges == 2


def test_random_partition_preserves_cut_distribution():
    g = Graph.erdos_renyi(30, 0.4, seed=3)
    part = random_partition(g, 3, seed=7)
    # relabelled graph has the same edge count and weights
    assert part.graph.n_edges == g.n_edges
    assert float(part.graph.total_weight()) == pytest.approx(
        float(g.total_weight())
    )

"""End-to-end ParaQAOA vs exact/baseline solvers on small instances
(paper Table 2 regime, scaled to CPU)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import (
    brute_force_maxcut,
    goemans_williamson,
    local_search,
    qaoa_in_qaoa,
)
from repro.core.graph import Graph, cut_value
from repro.core.pei import pei


@pytest.mark.parametrize("n,p,seed", [(14, 0.3, 0), (16, 0.5, 1), (12, 0.8, 2)])
def test_paraqaoa_ar_vs_bruteforce(n, p, seed):
    g = Graph.erdos_renyi(n, p, seed=seed)
    _, opt, _ = brute_force_maxcut(g)
    cfg = ParaQAOAConfig(n_qubits=8, top_k=3, p_layers=3, opt_steps=40)
    out = solve(g, cfg)
    ar = out.cut_value / opt
    # paper reports 81-97% AR on small graphs; we accept >= 75% here
    # (fewer layers/steps than the paper's production settings)
    assert ar >= 0.75, f"AR={ar:.3f}"
    assert out.partition.m >= 2  # actually exercised divide-and-conquer


def test_paraqaoa_single_subgraph_path():
    g = Graph.erdos_renyi(8, 0.6, seed=3)
    cfg = ParaQAOAConfig(n_qubits=10, top_k=2, opt_steps=30)
    out = solve(g, cfg)
    _, opt, _ = brute_force_maxcut(g)
    assert out.cut_value / opt >= 0.8
    assert out.partition.m == 1


def test_paraqaoa_k_improves_quality_on_average():
    # K is the paper's quality knob: higher K → search over more candidates
    vals = {}
    for k in (1, 4):
        tot = 0.0
        for seed in range(3):
            g = Graph.erdos_renyi(20, 0.5, seed=seed)
            out = solve(g, ParaQAOAConfig(n_qubits=8, top_k=k, opt_steps=30))
            tot += out.cut_value
        vals[k] = tot
    assert vals[4] >= vals[1] - 1e-6


def test_paraqaoa_refinement_never_hurts():
    g = Graph.erdos_renyi(30, 0.4, seed=5)
    base = solve(g, ParaQAOAConfig(n_qubits=8, top_k=2, opt_steps=25))
    ref = solve(
        g, ParaQAOAConfig(n_qubits=8, top_k=2, opt_steps=25, refine_steps=30)
    )
    assert ref.cut_value >= base.cut_value - 1e-6


def test_gw_beats_random_and_reaches_878_regime():
    g = Graph.erdos_renyi(60, 0.3, seed=7)
    _, v_gw, _ = goemans_williamson(g, steps=300, rounds=64, seed=0)
    # GW must clearly beat the 0.5-expected random cut
    assert v_gw > 0.58 * float(g.total_weight())


def test_gw_matches_bruteforce_small():
    g = Graph.erdos_renyi(12, 0.5, seed=8)
    _, opt, _ = brute_force_maxcut(g)
    _, v_gw, _ = goemans_williamson(g, steps=400, rounds=128, seed=0)
    assert v_gw / opt >= 0.878  # the GW guarantee (holds w.h.p. with rounding)


def test_qaoa_in_qaoa_baseline_runs():
    g = Graph.erdos_renyi(25, 0.4, seed=9)
    assignment, val, rep = qaoa_in_qaoa(g, n_qubits=8, opt_steps=20)
    assert assignment.shape == (25,)
    assert val > 0.4 * float(g.total_weight())  # sane quality
    assert float(cut_value(g, jnp.asarray(assignment))) == pytest.approx(val)


def test_local_search_baseline():
    g = Graph.erdos_renyi(40, 0.4, seed=10)
    s, v, rep = local_search(g, restarts=4, steps=100, seed=0)
    assert v >= 0.5 * float(g.total_weight())  # ≥ random expectation


def test_pei_sanity():
    # equal runtime → EF = 0.5; PEI = AR * 50
    assert pei(9, 10, 100.0, 100.0) == pytest.approx(45.0)
    # much faster → EF → 1
    assert pei(9, 10, 0.0, 1e6) == pytest.approx(90.0, abs=0.5)
    # much slower → EF → 0
    assert pei(10, 10, 1e6, 0.0) == pytest.approx(0.0, abs=0.5)

"""Fault tolerance: checkpoint atomicity/roundtrip, crash-restart resume
equivalence, elastic re-mesh, heartbeat stall detection, gradient
compression convergence parity."""

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, synthetic_batch
from repro.training.fault_tolerance import (
    HeartbeatMonitor,
    reshard_state,
    resume_or_init,
)
from repro.training.train_step import TrainConfig, init_state, train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(arch="qwen1_5_0_5b", lr=1e-3, compression="none"):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        adamw=opt.AdamWConfig(learning_rate=lr, warmup_steps=0, total_steps=100),
        remat=False,
        grad_compression=compression,
    )
    dcfg = DataConfig(seed=3, batch=2, seq=32)
    return cfg, model, tcfg, dcfg


def _run_steps(model, tcfg, dcfg, cfg, state, start, end):
    step_fn = jax.jit(lambda s, b: train_step(s, b, model, tcfg))
    losses = []
    for step in range(start, end):
        state, m = step_fn(state, synthetic_batch(cfg, dcfg, step))
        losses.append(float(m["loss"]))
    return state, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, tcfg, dcfg = _setup()
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    ckpt.save(7, state, {"note": "x"})
    assert ckpt.latest_step() == 7
    step, restored, extra = ckpt.restore(state)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_restart_bitwise_equivalent(tmp_path):
    """Train 6 steps straight vs train 3 + crash + resume + 3: identical."""
    cfg, model, tcfg, dcfg = _setup()

    state_a = init_state(model, jax.random.PRNGKey(0), tcfg)
    state_a, losses_a = _run_steps(model, tcfg, dcfg, cfg, state_a, 0, 6)

    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    state_b = init_state(model, jax.random.PRNGKey(0), tcfg)
    state_b, _ = _run_steps(model, tcfg, dcfg, cfg, state_b, 0, 3)
    ckpt.save(3, state_b)
    del state_b  # "crash"

    start, state_c, resumed = resume_or_init(
        ckpt, lambda: init_state(model, jax.random.PRNGKey(0), tcfg)
    )
    assert resumed and start == 3
    state_c, losses_c = _run_steps(model, tcfg, dcfg, cfg, state_c, 3, 6)

    for a, b in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_c.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    assert losses_a[3:] == pytest.approx(losses_c, abs=1e-5)


def test_checkpoint_atomic_under_partial_write(tmp_path):
    """A leftover .tmp dir (simulated mid-write crash) must not be visible
    as a checkpoint, and a subsequent save must succeed."""
    cfg, model, tcfg, dcfg = _setup()
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(tmp_path / ".tmp-5")
    (tmp_path / ".tmp-5" / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step() is None
    ckpt.save(5, state)
    assert ckpt.latest_step() == 5
    _, restored, _ = ckpt.restore(state)


def test_async_checkpoint_writer(tmp_path):
    cfg, model, tcfg, dcfg = _setup()
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    ckpt = CheckpointManager(str(tmp_path), async_write=True)
    ckpt.save(1, state)
    ckpt.save(2, state)
    ckpt.wait()
    assert ckpt.latest_step() == 2


def test_retention_gc(tmp_path):
    cfg, model, tcfg, dcfg = _setup()
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    steps = sorted(
        int(d.split("-")[1]) for d in os.listdir(tmp_path) if d.startswith("step-")
    )
    assert steps == [3, 4]


@pytest.mark.slow
def test_elastic_remesh_subprocess():
    """Save under a (2,4) mesh, restore under (4,2) and single-device;
    forward results identical. Runs with 8 fake devices in a subprocess."""
    code = r"""
import jax, json
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models.model import build_model
from repro.launch.sharding import params_shardings
from repro.training.fault_tolerance import reshard_state

cfg = configs.get_reduced("qwen1_5_0_5b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % cfg.vocab_size}
want, _ = model.forward(params, batch)

m1 = jax.make_mesh((2, 4), ("data", "model"))
s1 = params_shardings(jax.eval_shape(lambda: params), cfg, m1)
p1 = reshard_state(params, s1)
got1, _ = jax.jit(lambda p, b: model.forward(p, b))(p1, batch)

m2 = jax.make_mesh((4, 2), ("data", "model"))
s2 = params_shardings(jax.eval_shape(lambda: params), cfg, m2)
p2 = reshard_state(p1, s2)  # re-mesh from the *sharded* state
got2, _ = jax.jit(lambda p, b: model.forward(p, b))(p2, batch)

print(json.dumps({
  "m1_ok": bool(np.allclose(np.asarray(want), np.asarray(got1), atol=1e-5)),
  "m2_ok": bool(np.allclose(np.asarray(want), np.asarray(got2), atol=1e-5)),
}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["m1_ok"] and res["m2_ok"], res


def test_heartbeat_detects_stall():
    stalls = []
    mon = HeartbeatMonitor(timeout_s=0.3, on_stall=stalls.append)
    mon.beat(1)
    time.sleep(0.8)
    assert mon.stalled and stalls == [1]
    mon.stop()


def test_heartbeat_no_false_positive():
    mon = HeartbeatMonitor(timeout_s=0.5)
    for i in range(5):
        mon.beat(i)
        time.sleep(0.1)
    assert not mon.stalled
    mon.stop()


def test_grad_compression_converges_like_uncompressed():
    """int8 + error feedback must track the uncompressed loss curve."""
    cfg, model, tcfg_plain, dcfg = _setup(lr=3e-3)
    _, _, tcfg_int8, _ = _setup(lr=3e-3, compression="int8")

    s0 = init_state(model, jax.random.PRNGKey(0), tcfg_plain)
    s1 = init_state(model, jax.random.PRNGKey(0), tcfg_int8)
    _, plain = _run_steps(model, tcfg_plain, dcfg, cfg, s0, 0, 12)
    _, comp = _run_steps(model, tcfg_int8, dcfg, cfg, s1, 0, 12)

    # both must make progress and end within 5% of each other
    assert plain[-1] < plain[0]
    assert comp[-1] < comp[0]
    assert abs(plain[-1] - comp[-1]) / plain[-1] < 0.05, (plain[-1], comp[-1])


def test_train_driver_restart_cli(tmp_path):
    """End-to-end: the launch/train.py driver resumes from its checkpoint
    after an injected crash."""
    from repro.launch.train import run

    ckpt_dir = str(tmp_path / "ck")
    args = [
        "--arch", "qwen1_5_0_5b", "--reduced", "--steps", "8", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
        "--log-every", "2",
    ]
    with pytest.raises(RuntimeError, match="injected failure"):
        run(args + ["--fail-at-step", "5"])
    losses = run(args)  # resumes from step 5's checkpoint (saved at 4+1... latest)
    assert losses, "resumed run produced no losses"

"""CLI surface of the serving drivers: the `--reduced`/`--full-size` flag
pair on launch/serve.py (the old store_true-with-default-True made the
full-size path unreachable) and the serve_maxcut argument grid. Parser-only
— no model build, no jax device work."""

from repro.launch.serve import build_parser as serve_parser
from repro.launch.serve_maxcut import build_parser as maxcut_parser


def test_serve_reduced_is_default():
    args = serve_parser().parse_args(["--arch", "qwen1.5-0.5b"])
    assert args.reduced is True


def test_serve_full_size_reachable():
    args = serve_parser().parse_args(["--arch", "qwen1.5-0.5b", "--full-size"])
    assert args.reduced is False


def test_serve_reduced_explicit():
    args = serve_parser().parse_args(["--arch", "qwen1.5-0.5b", "--reduced"])
    assert args.reduced is True


def test_serve_last_flag_wins():
    args = serve_parser().parse_args(
        ["--arch", "x", "--reduced", "--full-size"]
    )
    assert args.reduced is False
    args = serve_parser().parse_args(
        ["--arch", "x", "--full-size", "--reduced"]
    )
    assert args.reduced is True


def test_serve_maxcut_defaults():
    args = maxcut_parser().parse_args([])
    assert args.requests == 8
    assert args.deadline is None
    assert args.target_quality is None
    assert not args.stream and not args.no_cache


def test_serve_maxcut_sla_and_service_flags():
    args = maxcut_parser().parse_args([
        "--requests", "4", "--deadline", "2.5", "--target-quality", "11",
        "--batch", "8", "--cache-capacity", "32", "--no-cache", "--stream",
        "--qubits", "8", "--repeat-frac", "0.5",
    ])
    assert args.requests == 4
    assert args.deadline == 2.5
    assert args.target_quality == 11.0
    assert args.batch == 8 and args.cache_capacity == 32
    assert args.no_cache and args.stream
    assert args.qubits == 8 and args.repeat_frac == 0.5


def test_serve_maxcut_backend_defaults():
    args = maxcut_parser().parse_args([])
    assert args.mesh is None
    assert args.tenants == 1
    assert args.max_inflight == 2
    assert not args.no_recalibrate


def test_serve_maxcut_mesh_and_tenancy_flags():
    args = maxcut_parser().parse_args([
        "--mesh", "data=4", "--tenants", "3", "--max-inflight", "4",
        "--no-recalibrate",
    ])
    assert args.mesh == "data=4"
    assert args.tenants == 3
    assert args.max_inflight == 4
    assert args.no_recalibrate

"""Roofline extraction + cell-grid unit tests (no 512-device compile)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import specs as SP
from repro.roofline import analysis as RA


def test_all_cells_grid_is_complete():
    cells = SP.all_cells()
    assert len(cells) == 40  # 10 archs × 4 shapes
    skips = [c for c in cells if isinstance(c, SP.SkipCell)]
    runs = [c for c in cells if isinstance(c, SP.Cell)]
    assert len(skips) == 6  # pure full-attention archs skip long_500k
    assert all(s.shape == "long_500k" for s in skips)
    assert {s.arch for s in skips} == {
        "qwen1_5_0_5b", "internlm2_20b", "internvl2_2b",
        "moonshot_v1_16b_a3b", "arctic_480b", "whisper_medium",
    }
    # every runnable long_500k arch is sub-quadratic
    for c in runs:
        if c.shape == "long_500k":
            assert c.arch in SP.LONG_OK


def test_input_specs_shapes():
    c = SP.get_cell("qwen1.5-0.5b", "train_4k")
    specs = SP.input_specs(c)
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].shape == (256, 4096)

    c = SP.get_cell("internvl2-2b", "train_4k")
    specs = SP.input_specs(c)
    # patches + text = 4096 total sequence
    assert specs["patches"].shape[1] + specs["tokens"].shape[1] == 4096

    c = SP.get_cell("whisper-medium", "decode_32k")
    specs = SP.input_specs(c)
    assert specs["token"].shape == (128,)

    c = SP.get_cell("mamba2-1.3b", "long_500k")
    state = SP.decode_state_specs_abstract(c)
    assert state.ssm_h.shape[1] == 1  # batch 1
    assert state.kv_k is None  # attention-free


def test_parse_collectives_factors():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %y), replica_groups=[8,2]<=[16]
  %cp = f32[256]{0} collective-permute(f32[256]{0} %z), source_target_pairs={{0,1}}
  %done = f32[1024]{0} all-reduce-done(f32[1024]{0} %ar)
"""
    st = RA.parse_collectives(hlo)
    assert st.counts == {"all-reduce": 1, "all-gather": 1, "collective-permute": 1}
    # all-reduce: 4096 B × 2·3/4 ; all-gather: 16384 B × 1/2 ; permute 1024 B
    assert st.wire_bytes == pytest.approx(4096 * 1.5 + 16384 * 0.5 + 1024)


def test_parse_collectives_tuple_shapes():
    hlo = "%t = (f32[128]{0}, bf16[64]{0}) all-reduce(%a, %b), replica_groups={{0,1}}\n"
    st = RA.parse_collectives(hlo)
    assert st.bytes_by_op["all-reduce"] == 128 * 4 + 64 * 2


def test_descanned_totals_linear_solve():
    # per-layer b=10 flops, fixed a=5, L=24: m1=15, m2=25 → total = 5+240
    cost1 = {"flops": 15.0, "bytes accessed": 30.0}
    cost2 = {"flops": 25.0, "bytes accessed": 40.0}
    c1 = RA.CollectiveStats({}, {}, 7.0)
    c2 = RA.CollectiveStats({}, {}, 9.0)
    cost, wire = RA.descanned_totals(cost1, c1, cost2, c2, 24)
    assert cost["flops"] == pytest.approx(5 + 24 * 10)
    assert cost["bytes accessed"] == pytest.approx(20 + 24 * 10)
    assert wire == pytest.approx(5 + 24 * 2)
    # clamp: m2 < m1 (noise) degrades gracefully to m1
    cost, wire = RA.descanned_totals(cost2, c2, cost1, c1, 24)
    assert cost["flops"] == 25.0 and wire == 9.0


def test_model_flops_regimes():
    train = SP.get_cell("qwen1.5-0.5b", "train_4k")
    prefill = SP.get_cell("qwen1.5-0.5b", "prefill_32k")
    decode = SP.get_cell("qwen1.5-0.5b", "decode_32k")
    n = train.cfg.n_active_params()
    f_train = RA.model_flops_for_cell(train, n)
    f_prefill = RA.model_flops_for_cell(prefill, n)
    f_decode = RA.model_flops_for_cell(decode, n)
    assert f_train == pytest.approx(6 * n * 4096 * 256)
    assert f_prefill == pytest.approx(2 * n * 32768 * 32)
    # decode: 2·N·B plus KV-read flops — strictly more than the matmul part
    assert f_decode > 2 * n * 128
    assert f_decode < f_prefill


def test_moe_active_params_less_than_total():
    c = SP.get_cell("arctic-480b", "train_4k")
    assert c.cfg.n_active_params() < 0.2 * c.cfg.n_params()
    # arctic really is ~480B total
    assert 3.5e11 < c.cfg.n_params() < 6e11


def test_roofline_bottleneck_selection():
    r = RA.build_roofline(
        arch="x", shape="y", mesh_desc="m", chips=4,
        cost={"flops": 197e12, "bytes accessed": 1.0},
        wire_bytes=0.0, collective_counts={},
        model_flops=100.0,
    )
    assert r.bottleneck == "compute"
    assert r.compute_s == pytest.approx(1.0)

"""Fused phase+mixer kernel vs composition of the reference ops, and the
`ops.apply_layer` dispatch that routes a whole engine layer through it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.kernels import ops, ref
from repro.kernels.fused_layer import fused_phase_mixer_group


@pytest.mark.parametrize("n,k", [(6, 3), (9, 7), (10, 5)])
@pytest.mark.parametrize("gamma,beta", [(0.4, 0.9), (-1.1, 2.3)])
def test_fused_matches_phase_then_mixer(n, k, gamma, beta):
    g = Graph.erdos_renyi(n, 0.5, seed=n)
    cutv = ref.cutvals(n, g.edges, g.weights)
    key = jax.random.PRNGKey(n)
    k1, k2 = jax.random.split(key)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)

    # reference: phase then one grouped mixer application on qubits [0, k)
    wr, wi = ref.apply_phase(re, im, cutv, gamma)
    C, D = ref.rx_kron_parts(jnp.float32(beta), k)
    wr3 = wr.reshape(-1, 2**k)
    wi3 = wi.reshape(-1, 2**k)
    want_re = wr3 @ C - wi3 @ D  # C, D symmetric → right-multiply works
    want_im = wi3 @ C + wr3 @ D

    got_re, got_im = fused_phase_mixer_group(
        re.reshape(-1, 2**k),
        im.reshape(-1, 2**k),
        cutv.reshape(-1, 2**k),
        gamma,
        jnp.float32(beta),
        k,
        interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got_re), np.asarray(want_re), atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_im), np.asarray(want_im), atol=2e-5)


def test_fused_preserves_norm():
    n, k = 8, 4
    g = Graph.erdos_renyi(n, 0.6, seed=1)
    cutv = ref.cutvals(n, g.edges, g.weights).reshape(-1, 2**k)
    re = jnp.full((2 ** (n - k), 2**k), 2.0 ** (-n / 2), jnp.float32)
    im = jnp.zeros_like(re)
    gr, gi = fused_phase_mixer_group(re, im, cutv, 0.7, 1.2, k, interpret=True)
    assert float(jnp.sum(gr**2 + gi**2)) == pytest.approx(1.0, abs=1e-5)


@pytest.mark.parametrize("n,group", [(6, 7), (9, 4)])
def test_apply_layer_dispatch_fires_fused_kernel(n, group, monkeypatch):
    """Under `ops.using_implementation("pallas_interpret")` a whole engine
    layer runs phase+first-group through *this* kernel (counted via a
    wrapper) and matches the XLA reference decomposition."""
    import repro.kernels.fused_layer as fl

    calls = {"n": 0}
    orig = fl.fused_phase_mixer_group

    def counting(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(fl, "fused_phase_mixer_group", counting)

    g = Graph.erdos_renyi(n, 0.5, seed=n)
    cutv = ref.cutvals(n, g.edges, g.weights)
    key = jax.random.PRNGKey(n)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (2**n,), jnp.float32)
    im = jax.random.normal(k2, (2**n,), jnp.float32)

    with ops.using_implementation("xla"):
        wr, wi = ops.apply_layer(re, im, cutv, 0.4, 0.9, n, group=group)
    assert calls["n"] == 0
    with ops.using_implementation("pallas_interpret"):
        gr, gi = ops.apply_layer(re, im, cutv, 0.4, 0.9, n, group=group)
    assert calls["n"] == 1
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=2e-5)

"""Level-aware merge: exactness vs exhaustive product enumeration, score
consistency, orientation constraint, beam-pruning monotonicity."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import merge as mm
from repro.core.graph import Graph, cut_value
from repro.core.partition import connectivity_preserving_partition


def _exhaustive_best(part, cand_idx, k):
    """Host-side exhaustive DFS over the oriented product space (oracle)."""
    g = part.graph
    m = part.m
    best_val, best_assign = -1.0, None
    sizes = part.sizes
    cands = [
        [((int(cand_idx[i][j]) >> np.arange(sizes[i])) & 1).astype(np.int8)
         for j in range(k)]
        for i in range(m)
    ]
    first = cands[0] + [1 - b for b in cands[0]]
    for b0 in first:
        stack = [(1, list(b0))]
        while stack:
            level, prefix = stack.pop()
            if level == m:
                assign = np.asarray(prefix, dtype=np.int8)
                v = float(cut_value(g, jnp.asarray(assign)))
                if v > best_val:
                    best_val, best_assign = v, assign
                continue
            lo, hi = part.ranges[level]
            shared = prefix[lo]
            for b in cands[level]:
                ob = b ^ (b[0] ^ shared)
                stack.append((level + 1, prefix + list(ob[1:])))
    return best_assign, best_val


@given(
    n=st.integers(8, 16),
    p=st.floats(0.3, 0.9),
    m=st.integers(2, 3),
    k=st.integers(1, 3),
    seed=st.integers(0, 50),
)
@settings(max_examples=12, deadline=None)
def test_merge_exact_matches_exhaustive(n, p, m, k, seed):
    if n // m < 3:
        return
    g = Graph.erdos_renyi(n, p, seed=seed)
    part = connectivity_preserving_partition(g, m)
    rng = np.random.default_rng(seed)
    cand_idx = rng.integers(0, 2 ** min(part.sizes), size=(m, k))
    plan = mm.build_merge_plan(part, cand_idx, k)
    bw = mm.exact_beam_width(k, m)
    res = mm.merge_scan(plan, bw)
    oracle_assign, oracle_val = _exhaustive_best(part, cand_idx, k)
    assert float(res.cut_value) == pytest.approx(oracle_val)
    # the returned assignment must actually achieve the reported cut
    achieved = float(cut_value(g, jnp.asarray(np.asarray(res.assignment))))
    assert achieved == pytest.approx(float(res.cut_value))


def test_merge_score_equals_full_reeval():
    g = Graph.erdos_renyi(40, 0.4, seed=9)
    part = connectivity_preserving_partition(g, 4)
    rng = np.random.default_rng(1)
    k = 2
    cand_idx = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = mm.build_merge_plan(part, cand_idx, k)
    res = mm.merge_scan(plan, mm.exact_beam_width(k, part.m))
    # every frontier row's incremental score == from-scratch cut value
    for w in range(min(8, res.beam_assign.shape[0])):
        if float(res.beam_score[w]) < -1e29:
            continue
        a = np.asarray(res.beam_assign[w, : g.n])
        v = float(cut_value(g, jnp.asarray(a)))
        assert v == pytest.approx(float(res.beam_score[w]), abs=1e-3)


def test_merge_shared_vertex_consistency():
    g = Graph.erdos_renyi(20, 0.5, seed=4)
    part = connectivity_preserving_partition(g, 3)
    rng = np.random.default_rng(2)
    cand_idx = rng.integers(0, 2 ** min(part.sizes), size=(part.m, 2))
    plan = mm.build_merge_plan(part, cand_idx, 2)
    res = mm.merge_scan(plan, 64)
    # each level's window starts with the shared vertex value already set:
    # re-deriving oriented candidates from the final assignment must agree
    a = np.asarray(res.assignment)
    for i in range(1, part.m):
        lo, hi = part.ranges[i]
        # assignment over the window matches one of b / ~b for some candidate
        window = a[lo:hi]
        ok = False
        for j in range(2):
            b = ((int(cand_idx[i][j]) >> np.arange(hi - lo)) & 1).astype(np.int8)
            if np.array_equal(window, b) or np.array_equal(window, 1 - b):
                ok = True
        assert ok, f"window at level {i} is not an oriented candidate"


def test_wider_beam_never_worse():
    g = Graph.erdos_renyi(36, 0.5, seed=11)
    part = connectivity_preserving_partition(g, 4)
    rng = np.random.default_rng(3)
    k = 3
    cand_idx = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = mm.build_merge_plan(part, cand_idx, k)
    vals = [
        float(mm.merge_scan(plan, bw).cut_value) for bw in (2, 8, 32, 256)
    ]
    assert all(b >= a - 1e-4 for a, b in zip(vals, vals[1:]))


def test_exact_beam_width():
    assert mm.exact_beam_width(1, 10) == 2
    assert mm.exact_beam_width(2, 3) == 16
    assert mm.exact_beam_width(4, 50, cap=1024) == 1024

"""Canonical graph hashing (service/canonical.py): padding-row invariance,
vertex-relabeling invariance, and collision sanity against cut_value."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Graph, cut_value
from repro.service.canonical import canonical_form, canonical_key, normalized_edges


def _relabel(g: Graph, perm: np.ndarray) -> Graph:
    e = np.asarray(g.edges)[: g.n_edges]
    w = np.asarray(g.weights)[: g.n_edges]
    return Graph.from_edges(g.n, perm[e], w)


def test_padding_row_invariance():
    for seed in range(5):
        g = Graph.erdos_renyi(12, 0.4, seed=seed)
        e = np.asarray(g.edges)[: g.n_edges]
        w = np.asarray(g.weights)[: g.n_edges]
        for extra in (0, 3, 64):
            g_pad = Graph.from_edges(12, e, w, pad_to=g.n_edges + extra)
            assert canonical_key(g_pad) == canonical_key(g)


def test_edge_order_and_duplicate_invariance():
    g = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3)], [1.0, 2.0, 3.0])
    # reversed order, flipped endpoints, and a duplicated split-weight edge
    g2 = Graph.from_edges(
        4, [(3, 2), (2, 1), (1, 0), (0, 1)], [3.0, 2.0, 0.5, 0.5]
    )
    assert canonical_key(g2) == canonical_key(g)
    # zero-weight edges contribute nothing to any cut -> ignored by the key
    g3 = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3), (0, 3)],
                          [1.0, 2.0, 3.0, 0.0])
    assert canonical_key(g3) == canonical_key(g)


@pytest.mark.parametrize("seed", range(8))
def test_vertex_relabeling_invariance(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 15))
    g = Graph.erdos_renyi(n, 0.4, seed=seed)
    f0 = canonical_form(g)
    for _ in range(3):
        perm = rng.permutation(n).astype(np.int32)
        g2 = _relabel(g, perm)
        f2 = canonical_form(g2)
        assert f2.key == f0.key
        # the canonical permutations compose: an assignment written in
        # canonical order replays onto either labeling with the same cut
        a = rng.integers(0, 2, n).astype(np.int8)
        canon = np.empty(n, dtype=np.int8)
        canon[f0.perm] = a
        a2 = canon[f2.perm]
        c1 = float(cut_value(g, jnp.asarray(a)))
        c2 = float(cut_value(g2, jnp.asarray(a2)))
        assert c1 == pytest.approx(c2)


def test_weighted_relabeling_invariance():
    rng = np.random.default_rng(3)
    n = 10
    e = [(i, j) for i in range(n) for j in range(i + 1, n) if rng.random() < 0.5]
    w = rng.uniform(0.5, 2.0, len(e)).astype(np.float32)
    g = Graph.from_edges(n, e, w)
    perm = rng.permutation(n).astype(np.int32)
    assert canonical_key(_relabel(g, perm)) == canonical_key(g)


def test_distinct_graphs_distinct_keys():
    """Collision sanity: structurally different instances (different
    cut-value landscapes per core.graph.cut_value) must not share a key."""
    keys = {}
    rng = np.random.default_rng(0)
    for seed in range(25):
        g = Graph.erdos_renyi(12, 0.4, seed=100 + seed)
        key = canonical_key(g)
        # witness that the instances really are different problems: some
        # assignment scores differently (or edge counts differ)
        for other in keys.values():
            a = rng.integers(0, 2, 12).astype(np.int8)
            same_cut = float(cut_value(g, jnp.asarray(a))) == float(
                cut_value(other, jnp.asarray(a))
            )
            if not same_cut or g.n_edges != other.n_edges:
                assert key != canonical_key(other)
        keys[key] = g
    assert len(keys) == 25


def test_large_graph_hashed_path_relabeling_invariance():
    """Above _EXACT_THRESHOLD vertices the vectorized hashed-WL path runs;
    it must still be relabeling-invariant and keep the perm round trip."""
    rng = np.random.default_rng(5)
    g = Graph.erdos_renyi(600, 0.01, seed=5)
    f0 = canonical_form(g)
    perm = rng.permutation(600).astype(np.int32)
    f2 = canonical_form(_relabel(g, perm))
    assert f2.key == f0.key
    a = rng.integers(0, 2, 600).astype(np.int8)
    canon = np.empty(600, dtype=np.int8)
    canon[f0.perm] = a
    c1 = float(cut_value(g, jnp.asarray(a)))
    c2 = float(cut_value(_relabel(g, perm), jnp.asarray(canon[f2.perm])))
    assert c1 == pytest.approx(c2)
    # and distinct large instances stay distinct
    assert canonical_key(Graph.erdos_renyi(600, 0.01, seed=6)) != f0.key


def test_weight_change_changes_key():
    g1 = Graph.from_edges(3, [(0, 1), (1, 2)], [1.0, 1.0])
    g2 = Graph.from_edges(3, [(0, 1), (1, 2)], [1.0, 2.0])
    assert canonical_key(g1) != canonical_key(g2)


def test_normalized_edges_strips_padding_and_zero_weight():
    g = Graph.from_edges(5, [(0, 1), (2, 1), (3, 4), (2, 4)],
                         [1.0, 2.0, 0.0, 1.5], pad_to=16)
    uv, w = normalized_edges(g)
    assert uv.shape == (3, 2)  # zero-weight and the 12 padding rows dropped
    assert (uv[:, 0] < uv[:, 1]).all()
    assert w.sum() == pytest.approx(4.5)

"""Extra property tests: PEI axioms, config registry integrity, QAOA²
contraction identity, vmapped kernel dispatch, merge-stripe union."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core.graph import Graph, cut_value
from repro.core.pei import approximation_ratio, efficiency_factor, pei
from repro.kernels import ref


# ------------------------------------------------------------------- PEI --
@given(
    cut=st.floats(0, 100),
    opt=st.floats(1, 100),
    t=st.floats(0, 1e4),
    tb=st.floats(0, 1e4),
)
@settings(max_examples=50, deadline=None)
def test_pei_bounded_and_monotone(cut, opt, t, tb):
    v = pei(cut, opt, t, tb)
    assert 0.0 <= v <= 100.0 * max(cut / opt, 1.0) + 1e-9
    # better cut → no lower PEI
    assert pei(cut + 1, opt, t, tb) >= v - 1e-9
    # slower → no higher PEI
    assert pei(cut, opt, t + 10, tb) <= v + 1e-9


@given(t=st.floats(-1e6, 1e6))
@settings(max_examples=30, deadline=None)
def test_efficiency_factor_sigmoid_properties(t):
    ef = efficiency_factor(t, 0.0)
    assert 0.0 <= ef <= 1.0
    assert efficiency_factor(0.0, 0.0) == pytest.approx(0.5)


# --------------------------------------------------------------- configs --
def test_registry_loads_every_arch():
    for arch in configs.lm_arch_ids():
        cfg = configs.get_config(arch)
        red = configs.get_reduced(arch)
        assert cfg.n_layers >= red.n_layers
        assert cfg.name
        # published sizes spot-check
    assert configs.get_config("qwen1.5-0.5b").vocab_size == 151_936
    assert configs.get_config("gemma3-27b").n_layers == 62
    assert configs.get_config("arctic-480b").n_experts == 128
    assert configs.get_config("mamba2-1.3b").ssm_state == 128


def test_paraqaoa_config_taxonomy():
    cfg = configs.get_config("paraqaoa")
    # hardware-dependent / tunable parameters of §4.2 are all present
    assert cfg.n_qubits == 26 and cfg.n_solvers == 256
    assert cfg.top_k >= 1 and cfg.merge_level >= 1


def test_gemma3_layer_pattern_5to1():
    cfg = configs.get_config("gemma3-4b")
    w = cfg.layer_windows()
    globals_ = [i for i, x in enumerate(w) if x == 0]
    locals_ = [i for i, x in enumerate(w) if x > 0]
    assert len(locals_) == pytest.approx(5 * len(globals_), abs=5)
    assert all(x in (0, 1024) for x in w)


def test_zamba2_shared_block_cadence():
    cfg = configs.get_config("zamba2-2.7b")
    kinds = cfg.layer_kinds()
    attn_idx = [i for i, k in enumerate(kinds) if k == "ssm_attn"]
    assert len(attn_idx) == 54 // 6
    assert all(b - a == 6 for a, b in zip(attn_idx, attn_idx[1:]))


# ------------------------------------------------- QAOA² contraction -----
@given(n=st.integers(10, 24), seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_qaoa2_assignment_achieves_reported_cut(n, seed):
    from repro.core.baselines.qaoa_in_qaoa import qaoa_in_qaoa

    g = Graph.erdos_renyi(n, 0.5, seed=seed)
    if g.n_edges == 0:
        return
    assignment, val, _ = qaoa_in_qaoa(g, n_qubits=6, opt_steps=8)
    achieved = float(cut_value(g, jnp.asarray(assignment)))
    assert achieved == pytest.approx(val)


# ------------------------------------------------------- kernels + vmap --
def test_cutvals_kernel_under_vmap():
    """The solver pool vmaps over subgraphs; the Pallas kernel must batch."""
    from repro.kernels import cutvals as K

    n = 6
    gs = [Graph.erdos_renyi(n, 0.6, seed=s, pad_to=32) for s in range(3)]
    edges = jnp.stack([g.edges for g in gs])
    weights = jnp.stack([g.weights for g in gs])
    got = jax.vmap(lambda e, w: K.cutvals(n, e, w, interpret=True))(edges, weights)
    want = jnp.stack([ref.cutvals(n, g.edges, g.weights) for g in gs])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------- merge stripe union --
def test_merge_stripes_cover_everything():
    """Union of striped shards' frontiers == unsharded frontier result."""
    from repro.core import merge as mm
    from repro.core.partition import connectivity_preserving_partition

    g = Graph.erdos_renyi(24, 0.5, seed=3)
    part = connectivity_preserving_partition(g, 3)
    rng = np.random.default_rng(0)
    k = 2
    cand = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = mm.build_merge_plan(part, cand, k)
    full = mm.merge_scan(plan, mm.exact_beam_width(k, part.m))
    best_striped = max(
        float(
            mm.merge_scan(
                plan, 16, shard_id=jnp.int32(s), n_shards=4, split_level=1
            ).cut_value
        )
        for s in range(4)
    )
    assert best_striped == pytest.approx(float(full.cut_value))

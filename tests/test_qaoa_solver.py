"""QAOA statevector solver: correctness vs dense-unitary oracle, norm
preservation, optimization improvement, top-k marginal semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import qaoa as qq
from repro.core.graph import Graph
from repro.kernels import ref


def _rand_graph(n, p, seed):
    return Graph.erdos_renyi(n, p, seed=seed)


@pytest.mark.parametrize("n", [2, 4, 6])
@pytest.mark.parametrize("group", [1, 2, 3, 7])
def test_statevector_matches_dense_oracle(n, group):
    g = _rand_graph(n, 0.6, seed=n)
    cutv = ref.cutvals(n, g.edges, g.weights)
    gamma, beta = 0.37, 0.81
    re, im = qq.qaoa_statevector(
        cutv, n, jnp.array([gamma]), jnp.array([beta]), group=group
    )
    psi0 = jnp.full((2**n,), 2.0 ** (-n / 2), dtype=jnp.complex64)
    psi = ref.dense_qaoa_layer(psi0, cutv, gamma, beta, n)
    np.testing.assert_allclose(np.asarray(re), np.real(psi), atol=1e-5)
    np.testing.assert_allclose(np.asarray(im), np.imag(psi), atol=1e-5)


@given(
    n=st.integers(2, 7),
    seed=st.integers(0, 100),
    p_layers=st.integers(1, 3),
)
@settings(max_examples=15, deadline=None)
def test_statevector_norm_preserved(n, seed, p_layers):
    g = _rand_graph(n, 0.5, seed=seed)
    cutv = ref.cutvals(n, g.edges, g.weights)
    key = jax.random.PRNGKey(seed)
    gammas = jax.random.uniform(key, (p_layers,))
    betas = jax.random.uniform(key, (p_layers,)) + 0.1
    re, im = qq.qaoa_statevector(cutv, n, gammas, betas)
    norm = float(jnp.sum(re * re + im * im))
    assert norm == pytest.approx(1.0, abs=1e-4)


def test_optimization_improves_expectation():
    n = 8
    g = _rand_graph(n, 0.5, seed=5)
    cfg = qq.QAOAConfig(n_qubits=n, p_layers=2, opt_steps=40)
    cutv = ref.cutvals(n, g.edges, g.weights)
    init = qq.linear_ramp_init(cfg.p_layers, cfg.ramp_delta)
    e0 = float(qq.qaoa_expectation(init, cutv, n))
    params = qq.optimize_params(cutv, n, cfg)
    e1 = float(qq.qaoa_expectation(params, cutv, n))
    assert e1 >= e0 - 1e-5
    # must beat the uniform-random expectation (= half total weight)
    assert e1 > 0.5 * float(g.total_weight())


def test_topk_marginal_no_padding_duplicates():
    # subgraph of 3 real qubits inside a 5-qubit solver
    n, n_real = 5, 3
    g = _rand_graph(n_real, 0.9, seed=1)
    edges, weights, masks = qq.pad_subgraph_arrays([g], n)
    cfg = qq.QAOAConfig(n_qubits=n, p_layers=2, opt_steps=10, top_k=4)
    res = qq.solve_subgraph_batch(edges, weights, masks, cfg)
    bits = np.asarray(res.bitstrings)[0]
    # all reported bitstrings live in the real-qubit subspace and are unique
    assert np.all(bits < 2**n_real)
    assert len(set(bits.tolist())) == len(bits)
    # probabilities are a valid sub-distribution
    probs = np.asarray(res.probs)[0]
    assert np.all(probs >= -1e-6) and probs.sum() <= 1.0 + 1e-5


def test_solver_finds_optimum_tiny():
    # 4-cycle: optimal cut = 4 with alternating assignment
    g = Graph.from_edges(4, [(0, 1), (1, 2), (2, 3), (3, 0)])
    edges, weights, masks = qq.pad_subgraph_arrays([g], 4)
    cfg = qq.QAOAConfig(n_qubits=4, p_layers=3, opt_steps=60, top_k=2)
    res = qq.solve_subgraph_batch(edges, weights, masks, cfg)
    top = int(np.asarray(res.bitstrings)[0, 0])
    bits = (top >> np.arange(4)) & 1
    cut = sum(bits[a] != bits[b] for a, b in [(0, 1), (1, 2), (2, 3), (3, 0)])
    assert cut == 4


def test_index_to_bits_roundtrip():
    idx = jnp.array([0, 1, 5, 12], dtype=jnp.int32)
    bits = qq.index_to_bits(idx, 4)
    back = np.asarray(bits) @ (2 ** np.arange(4))
    np.testing.assert_array_equal(back, np.asarray(idx))

"""Tier-1 test configuration.

1. Hypothesis fallback: the property tests import `hypothesis`; offline CI
   images often lack it. Install the vendored shim (tests/_propshim.py)
   into sys.modules before collection when the real package is missing —
   real Hypothesis, when installed, is used untouched.

The `slow` marker (subprocess-based multi-device tests, own CI job) is
registered in pytest.ini.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:  # pragma: no cover - trivially environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    import _propshim

    sys.modules["hypothesis"] = _propshim
    sys.modules["hypothesis.strategies"] = _propshim.strategies  # type: ignore

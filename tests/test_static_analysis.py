"""reprolint (src/repro/analysis): one flagged + one clean snippet per
rule, suppression and baseline mechanics, the PR 5 cache-key regression
replayed against the *real* distributed/qaoa sources, the tier-1
repo-is-clean gate, and a CLI smoke test.

Snippets are analyzed in-memory via `run_on_sources` — same driver as
the CLI minus the filesystem walk."""

import json
import os
import re
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import get_rules, run_on_sources, rule_ids

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _src(path):
    with open(os.path.join(REPO, path), encoding="utf-8") as f:
        return f.read()


def _rules_of(report):
    return [f.rule for f in report.findings]


# ---------------------------------------------------------------- registry --
def test_rule_registry_is_complete():
    assert rule_ids() == [
        "cache-key", "dispatch-purity", "tracer-hazard",
        "collective-axis", "hot-nondeterminism",
    ]


def test_unknown_rule_id_raises():
    with pytest.raises(KeyError):
        get_rules(["cache-key", "no-such-rule"])


# --------------------------------------------------------------- cache-key --
_UNKEYED_BUILDER = """
import functools
from repro.kernels import ops

@functools.lru_cache(maxsize=8)
def build(n: int):
    def run(x):
        return ops.apply_phase(x, x, None, 0.1)
    return run
"""

_KEYED_BUILDER = """
import functools
from repro.kernels import ops

@functools.lru_cache(maxsize=8)
def build(n: int, impl: str):
    def run(x):
        with ops.using_implementation(impl):
            return ops.apply_phase(x, x, None, 0.1)
    return run
"""

_GLOBAL_READ_BUILDER = """
import functools
from repro.kernels import ops

@functools.lru_cache(maxsize=8)
def build(n: int):
    def run(x):
        with ops.using_implementation(ops.get_implementation()):
            return ops.apply_phase(x, x, None, 0.1)
    return run
"""


def test_cache_key_flags_unkeyed_impl_sensitive_builder():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _UNKEYED_BUILDER}, rules=["cache-key"]
    )
    assert _rules_of(rep) == ["cache-key"]
    assert rep.findings[0].symbol == "build"


def test_cache_key_accepts_keyed_builder():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _KEYED_BUILDER}, rules=["cache-key"]
    )
    assert rep.findings == []


def test_cache_key_flags_trace_time_global_read():
    # using_implementation(ops.get_implementation()) re-reads the global
    # at trace time: the lru key cannot see it
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _GLOBAL_READ_BUILDER},
        rules=["cache-key"],
    )
    assert _rules_of(rep) == ["cache-key"]


_TUNE_STATE_GLOBAL_READ = """
import functools
from repro.kernels import ops
from repro.kernels import tuning

@functools.lru_cache(maxsize=8)
def build(n: int, impl: str):
    def run(x):
        with ops.using_implementation(impl), \\
                tuning.using_state(tuning.state()):
            return ops.apply_phase(x, x, None, 0.1)
    return run
"""

_TUNE_STATE_KEYED = """
import functools
from repro.kernels import ops
from repro.kernels import tuning

@functools.lru_cache(maxsize=8)
def build(n: int, impl: str, tune: tuple):
    def run(x):
        with ops.using_implementation(impl), tuning.using_state(tune):
            return ops.apply_phase(x, x, None, 0.1)
    return run
"""


def test_cache_key_flags_trace_time_tuning_state_read():
    # tuning.using_state(tuning.state()) inside a cached builder is the
    # same cache-blindness bug as the get_implementation() re-read: the
    # block-shape table the body traces against never reaches the lru key
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _TUNE_STATE_GLOBAL_READ},
        rules=["cache-key"],
    )
    assert _rules_of(rep) == ["cache-key"]
    assert "tuning.using_state()" in rep.findings[0].message


def test_cache_key_accepts_param_tuning_state():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _TUNE_STATE_KEYED}, rules=["cache-key"]
    )
    assert rep.findings == []


def test_cache_key_regression_solve_pool_program():
    """Acceptance criterion: stripping the PR 5 fix (the `impl` re-assert
    inside the cached pool/statevector builders) out of the *real*
    distributed.py must re-raise the finding — exercising the
    cross-module call graph through qaoa's `solve_subgraph_batch` vmap
    alias. The unmodified sources must stay clean."""
    paths = [
        "src/repro/core/distributed.py", "src/repro/core/qaoa.py",
        "src/repro/core/engine.py", "src/repro/core/merge.py",
        "src/repro/kernels/ops.py", "src/repro/compat.py",
    ]
    sources = {p: _src(p) for p in paths}
    assert run_on_sources(sources, rules=["cache-key"]).findings == []

    dist_src = sources["src/repro/core/distributed.py"]
    degraded, n_subs = re.subn(
        r"with ops\.using_implementation\(impl\)"
        r"(?:, tuning\.using_state\(tune\))?:",
        "if True:", dist_src,
    )
    assert n_subs >= 2, "expected the keyed builders in distributed.py"
    sources["src/repro/core/distributed.py"] = degraded
    rep = run_on_sources(sources, rules=["cache-key"])
    flagged = {f.symbol for f in rep.findings}
    assert "_solve_pool_program" in flagged, [f.render() for f in rep.findings]
    assert "_sharded_qaoa_program" in flagged

    # variant: delete `impl` from the cache signature but keep the
    # re-assert — now the with-block reads a value the lru key cannot
    # see, the other half of the same bug
    unsigned, n_subs = re.subn(
        r"(?m)^(\s*)impl: str,?$|,\s*impl: str(?=\s*\))", r"\1", dist_src
    )
    assert n_subs >= 2, "expected impl params in the builder signatures"
    sources["src/repro/core/distributed.py"] = unsigned
    rep = run_on_sources(sources, rules=["cache-key"])
    assert any(
        f.rule == "cache-key" and "_solve_pool_program" in (f.symbol or "")
        for f in rep.findings
    ), [f.render() for f in rep.findings]


# ---------------------------------------------------------- dispatch-purity --
_DIRECT_IMPORT = """
from repro.kernels import ref

def f(x):
    return ref.cutvals(4, x, x)
"""

_VIA_OPS = """
from repro.kernels import ops

def f(x):
    return ops.cutvals(4, x, x)
"""


def test_dispatch_purity_flags_direct_impl_import():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _DIRECT_IMPORT},
        rules=["dispatch-purity"],
    )
    assert _rules_of(rep) == ["dispatch-purity"]


def test_dispatch_purity_accepts_ops_and_allowed_zones():
    clean = run_on_sources(
        {"src/repro/core/snippet.py": _VIA_OPS}, rules=["dispatch-purity"]
    )
    assert clean.findings == []
    # tests/ and the kernels package itself may touch impls directly
    for path in ("tests/snippet.py", "src/repro/kernels/snippet.py"):
        rep = run_on_sources({path: _DIRECT_IMPORT}, rules=["dispatch-purity"])
        assert rep.findings == [], path


# ------------------------------------------------------------ tracer-hazard --
_TRACER_BAD = """
import jax
import numpy as np

@jax.jit
def f(x):
    if x > 0:
        x = x + 1
    y = float(x)
    return np.sum(x) + y
"""

_TRACER_CLEAN = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x, n: int, mode: str = "fast"):
    if n > 2 and mode == "fast":  # static config, annotated
        x = x * 2
    for _ in range(int(x.shape[0])):  # shapes are static
        x = jnp.where(x > 0, x, -x)  # traced compare stays in jnp
    if x is None:  # identity is static even on tracers
        return x
    return x
"""


def test_tracer_hazard_flags_casts_numpy_and_control_flow():
    rep = run_on_sources(
        {"src/repro/models/snippet.py": _TRACER_BAD},
        rules=["tracer-hazard"],
    )
    msgs = " ".join(f.message for f in rep.findings)
    assert len(rep.findings) == 3, [f.render() for f in rep.findings]
    assert "float()" in msgs and "numpy" in msgs and "`if`" in msgs


def test_tracer_hazard_quiet_on_static_config_and_shapes():
    rep = run_on_sources(
        {"src/repro/models/snippet.py": _TRACER_CLEAN},
        rules=["tracer-hazard"],
    )
    assert rep.findings == [], [f.render() for f in rep.findings]


def test_tracer_hazard_only_fires_inside_traced_functions():
    # same body, no jit: plain host code may cast freely
    host = _TRACER_BAD.replace("@jax.jit\n", "")
    rep = run_on_sources(
        {"src/repro/models/snippet.py": host}, rules=["tracer-hazard"]
    )
    assert rep.findings == []


# ---------------------------------------------------------- collective-axis --
_AXIS_BAD = """
import jax

def f(x):
    return jax.lax.psum(x, "batch")
"""

_AXIS_UNBOUND = """
import jax

def f(x):
    return jax.lax.psum(x, some_axis)
"""

_AXIS_CLEAN = """
import jax

def f(x, layout, axis: str):
    a = jax.lax.psum(x, "model")
    b = jax.lax.pmean(x, layout.axis)
    c = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0)
    d = jax.lax.axis_index(axis_name=("data", "model"))
    return a + b + c + d
"""


def test_collective_axis_flags_unknown_literal_and_unbound_name():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _AXIS_BAD}, rules=["collective-axis"]
    )
    assert _rules_of(rep) == ["collective-axis"]
    assert "batch" in rep.findings[0].message
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _AXIS_UNBOUND},
        rules=["collective-axis"],
    )
    assert _rules_of(rep) == ["collective-axis"]


def test_collective_axis_accepts_mesh_axes_params_and_layout_attr():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _AXIS_CLEAN}, rules=["collective-axis"]
    )
    assert rep.findings == [], [f.render() for f in rep.findings]


# ------------------------------------------------------- hot-nondeterminism --
_NONDET_TRACED = """
import jax
import time
import random

@jax.jit
def f(x):
    return x * random.random() + time.time()
"""

_SCHED_BAD = """
import time
import random

def _pick_bucket(buckets):
    t = time.time()
    return buckets[int(t) % len(buckets)] if random.random() > 0.5 else None
"""

_SCHED_CLEAN = """
import time

def _pick_bucket(buckets):
    t0 = time.perf_counter()
    best = min(buckets)
    return best, time.perf_counter() - t0
"""


def test_nondeterminism_flags_rng_and_clock_in_traced_fn():
    rep = run_on_sources(
        {"src/repro/core/snippet.py": _NONDET_TRACED},
        rules=["hot-nondeterminism"],
    )
    assert sorted(_rules_of(rep)) == ["hot-nondeterminism"] * 2


def test_nondeterminism_guards_scheduler_path_allows_perf_counter():
    path = "src/repro/service/scheduler.py"  # module under guard
    rep = run_on_sources({path: _SCHED_BAD}, rules=["hot-nondeterminism"])
    assert len(rep.findings) == 2, [f.render() for f in rep.findings]
    rep = run_on_sources({path: _SCHED_CLEAN}, rules=["hot-nondeterminism"])
    assert rep.findings == []
    # identical code outside the guarded module (and untraced) is fine
    rep = run_on_sources(
        {"src/repro/service/solver_api.py": _SCHED_BAD},
        rules=["hot-nondeterminism"],
    )
    assert rep.findings == []


_OBS_BAD = """
import time

def begin(name):
    # direct clock read: bypasses the injectable clock, so a tracer
    # running under a VirtualClock would stamp wall time into spans
    return name, time.perf_counter()
"""

_OBS_CLEAN = """
from repro.obs.clock import default_clock

def begin(name, clock=default_clock):
    return name, clock()
"""


def test_nondeterminism_obs_package_bans_all_clock_reads():
    # inside repro.obs.* even the monotonic clocks the scheduler region
    # allows are banned — timestamps must flow through the injectable
    # clock so virtual-clock soaks stay bit-deterministic (DESIGN.md §8)
    path = "src/repro/obs/snippet.py"
    rep = run_on_sources({path: _OBS_BAD}, rules=["hot-nondeterminism"])
    assert len(rep.findings) == 1, [f.render() for f in rep.findings]
    assert "injectable clock" in rep.findings[0].message
    rep = run_on_sources({path: _OBS_CLEAN}, rules=["hot-nondeterminism"])
    assert rep.findings == []


def test_nondeterminism_obs_clock_module_is_the_sanctioned_boundary():
    # the clock module itself may touch time.* — it IS the boundary
    rep = run_on_sources(
        {"src/repro/obs/clock.py": _OBS_BAD}, rules=["hot-nondeterminism"]
    )
    assert rep.findings == []
    # the carve-out is the obs package only: an unguarded, untraced
    # module elsewhere may still read perf_counter freely
    rep = run_on_sources(
        {"src/repro/service/solver_api.py": _OBS_BAD},
        rules=["hot-nondeterminism"],
    )
    assert rep.findings == []


def test_nondeterminism_measurement_path_bans_all_clock_reads():
    # the autotune timing helper (repro.kernels.tuning) is held to the
    # obs-package contract: its timings feed the committed tuning cache,
    # so sweeps must be replayable through the injectable clock — direct
    # time.* reads (even monotonic ones) are banned (DESIGN.md §2.7)
    path = "src/repro/kernels/tuning.py"
    rep = run_on_sources({path: _OBS_BAD}, rules=["hot-nondeterminism"])
    assert len(rep.findings) == 1, [f.render() for f in rep.findings]
    assert "measurement-path" in rep.findings[0].message
    assert "injectable clock" in rep.findings[0].message
    rep = run_on_sources({path: _OBS_CLEAN}, rules=["hot-nondeterminism"])
    assert rep.findings == []
    # the guard is that one module, not the whole kernels package
    rep = run_on_sources(
        {"src/repro/kernels/snippet.py": _OBS_BAD},
        rules=["hot-nondeterminism"],
    )
    assert rep.findings == []


# ------------------------------------------------ suppression and baseline --
def test_line_suppression_with_justification():
    src = _DIRECT_IMPORT.replace(
        "from repro.kernels import ref",
        "from repro.kernels import ref"
        "  # reprolint: disable=dispatch-purity (comparing against ref)",
    )
    rep = run_on_sources(
        {"src/repro/core/snippet.py": src}, rules=["dispatch-purity"]
    )
    assert rep.findings == [] and rep.suppressed == 1


def test_file_suppression():
    src = "# reprolint: disable-file=tracer-hazard\n" + _TRACER_BAD
    rep = run_on_sources(
        {"src/repro/models/snippet.py": src}, rules=["tracer-hazard"]
    )
    assert rep.findings == [] and rep.suppressed == 3


def test_suppression_is_per_rule():
    # suppressing one rule must not silence another on the same line
    src = _DIRECT_IMPORT.replace(
        "from repro.kernels import ref",
        "from repro.kernels import ref  # reprolint: disable=cache-key",
    )
    rep = run_on_sources(
        {"src/repro/core/snippet.py": src}, rules=["dispatch-purity"]
    )
    assert _rules_of(rep) == ["dispatch-purity"]


def test_baseline_absorbs_then_releases_on_code_change():
    path = "src/repro/core/snippet.py"
    rep = run_on_sources({path: _DIRECT_IMPORT}, rules=["dispatch-purity"])
    fp = rep.findings[0].fingerprint
    rep2 = run_on_sources(
        {path: _DIRECT_IMPORT}, rules=["dispatch-purity"], baseline={fp}
    )
    assert rep2.findings == [] and rep2.baselined == 1
    # the fingerprint tracks the *code*: change the offending line and
    # the grandfathered entry no longer matches
    changed = _DIRECT_IMPORT.replace(
        "import ref", "import ref as reference"
    )
    rep3 = run_on_sources(
        {path: changed}, rules=["dispatch-purity"], baseline={fp}
    )
    assert len(rep3.findings) == 1 and rep3.baselined == 0


@given(pad=st.integers(min_value=0, max_value=12))
@settings(max_examples=10, deadline=None)
def test_fingerprint_stable_under_line_churn(pad):
    """Baseline identity must survive unrelated edits above the finding:
    fingerprints hash rule + path tail + symbol + line text, not line
    numbers."""
    base = run_on_sources(
        {"src/repro/core/snippet.py": _DIRECT_IMPORT},
        rules=["dispatch-purity"],
    ).findings[0]
    padded = "# padding\n" * pad + _DIRECT_IMPORT
    moved = run_on_sources(
        {"src/repro/core/snippet.py": padded}, rules=["dispatch-purity"]
    ).findings[0]
    assert moved.fingerprint == base.fingerprint
    assert moved.line == base.line + pad


def test_fingerprint_anchors_path_at_src():
    rel = run_on_sources(
        {"src/repro/core/snippet.py": _DIRECT_IMPORT},
        rules=["dispatch-purity"],
    ).findings[0]
    abs_ = run_on_sources(
        {"/somewhere/else/src/repro/core/snippet.py": _DIRECT_IMPORT},
        rules=["dispatch-purity"],
    ).findings[0]
    assert rel.fingerprint == abs_.fingerprint


# -------------------------------------------------------- tier-1 repo gate --
def test_repo_tree_is_reprolint_clean():
    """The CI lint job's contract, enforced from tier-1 as well: the
    whole src/repro tree passes every rule (modulo justified inline
    suppressions and the checked-in baseline)."""
    from repro.analysis import load_baseline, run

    baseline = os.path.join(REPO, "src", "repro", "analysis", "baseline.json")
    report = run(
        [os.path.join(REPO, "src", "repro")],
        baseline_path=baseline,
    )
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings
    )


# --------------------------------------------------------------- CLI smoke --
def _run_cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd,
    )


def test_cli_json_on_violation(tmp_path):
    bad = tmp_path / "src" / "repro" / "core" / "snippet.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(_DIRECT_IMPORT)
    proc = _run_cli(str(bad), "--format", "json", "--baseline", "none")
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"][0]["rule"] == "dispatch-purity"
    assert payload["findings"][0]["fingerprint"]


def test_cli_clean_tree_exits_zero():
    proc = _run_cli("src/repro", "--format", "json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["findings"] == []
    assert payload["files"] > 50


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    assert proc.stdout.split() == rule_ids()

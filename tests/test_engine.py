"""Statevector-engine invariants testable without a device mesh
(DESIGN.md §2.6): layout-B geometry (relabeling + global-qubit mix),
flat-path equivalence against the dense oracle, the shared Adam scan,
and the no-direct-`ref.*` contract of the sharded hot loop."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import distributed as dist
from repro.core import engine
from repro.core.graph import Graph
from repro.kernels import ref


def _layout(h: int, log2_chunk: int) -> engine.ShardedLayout:
    """Smallest-n layout with the requested shard geometry: n_local is
    h (the post-swap global-qubit block) + log2_chunk (the a2a block)."""
    n_local = h + log2_chunk
    return engine.ShardedLayout(
        n=n_local + h, axis="model", axis_size=2**h
    )


# ------------------------------------------------------ layout-B geometry --
@given(h=st.integers(1, 3), log2_chunk=st.integers(0, 4),
       seed=st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_layout_b_is_a_relabeling(h, log2_chunk, seed):
    """The union of per-device layout-B index rows is a permutation of the
    basis — so evaluating the diagonal cost in layout B is a pure
    relabeling (the alternating schedule's correctness condition), and
    the layout-B cut table is the layout-A table gathered through it."""
    lay = _layout(h, log2_chunk)
    g = Graph.erdos_renyi(lay.n, 0.5, seed=seed)
    cutv = np.asarray(ref.cutvals(lay.n, g.edges, g.weights))
    seen = []
    for dev in range(lay.axis_size):
        idx_a, idx_b = engine.layout_index_maps(lay, dev)
        assert idx_a.shape == idx_b.shape == (lay.local_dim,)
        seen.append(idx_b)
        np.testing.assert_array_equal(
            np.asarray(
                ref.cutvals_at(jnp.asarray(idx_b, jnp.int32), g.edges,
                               g.weights)
            ),
            cutv[idx_b],
        )
    flat = np.concatenate(seen)
    np.testing.assert_array_equal(np.sort(flat), np.arange(2**lay.n))


@given(h=st.integers(1, 3), log2_chunk=st.integers(0, 3),
       seed=st.integers(0, 8))
@settings(max_examples=25, deadline=None)
def test_layout_b_local_mix_is_global_qubit_mix(h, log2_chunk, seed):
    """In layout B the local bits [log2_chunk, log2_chunk+h) are the
    original high h qubits: a *local* `apply_mixer_bits` there equals the
    global mixer on qubits [n_local, n) — the property that lets the
    sharded engine mix the shard-axis qubits without further collectives."""
    lay = _layout(h, log2_chunk)
    n = lay.n
    rng = np.random.default_rng(seed)
    s_re = rng.normal(size=2**n).astype(np.float32)
    s_im = rng.normal(size=2**n).astype(np.float32)
    beta = jnp.float32(0.3 + 0.1 * seed)

    want_re, want_im = ref.apply_mixer_bits(
        jnp.asarray(s_re), jnp.asarray(s_im), n, lay.n_local, lay.h, beta
    )

    got_re = np.zeros_like(s_re)
    got_im = np.zeros_like(s_im)
    for dev in range(lay.axis_size):
        # the qubit-swap all_to_all delivers exactly s[idx_b] to device dev
        _, idx_b = engine.layout_index_maps(lay, dev)
        lre, lim = ref.apply_mixer_bits(
            jnp.asarray(s_re[idx_b]),
            jnp.asarray(s_im[idx_b]),
            lay.n_local,
            lay.log2_chunk,
            lay.h,
            beta,
        )
        got_re[idx_b] = np.asarray(lre)
        got_im[idx_b] = np.asarray(lim)

    np.testing.assert_allclose(got_re, np.asarray(want_re), atol=2e-6)
    np.testing.assert_allclose(got_im, np.asarray(want_im), atol=2e-6)


# ------------------------------------------------------- flat-path parity --
@pytest.mark.parametrize("n,p", [(5, 1), (6, 2)])
def test_flat_evolve_matches_dense_oracle(n, p):
    g = Graph.erdos_renyi(n, 0.5, seed=n)
    cutv = ref.cutvals(n, g.edges, g.weights)
    gammas = jnp.linspace(0.2, 0.7, p).astype(jnp.float32)
    betas = jnp.linspace(0.8, 0.3, p).astype(jnp.float32)

    layout = engine.FlatLayout(n=n)
    cut = engine.CutTable(cutv, None, None, None)
    re, im, in_b = engine.evolve(layout, cut, gammas, betas)
    assert in_b is False

    psi = jnp.full((2**n,), 2.0 ** (-n / 2), dtype=jnp.complex64)
    for l in range(p):
        psi = ref.dense_qaoa_layer(psi, cutv, float(gammas[l]),
                                   float(betas[l]), n)
    np.testing.assert_allclose(np.asarray(re), np.asarray(psi.real),
                               atol=3e-6)
    np.testing.assert_allclose(np.asarray(im), np.asarray(psi.imag),
                               atol=3e-6)


def test_flat_evolve_is_qaoa_statevector():
    """`qaoa.qaoa_statevector` is the engine's FlatLayout path — bitwise."""
    from repro.core import qaoa as qaoa_mod

    n = 7
    g = Graph.erdos_renyi(n, 0.4, seed=1)
    cutv = ref.cutvals(n, g.edges, g.weights)
    gammas, betas = qaoa_mod.linear_ramp_init(3, 0.75)
    re1, im1 = qaoa_mod.qaoa_statevector(cutv, n, gammas, betas)
    cut = engine.CutTable(cutv, None, None, None)
    re2, im2, _ = engine.evolve(engine.FlatLayout(n=n), cut, gammas, betas)
    np.testing.assert_array_equal(np.asarray(re1), np.asarray(re2))
    np.testing.assert_array_equal(np.asarray(im1), np.asarray(im2))


# ------------------------------------------------------------- adam_scan --
def test_adam_scan_minimizes_quadratic():
    grad_fn = jax.grad(lambda p: jnp.sum((p[0] - 3.0) ** 2))
    (x,) = engine.adam_scan(grad_fn, (jnp.zeros((2,)),), 200, 0.1)
    np.testing.assert_allclose(np.asarray(x), 3.0, atol=1e-2)


# ------------------------------------------- hot-loop dispatch contract --
def test_sharded_hot_loop_has_no_direct_ref_calls():
    """Acceptance contract: every op in the sharded hot loop goes through
    the `kernels.ops` dispatch — no `ref.*` escapes it (the runtime half
    of this contract is tests/test_distributed.py's
    `test_engine_ops_dispatch_per_shard`).

    The old hand-rolled regex over `inspect.getsource` is gone: the
    invariant is now reprolint's `dispatch-purity` rule (tree-wide check:
    tests/test_static_analysis.py::test_repo_tree_is_reprolint_clean);
    this asserts it on the hot-loop modules so the engine suite still
    fails standalone if a direct kernel import sneaks in here."""
    from repro.analysis import run_on_sources

    sources = {}
    for mod in (dist, engine):
        path = inspect.getsourcefile(mod)
        with open(path, encoding="utf-8") as f:
            sources[path] = f.read()
    report = run_on_sources(sources, rules=["dispatch-purity"])
    assert not report.findings, [f.render() for f in report.findings]

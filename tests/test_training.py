"""Training substrate units: AdamW math, schedule, clipping, CE loss,
deterministic data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.training import optimizer as opt
from repro.training.data import DataConfig, synthetic_batch
from repro.training.train_step import cross_entropy


def test_adamw_minimizes_quadratic():
    cfg = opt.AdamWConfig(
        learning_rate=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
        min_lr_ratio=1.0, clip_norm=1e9,
    )
    target = jnp.asarray([3.0, -2.0])
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.apply(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_weight_decay_only_on_matrices():
    cfg = opt.AdamWConfig(learning_rate=0.0, weight_decay=0.5, warmup_steps=0)
    # lr = 0 → pure decay term × lr = 0: params unchanged regardless
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.apply(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0)
    # now with lr > 0: matrices decay, vectors don't (ndim<2 masked out)
    cfg = opt.AdamWConfig(learning_rate=0.1, weight_decay=0.5, warmup_steps=0,
                          min_lr_ratio=1.0)
    new, _, _ = opt.apply(cfg, params, grads, opt.init(params))
    assert float(new["w"][0, 0]) < 1.0
    assert float(new["b"][0]) == pytest.approx(1.0)


def test_schedule_warmup_and_cosine():
    cfg = opt.AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(opt.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(opt.schedule(cfg, jnp.asarray(110))) == pytest.approx(0.1)
    mid = float(opt.schedule(cfg, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, norm = opt.clip_by_global_norm(grads, 1.0)
    total = float(opt.global_norm(clipped))
    assert float(norm) == pytest.approx(np.sqrt(3 * 16 + 4 * 9))
    assert total == pytest.approx(1.0, rel=1e-5)
    # under the cap: untouched
    same, _ = opt.clip_by_global_norm(grads, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 4.0)


def test_cross_entropy_matches_naive():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 11))
    labels = jax.random.randint(key, (2, 5), 0, 11)
    got = float(cross_entropy(logits, labels))
    # naive
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -float(
        jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
    )
    assert got == pytest.approx(want, rel=1e-5)


def test_cross_entropy_masks_negative_labels():
    logits = jnp.zeros((1, 4, 7))
    labels = jnp.asarray([[1, 2, -1, -1]])
    # uniform logits → CE = log(7) over the 2 unmasked tokens
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(7), rel=1e-5)


@given(step=st.integers(0, 1000), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic(step, seed):
    cfg = configs.get_reduced("qwen1_5_0_5b")
    dcfg = DataConfig(seed=seed, batch=2, seq=16)
    a = synthetic_batch(cfg, dcfg, step)
    b = synthetic_batch(cfg, dcfg, step)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # labels are left-shifted tokens
    np.testing.assert_array_equal(
        np.asarray(a["labels"][:, :-1]), np.asarray(a["tokens"][:, 1:])
    )


def test_data_pipeline_host_slicing():
    cfg = configs.get_reduced("qwen1_5_0_5b")
    full = synthetic_batch(cfg, DataConfig(seed=1, batch=4, seq=8), 3)
    h0 = synthetic_batch(cfg, DataConfig(seed=1, batch=4, seq=8, host_id=0, n_hosts=2), 3)
    h1 = synthetic_batch(cfg, DataConfig(seed=1, batch=4, seq=8, host_id=1, n_hosts=2), 3)
    stitched = np.concatenate([np.asarray(h0["tokens"]), np.asarray(h1["tokens"])])
    np.testing.assert_array_equal(stitched, np.asarray(full["tokens"]))

"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + (where the family supports it) prefill/decode consistency.
All on CPU with tiny dims; the full configs are exercised by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import build_model
from repro.training import optimizer as opt
from repro.training.train_step import TrainConfig, init_state, train_step

ARCHS = list(configs.lm_arch_ids())


def _batch(cfg, key, bsz=2, seq=32):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (bsz, seq), 0, cfg.vocab_size),
    }
    labels_len = seq
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[1], (bsz, cfg.frontend_seq, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (bsz, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    batch["labels"] = jax.random.randint(ks[2], (bsz, labels_len), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    bsz, seq = batch["tokens"].shape
    assert logits.shape == (bsz, seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_nothing_nan(arch):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        adamw=opt.AdamWConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10),
        remat=True,
    )
    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = jax.jit(
        lambda s, b: train_step(s, b, model, tcfg)
    )(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, state2.params
    )
    assert any(jax.tree.leaves(changed))


DECODE_ARCHS = [a for a in ARCHS]  # all assigned archs have a decoder


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full-sequence forward:
    run prefill on s tokens, then decode token s; compare with forward
    logits at position s."""
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bsz, seq = 2, 16
    batch = _batch(cfg, jax.random.PRNGKey(1), bsz=bsz, seq=seq)
    tokens = batch["tokens"]

    full_logits, _ = model.forward(params, batch)

    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    prompt = {**batch, "tokens": tokens[:, : seq - 1]}
    pre_logits, state = model.prefill(params, prompt, s_max=seq + extra + 8)
    step_logits, state = model.decode_step(params, tokens[:, seq - 1], state)

    # prefill last-position logits == forward at seq-2
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]),
        np.asarray(full_logits[:, seq - 2]),
        # MoE tolerance is looser: capacity-based dropping differs between
        # a 31-token forward and a 1-token decode (expected semantics)
        atol=5e-2 if cfg.n_experts else 5e-3,
        rtol=1e-2,
    )
    # decode-step logits == forward at seq-1
    np.testing.assert_allclose(
        np.asarray(step_logits),
        np.asarray(full_logits[:, seq - 1]),
        atol=5e-2 if cfg.n_experts else 5e-3,
        rtol=1e-2,
    )


def test_sliding_window_differs_from_full():
    """gemma3 reduced config: local layers must actually mask."""
    import dataclasses

    cfg = configs.get_reduced("gemma3_4b")
    assert cfg.sliding_window is not None
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), bsz=1, seq=64)
    logits_local, _ = model.forward(params, batch)
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    logits_full, _ = build_model(cfg_full).forward(params, batch)
    assert not np.allclose(
        np.asarray(logits_local), np.asarray(logits_full), atol=1e-4
    )


def test_moe_router_actually_routes():
    cfg = configs.get_reduced("moonshot_v1_16b_a3b")
    assert cfg.n_experts > 1
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    # balanced-ish routing at init: aux loss near 1.0 (= E * mean² * E terms)
    assert 0.5 < float(aux) / cfg.n_layers < 4.0


def test_ssd_chunked_grads_finite_under_long_decay():
    """Regression for the mamba2 NaN grad_norm: ssd_chunked used to exp()
    the *unmasked* upper triangle of the intra-chunk log-decay matrix.
    With |a|·dt·Q ≳ 89 that overflows f32 to inf; the forward was saved by
    the tril mask, but backprop through where(tri, inf·cb, 0) turns the
    masked entries into 0·inf = NaN."""
    from repro.models import ssm

    key = jax.random.PRNGKey(0)
    b, s, h, p, n, q = 1, 16, 4, 4, 4, 8
    x = jax.random.normal(key, (b, s, h, p), jnp.float32)
    dt = jnp.full((b, s, h), 5.0, jnp.float32)  # worst-case decay range
    a = -jnp.asarray([1.0, 4.0, 16.0, 64.0])  # |a|·dt·(q-1) up to 2240 ≫ 89
    b_mat = jax.random.normal(jax.random.PRNGKey(1), (b, s, n), jnp.float32)
    c_mat = jax.random.normal(jax.random.PRNGKey(2), (b, s, n), jnp.float32)

    def loss(x, dt, b_mat, c_mat):
        y, h_fin = ssm.ssd_chunked(x, dt, a, b_mat, c_mat, q)
        return jnp.sum(y.astype(jnp.float32) ** 2) + jnp.sum(h_fin**2)

    val = loss(x, dt, b_mat, c_mat)
    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(x, dt, b_mat, c_mat)
    assert bool(jnp.isfinite(val))
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g))), "NaN/inf gradient in SSD path"


def test_param_count_matches_analytic():
    for arch in ("qwen1_5_0_5b", "mamba2_1_3b", "moonshot_v1_16b_a3b"):
        cfg = configs.get_reduced(arch)
        model = build_model(cfg)
        shapes = model.param_shapes()
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.15, (
            arch, actual, analytic,
        )

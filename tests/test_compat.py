"""Unit tests for the JAX version-portability layer (repro.compat)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


def test_shard_map_resolved():
    assert callable(compat._RAW_SHARD_MAP)
    # on every supported version exactly one of the two kwargs exists
    assert compat._CHECK_KWARG in ("check_vma", "check_rep")


def test_shard_map_runs_on_single_device_mesh():
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(
        lambda x: x * 2.0, mesh, in_specs=(P(),), out_specs=P()
    )
    out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0, 6.0])


def test_check_kwarg_adaptation(monkeypatch):
    """The wrapper must translate `check=` onto whichever kwarg the
    resolved shard_map exposes — both the new-style and 0.4.x spellings."""
    seen = {}

    def new_style(f, *, mesh, in_specs, out_specs, check_vma=True):
        seen.update(check_vma=check_vma)
        return f

    def old_style(f, *, mesh, in_specs, out_specs, check_rep=True):
        seen.update(check_rep=check_rep)
        return f

    for impl, kwarg in ((new_style, "check_vma"), (old_style, "check_rep")):
        monkeypatch.setattr(compat, "_RAW_SHARD_MAP", impl)
        assert compat._check_kwarg_name() == kwarg
        monkeypatch.setattr(compat, "_CHECK_KWARG", kwarg)
        seen.clear()
        compat.shard_map(lambda x: x, None, in_specs=(), out_specs=())
        assert seen == {kwarg: False}


def test_make_mesh_axes():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert mesh.shape == {"data": 1, "model": 1}
    assert compat.mesh_data_axes(mesh) == ("data",)
    assert compat.mesh_model_axis(mesh) == "model"
    no_model = compat.make_mesh((1,), ("data",))
    assert compat.mesh_model_axis(no_model) is None


def test_donation_gating():
    assert compat.supports_donation("tpu")
    assert compat.supports_donation("gpu")
    assert not compat.supports_donation("cpu")
    # jit with donation requested still works on the current backend
    f = compat.jit(lambda x: x + 1, donate_argnums=(0,))
    assert float(f(jnp.float32(1.0))) == 2.0


def test_ensure_host_device_count_after_init():
    # backend is initialized by the time tests run: must be a no-op that
    # reports the real count instead of mutating XLA_FLAGS
    n = len(jax.devices())
    assert compat.ensure_host_device_count(64) == n


def test_cached_program_builder_called_once():
    calls = []

    @compat.cached_program
    def build(key):
        calls.append(key)
        return lambda x: x * key

    f1 = build(3)
    f2 = build(3)
    assert f1 is f2 and calls == [3]
    build(4)
    assert calls == [3, 4]

"""Minimal hypothesis-compatible shim over seeded random draws.

The tier-1 suite's property tests use a small slice of the Hypothesis API
(`given`/`settings`/`strategies.integers`/`strategies.floats`). When real
Hypothesis is installed it is used untouched (see conftest.py); offline,
this shim substitutes deterministic seeded sampling:

  - every test gets its own RNG seeded from its qualified name, so runs
    are reproducible and order-independent;
  - `max_examples` is honored; `deadline` and other settings kwargs are
    accepted and ignored;
  - on failure, the falsifying example is attached to the exception args
    so the pytest report shows the drawn values.

No shrinking, no example database — this is a fallback, not a replacement.
"""

from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw, desc: str):
        self._draw = draw
        self._desc = desc

    def example_from(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return self._desc


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rng: random.Random):
        # hit the bounds occasionally — the cheapest of hypothesis's edge
        # biases, and the one these property tests actually rely on
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.1:
            return hi
        return rng.uniform(lo, hi)

    return _Strategy(draw, f"floats({min_value}, {max_value})")


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq), f"sampled_from({seq!r})")


class settings:
    """Decorator form only (matches how the suite uses it)."""

    def __init__(self, max_examples: int = 100, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._propshim_settings = self
        return fn


_DEFAULT_MAX_EXAMPLES = 20


def given(**strategies_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_propshim_settings", None) or getattr(
                fn, "_propshim_settings", None
            )
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strategies_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    e.args = (
                        f"falsifying example (propshim): {drawn!r}",
                    ) + tuple(e.args)
                    raise

        # tolerate @settings stacked above @given as well as below
        if hasattr(fn, "_propshim_settings"):
            wrapper._propshim_settings = fn._propshim_settings
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest must not see the strategy-filled params (it would look for
        # fixtures named after them); expose only the remaining ones
        del wrapper.__dict__["__wrapped__"]
        remaining = [
            p
            for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies_kwargs
        ]
        wrapper.__signature__ = inspect.Signature(remaining)
        return wrapper

    return deco


# `from hypothesis import strategies as st` resolves this attribute when the
# shim module is installed as sys.modules["hypothesis"]
strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    booleans=booleans,
    sampled_from=sampled_from,
)

"""Problem families through the one diagonal-cost oracle (DESIGN.md §9):
weighted Max-Cut → arbitrary QUBO → penalty-encoded MIS.

Covers the oracle contract at every layer: kernel linear terms (values +
custom-vjp gradients), the `Problem` wrapper's QUBO/MIS encodings against
dense evaluation and exhaustive brute force, partition/merge linear
threading (merge made exhaustive via top_k = 2^n so the solve is provably
optimal on small instances), canonical-hash separation of linear-distinct
QUBOs, service≡solo bit-parity for weighted and QUBO traffic, and the
local-search re-score/epsilon bugfixes."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParaQAOAConfig, solve
from repro.core.baselines.brute_force import (
    brute_force_maxcut,
    brute_force_problem,
)
from repro.core.baselines.local_search import refine
from repro.core.graph import (
    Graph,
    Problem,
    as_problem,
    cut_value,
    independent_set_violations,
    problem_value,
)
from repro.core.partition import connectivity_preserving_partition, split_linear
from repro.kernels import ops
from repro.kernels import ref
from repro.service import SLA, ServiceConfig, SolveService
from repro.service.canonical import canonical_key
from repro.service.workload import problem_mix, relabel_problem


def _random_problem(n, p, seed, offset=0.0):
    rng = np.random.default_rng(seed)
    e = np.array(
        [(i, j) for i in range(n) for j in range(i + 1, n)
         if rng.random() < p],
        dtype=np.int32,
    ).reshape(-1, 2)
    q = rng.normal(size=e.shape[0]).astype(np.float32)
    h = rng.normal(size=n).astype(np.float32)
    return Problem.qubo(n, e, q, linear=h, offset=offset)


def _exhaustive_cfg(n_qubits: int) -> ParaQAOAConfig:
    """top_k = 2^n makes the merge frontier enumerate *every* assignment,
    so the solve is exact whenever the (uncapped) beam is exhaustive."""
    return ParaQAOAConfig(
        n_qubits=n_qubits, top_k=1 << n_qubits, p_layers=2, opt_steps=5,
        beam_cap=1 << 22,
    )


# ------------------------------------------------------------- kernels --
def test_cutvals_linear_semantics():
    """cutvals(..., linear) == quadratic cut + bits @ linear over every
    basis state, for the reference and Pallas-interpret kernels alike."""
    n = 6
    g = Graph.erdos_renyi_weighted(n, 0.5, seed=0)
    lin = np.linspace(-1.0, 1.5, n).astype(np.float32)
    idx = np.arange(1 << n)
    bits = ((idx[:, None] >> np.arange(n)) & 1).astype(np.float32)
    want = np.asarray(ref.cutvals(n, g.edges, g.weights)) + bits @ lin

    got_ref = np.asarray(ref.cutvals(n, g.edges, g.weights, jnp.asarray(lin)))
    np.testing.assert_allclose(got_ref, want, atol=1e-5)

    from repro.kernels import cutvals as kcut

    got_pl = np.asarray(
        kcut.cutvals(n, g.edges, g.weights, jnp.asarray(lin), interpret=True)
    )
    np.testing.assert_array_equal(got_pl, got_ref)

    sub = jnp.asarray([0, 3, 17, 63], jnp.int32)
    got_at = np.asarray(ref.cutvals_at(sub, g.edges, g.weights, jnp.asarray(lin)))
    np.testing.assert_allclose(got_at, want[np.asarray(sub)], atol=1e-5)


def test_cutvals_linear_grads():
    """The custom-vjp rules: d_weights[e] = <g, xor_e>, d_linear[v] =
    <g, bit_v> — checked against dense cotangent expectations."""
    n = 5
    g = Graph.erdos_renyi(n, 0.6, seed=1)
    lin = jnp.asarray(np.random.default_rng(2).normal(size=n), jnp.float32)
    ct = jnp.asarray(np.random.default_rng(3).normal(size=1 << n), jnp.float32)

    def loss(w, l):
        return jnp.vdot(ct, ops.cutvals(n, g.edges, w, l))

    d_w, d_l = jax.grad(loss, argnums=(0, 1))(g.weights, lin)

    e = np.asarray(g.edges)
    idx = np.arange(1 << n)
    crossed = (((idx[:, None] >> e[None, :, 0]) ^ (idx[:, None] >> e[None, :, 1])) & 1)
    want_w = np.asarray(ct) @ crossed.astype(np.float32)
    bits = ((idx[:, None] >> np.arange(n)) & 1).astype(np.float32)
    want_l = np.asarray(ct) @ bits
    np.testing.assert_allclose(np.asarray(d_w), want_w, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d_l), want_l, rtol=1e-5, atol=1e-4)

    # the linear=None path keeps its own vjp (no d_linear cotangent)
    d_w0 = jax.grad(lambda w: jnp.vdot(ct, ops.cutvals(n, g.edges, w)))(g.weights)
    np.testing.assert_allclose(np.asarray(d_w0), want_w, rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------ encodings --
def test_qubo_matches_dense_evaluation():
    """problem_value == x^T Q x (upper-tri) + h @ x + c for random x."""
    n = 9
    prob = _random_problem(n, 0.5, seed=4, offset=-2.5)
    rng = np.random.default_rng(5)
    # reconstruct the dense QUBO this problem was built from
    rng2 = np.random.default_rng(4)
    edges = np.array(
        [(i, j) for i in range(n) for j in range(i + 1, n)
         if rng2.random() < 0.5],
        dtype=np.int32,
    )
    q = rng2.normal(size=edges.shape[0]).astype(np.float32)
    h = rng2.normal(size=n).astype(np.float32)
    for _ in range(16):
        x = rng.integers(0, 2, size=n).astype(np.float64)
        want = float(
            sum(qq * x[i] * x[j] for (i, j), qq in zip(edges, q))
            + h @ x - 2.5
        )
        got = float(problem_value(prob, jnp.asarray(x.astype(np.int8))))
        assert abs(got - want) < 1e-4, (got, want)


def test_mis_penalty_encoding_requires_penalty_ge_2():
    g = Graph.erdos_renyi(6, 0.5, seed=6)
    with pytest.raises(ValueError):
        Problem.mis(g, penalty=1.5)


def test_brute_force_problem_matches_maxcut_oracle():
    """On a zero-linear problem the full-enumeration oracle agrees with
    the bit0=0 symmetry-exploiting Max-Cut oracle."""
    g = Graph.erdos_renyi_weighted(10, 0.4, seed=7)
    _, v_mc, _ = brute_force_maxcut(g)
    _, v_pr, _ = brute_force_problem(g)
    assert abs(v_mc - v_pr) < 1e-4, (v_mc, v_pr)


# ------------------------------------------------- end-to-end small-n --
def test_qubo_solve_matches_brute_force():
    """Exhaustive-merge solve of a random QUBO (n <= 12) lands exactly on
    the brute-force optimum — linear terms thread partition → oracle →
    merge correctly, including the broken flip symmetry."""
    prob = _random_problem(11, 0.4, seed=8, offset=1.25)
    _, opt, _ = brute_force_problem(prob)
    out = solve(prob, _exhaustive_cfg(6))
    assert abs(out.cut_value - opt) < 1e-3, (out.cut_value, opt)
    assert abs(
        float(problem_value(prob, jnp.asarray(out.assignment))) - opt
    ) < 1e-3


def test_mis_solve_valid_and_optimal():
    """Penalty-QUBO MIS on small graphs: the solved set is independent
    and its size equals the brute-force maximum independent set."""
    for seed in (9, 10):
        g = Graph.erdos_renyi(12, 0.3, seed=seed)
        prob = Problem.mis(g)
        _, opt, _ = brute_force_problem(prob)
        out = solve(prob, _exhaustive_cfg(6))
        assert independent_set_violations(g, out.assignment) == 0
        assert abs(out.cut_value - opt) < 1e-3, (seed, out.cut_value, opt)
        assert int(np.sum(out.assignment)) == int(round(opt))


def test_zero_linear_problem_bit_identical_to_graph_solve():
    """Problem.maxcut(g) must follow the exact zero-linear special case:
    bit-identical assignment and cut to solving the plain Graph."""
    g = Graph.erdos_renyi(30, 0.25, seed=11)
    cfg = ParaQAOAConfig(n_qubits=7, top_k=2, p_layers=2, opt_steps=10)
    a = solve(g, cfg)
    b = solve(Problem.maxcut(g), cfg)
    assert a.cut_value == b.cut_value
    np.testing.assert_array_equal(a.assignment, b.assignment)


def test_split_linear_covers_each_vertex_once():
    """Every vertex's h lands in exactly one subproblem (first coverage);
    shared boundary vertices see h = 0 in later ranges."""
    g = Graph.erdos_renyi(23, 0.3, seed=12)
    part = connectivity_preserving_partition(g, 4)
    lin = np.arange(1, g.n + 1, dtype=np.float32)
    subs = split_linear(part, lin)
    recovered = np.zeros(g.n, dtype=np.float64)
    for (lo, hi), li in zip(part.ranges, subs):
        assert li.shape == (hi - lo,)
        recovered[lo:hi] += li
    np.testing.assert_allclose(recovered, lin)


# -------------------------------------------------------- canonical key --
def test_canonical_linear_distinct_qubos_do_not_collide():
    prob = _random_problem(10, 0.4, seed=13)
    h2 = np.asarray(prob.linear).copy()
    h2[3] += 0.5
    other = dataclasses.replace(prob, linear=jnp.asarray(h2))
    assert canonical_key(prob) != canonical_key(other)


def test_canonical_relabeled_qubo_collides():
    prob = _random_problem(10, 0.4, seed=14)
    perm = np.random.default_rng(15).permutation(prob.n).astype(np.int32)
    assert canonical_key(prob) == canonical_key(relabel_problem(prob, perm))


def test_canonical_zero_linear_problem_matches_graph_key():
    """The zero-linear path appends nothing to the certificate: a plain
    Graph and its Problem.maxcut wrapper hash byte-identically."""
    g = Graph.erdos_renyi_weighted(14, 0.4, seed=16)
    assert canonical_key(g) == canonical_key(Problem.maxcut(g))


# ------------------------------------------------------------- service --
@pytest.mark.parametrize("weights", ["uniform", "spin"])
def test_weighted_service_bit_identical_to_solo_solve(weights):
    """The §6.1 parity contract on *weighted* instances, alongside the
    unweighted one in test_service.py."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8,
                                     enable_cache=False))
    gen = (Graph.erdos_renyi_weighted if weights == "uniform"
           else Graph.spin_glass)
    graphs = [gen(n, 0.3, seed=s) for s, n in enumerate((18, 25, 21))]
    rids = [svc.submit(g, SLA(deadline_s=30.0)) for g in graphs]
    res = svc.drain()
    for g, rid in zip(graphs, rids):
        r = res[rid]
        solo = solve(g, r.plan.to_config())
        assert r.cut_value == solo.cut_value, (rid, r.cut_value, solo.cut_value)
        np.testing.assert_array_equal(r.assignment, solo.assignment)


def test_qubo_service_bit_identical_to_solo_solve():
    """A QUBO request served through `SolveService` is bit-identical to
    solo `core.solve` on the same problem (acceptance criterion)."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8,
                                     enable_cache=False))
    probs = [_random_problem(n, 0.3, seed=20 + n, offset=0.5)
             for n in (18, 26)]
    probs.append(Problem.mis(Graph.erdos_renyi(22, 0.2, seed=21)))
    rids = [svc.submit(p, SLA(deadline_s=30.0)) for p in probs]
    res = svc.drain()
    for p, rid in zip(probs, rids):
        r = res[rid]
        solo = solve(p, r.plan.to_config())
        assert r.cut_value == solo.cut_value, (rid, r.cut_value, solo.cut_value)
        np.testing.assert_array_equal(r.assignment, solo.assignment)


def test_service_cache_separates_linear_terms():
    """Same quadratic, different linear terms → distinct keys (no false
    hit); a *relabeled* copy of the same QUBO hits."""
    svc = SolveService(ServiceConfig(batch_slots=4, max_qubits=8))
    prob = _random_problem(20, 0.3, seed=22)
    rid0 = svc.submit(prob)
    svc.drain()
    assert not svc.results[rid0].cached

    h2 = np.asarray(prob.linear).copy()
    h2[0] += 1.0
    rid1 = svc.submit(dataclasses.replace(prob, linear=jnp.asarray(h2)))
    svc.drain()
    assert not svc.results[rid1].cached

    perm = np.random.default_rng(23).permutation(prob.n).astype(np.int32)
    rid2 = svc.submit(relabel_problem(prob, perm))
    svc.drain()
    r2 = svc.results[rid2]
    assert r2.cached
    assert r2.cut_value == pytest.approx(svc.results[rid0].cut_value)


def test_problem_mix_families():
    probs = problem_mix(6, (10, 14), 0.3, 0.3, seed=24, problem="mis")
    assert all(isinstance(p, Problem) and p.kind == "mis" for p in probs)
    probs = problem_mix(6, (10, 14), 0.3, 0.3, seed=24, problem="qubo",
                        weights="spin")
    assert all(p.kind == "qubo" for p in probs)
    graphs = problem_mix(4, (10, 14), 0.3, 0.0, seed=24, weights="uniform")
    assert all(isinstance(g, Graph) for g in graphs)


# -------------------------------------------------------- local search --
def test_refine_rescore_no_drift():
    """The returned value is a from-scratch re-score of the final
    assignment: on a weighted instance with hundreds of accepted flips it
    must equal cut_value(graph, assignment) *exactly* (the old
    scan-accumulated carry drifted in float32)."""
    g = Graph.erdos_renyi_weighted(120, 0.2, seed=25, low=0.01, high=3.0)
    a0 = np.zeros(g.n, dtype=np.int8)
    a, v = refine(g, a0, steps=400)
    assert v == float(cut_value(g, jnp.asarray(a))), (
        v, float(cut_value(g, jnp.asarray(a)))
    )


def test_refine_relative_epsilon_accepts_tiny_weights():
    """Uniformly tiny weights: every real improvement is < the old
    absolute 1e-6 threshold; the relative epsilon must still accept."""
    n = 6
    e = np.array([[0, i] for i in range(1, n)], dtype=np.int32)  # star
    w = np.full(n - 1, 1e-8, dtype=np.float32)
    g = Graph.from_edges(n, e, w)
    a0 = np.zeros(n, dtype=np.int8)  # cut 0; flipping the hub gains 5e-8
    a, v = refine(g, a0, steps=5)
    assert v > 0.0, "relative epsilon rejected a real improvement"
    assert v == pytest.approx(5e-8, rel=1e-3)


def test_refine_with_linear_clears_mis_violations():
    """Dropping a violating vertex gains >= penalty - 1 > 0, so the
    linear-aware 1-flip refinement drives violations to zero."""
    g = Graph.erdos_renyi(30, 0.25, seed=26)
    prob = Problem.mis(g, penalty=2.0)
    a0 = np.ones(g.n, dtype=np.int8)  # everything selected: maximally bad
    a, v = refine(prob.graph, a0, steps=120, linear=prob.linear)
    assert independent_set_violations(g, a) == 0
    assert v == pytest.approx(
        float(problem_value(prob, jnp.asarray(a))) - prob.offset
    )


def test_refine_improves_qubo_objective():
    prob = _random_problem(40, 0.2, seed=27)
    a0 = np.zeros(prob.n, dtype=np.int8)
    v0 = float(problem_value(prob, jnp.asarray(a0)))
    _, v = refine(prob.graph, a0, steps=80, linear=prob.linear)
    assert v >= v0 - 1e-6

"""CLI mesh-spec parsing (launch/mesh.py) — pure string processing, so
these run without any device emulation."""

import pytest

from repro.launch.mesh import mesh_spec_size, parse_mesh_spec
from repro.launch.solve_maxcut import build_parser


def test_parse_basic_specs():
    assert parse_mesh_spec("data=2") == {"data": 2}
    assert parse_mesh_spec("data=2,model=4") == {"data": 2, "model": 4}
    assert parse_mesh_spec(" data = 2 , model = 4 ") == {"data": 2, "model": 4}
    assert mesh_spec_size({"pod": 2, "data": 3, "model": 4}) == 24


def test_parse_normalizes_axis_order():
    # canonical (pod, data, model) order regardless of flag spelling
    spec = parse_mesh_spec("model=4,data=2,pod=2")
    assert list(spec) == ["pod", "data", "model"]


@pytest.mark.parametrize(
    "bad",
    [
        "",
        "   ",
        "data",  # missing =
        "data=",  # missing size
        "data=x",  # non-integer
        "data=2.5",  # non-integer
        "data=0",  # non-positive
        "data=-2",
        "batch=2",  # unknown axis
        "data=2,data=4",  # duplicate axis
        "model=3",  # model must be a power of two
        "model=6",
        "data=2,,model=4",  # empty entry
    ],
)
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_mesh_spec(bad)


def test_parse_accepts_power_of_two_model():
    for m in (1, 2, 4, 8, 16):
        assert parse_mesh_spec(f"model={m}")["model"] == m


def test_solver_cli_exposes_mesh_flags():
    args = build_parser().parse_args(
        ["--n", "100", "--mesh", "data=2,model=4", "--schedule", "faithful",
         "--merge", "striped"]
    )
    assert args.mesh == "data=2,model=4"
    assert args.schedule == "faithful"
    assert args.merge_mode == "striped"
    # every registered flag carries help text (the --help audit)
    for action in build_parser()._actions:
        assert action.help, f"flag {action.option_strings} has no help text"


def test_striped_beam_width_covers_presplit_frontier():
    """Regression: the width must cover the full 2·K^split pre-split
    frontier (it once used 2·K^(split-1), pruning partial-score rows)."""
    from repro.core.merge import exact_beam_width, striped_beam_width

    for k, m, n, sl in [(2, 5, 8, 2), (2, 6, 4, 2), (3, 4, 2, 3), (2, 7, 4, 1)]:
        w = striped_beam_width(k, m, n, sl)
        assert w is not None
        assert w >= 2 * k ** min(sl, m - 1)
        assert w <= exact_beam_width(k, m)  # never wider than one device
    # heuristic regime: exhaustive sweep over the cap → None
    assert striped_beam_width(2, 45, 2, 1, cap=1 << 18) is None


def test_solver_cli_rejects_malformed_mesh():
    from repro.launch import solve_maxcut

    with pytest.raises(ValueError):
        solve_maxcut.run(["--n", "16", "--mesh", "data=two"])
    with pytest.raises(ValueError):
        solve_maxcut.run(["--n", "16", "--mesh", "rows=4"])

"""results/BENCH_*.json schema validation: every committed benchmark file
must carry the envelope documented in docs/EXPERIMENTS.md §Schema, so
benchmark writers can't silently drift from it. Pure JSON checking — no
jax import."""

import json
import re
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parent.parent / "results"

EXPECTED_FILES = {
    "BENCH_schedules.json",
    "BENCH_distributed.json",
    "BENCH_obs.json",
    "BENCH_service.json",
    "BENCH_service_mesh.json",
    "BENCH_service_sla.json",
    "BENCH_sharded_engine.json",
    "BENCH_kernel_autotune.json",
}

ENVELOPE_KEYS = {"suite", "jax_version", "backend", "device_count", "rows"}

_DERIVED = re.compile(r"^[\w.+-]+=[^;]*(;[\w.+-]+=[^;]*)*$")


def bench_files():
    return sorted(RESULTS.glob("BENCH_*.json"))


def test_expected_bench_files_committed():
    names = {p.name for p in bench_files()}
    missing = EXPECTED_FILES - names
    assert not missing, f"missing committed benchmark files: {missing}"


@pytest.mark.parametrize("path", bench_files(), ids=lambda p: p.name)
def test_envelope(path):
    payload = json.loads(path.read_text())
    assert ENVELOPE_KEYS <= set(payload), (
        f"{path.name}: missing envelope keys {ENVELOPE_KEYS - set(payload)}"
    )
    assert path.name == f"BENCH_{payload['suite']}.json"
    assert isinstance(payload["jax_version"], str) and payload["jax_version"]
    assert isinstance(payload["backend"], str) and payload["backend"]
    assert isinstance(payload["device_count"], int)
    assert payload["device_count"] >= 1
    assert isinstance(payload["rows"], list) and payload["rows"]


@pytest.mark.parametrize("path", bench_files(), ids=lambda p: p.name)
def test_rows(path):
    payload = json.loads(path.read_text())
    suite = payload["suite"]
    for i, row in enumerate(payload["rows"]):
        where = f"{path.name} rows[{i}]"
        assert isinstance(row, dict), where
        assert isinstance(row.get("name"), str), where
        # `name` is `<suite>/<case>` (EXPERIMENTS.md §Schema)
        assert row["name"].startswith(f"{suite}/"), (
            f"{where}: name {row['name']!r} must start with '{suite}/'"
        )
        assert isinstance(row.get("runtime_s"), (int, float)), where
        assert row["runtime_s"] >= 0, where
        # `derived` is a `;`-separated `k=v` string
        derived = row.get("derived", "")
        assert isinstance(derived, str), where
        if derived:
            assert _DERIVED.match(derived), (
                f"{where}: derived {derived!r} is not ';'-separated k=v"
            )


def test_sharded_engine_rows_carry_quality_claim():
    """The engine suite must record the fused-vs-unfused layer pair and
    the opt-vs-ramp quality row with its ⟨cut⟩_opt >= ⟨cut⟩_ramp claim
    (the sharded-ascent acceptance criterion, DESIGN.md §2.6)."""
    path = RESULTS / "BENCH_sharded_engine.json"
    payload = json.loads(path.read_text())
    names = {r["name"] for r in payload["rows"]}
    assert any(n.startswith("sharded_engine/layer_fused_") for n in names)
    assert any(n.startswith("sharded_engine/layer_unfused_") for n in names)
    quality = [r for r in payload["rows"] if "opt_ge_ramp" in r]
    assert quality, "missing sharded_engine/opt_vs_ramp_* row"
    for row in quality:
        assert row["opt_ge_ramp"] is True
        derived = dict(kv.split("=") for kv in row["derived"].split(";"))
        assert float(derived["exp_opt"]) >= float(derived["exp_ramp"])


def test_service_rows_carry_load_metrics():
    """The service suite's mode rows must record the load-curve fields the
    EXPERIMENTS.md §Schema entry documents."""
    path = RESULTS / "BENCH_service.json"
    payload = json.loads(path.read_text())
    modes = [r for r in payload["rows"] if "mode" in r]
    assert {r["mode"] for r in modes} == {"sequential", "batched"}
    for row in modes:
        for key in ("load", "throughput_rps", "p50_s", "p99_s"):
            assert key in row, f"{row['name']}: missing {key}"
    batched = [r for r in modes if r["mode"] == "batched"]
    assert all("cache_hit_ratio" in r and "fill_ratio" in r for r in batched)
    speedups = [r for r in payload["rows"] if "speedup" in r]
    assert speedups, "missing service/speedup_* summary rows"
    # the §6.1 amortization claim, as committed: >= 1.5x at >= 4 concurrent
    big = [r for r in speedups if r["load"] >= 4]
    assert big and all(r["speedup"] >= 1.5 for r in big), speedups
    assert all(r["cut_equal"] for r in speedups)


def test_service_sla_rows_carry_attainment_claims():
    """The §6.6 suite (§Perf C9) must chart attainment/shed/downgrade/p99
    against >= 3 offered-load points, each row carrying the
    `attainment_ge_threshold` claim column and exact per-tenant
    terminal-state accounting — and the claim must hold at the calibrated
    (lowest offered load) point."""
    path = RESULTS / "BENCH_service_sla.json"
    payload = json.loads(path.read_text())
    rows = [r for r in payload["rows"] if r.get("mode") == "sla_soak"]
    assert len(rows) >= 3, "need >= 3 offered-load points"
    assert len({r["offered_rps"] for r in rows}) >= 3
    for row in rows:
        for key in ("offered_rps", "attainment", "shed_rate", "expired_rate",
                    "downgrade_rate", "p50_s", "p99_s",
                    "attainment_threshold", "attainment_ge_threshold",
                    "calibrated", "tenants"):
            assert key in row, f"{row['name']}: missing {key}"
        assert 0.0 <= row["attainment"] <= 1.0, row["name"]
        assert isinstance(row["attainment_ge_threshold"], bool), row["name"]
        # terminal accounting is exact: completed+shed+expired == offered,
        # globally and per tenant (summing to the global buckets)
        assert row["completed"] + row["shed"] + row["expired"] == row["load"]
        for field in ("completed", "shed", "expired", "sla_met", "sla_missed"):
            total = sum(t[field] for t in row["tenants"].values())
            if field in row:
                assert total == row[field], f"{row['name']}: {field}"
        for t in row["tenants"].values():
            assert t["completed"] + t["shed"] + t["expired"] == t["submitted"]
    calibrated = [r for r in rows if r["calibrated"]]
    assert calibrated, "missing the calibrated (lowest-load) row"
    lowest = min(rows, key=lambda r: r["offered_rps"])
    assert lowest["calibrated"] is True
    for row in calibrated:
        assert row["attainment_ge_threshold"] is True, (
            f"{row['name']}: attainment {row['attainment']} below "
            f"threshold {row['attainment_threshold']} at the calibrated load"
        )


def test_obs_rows_carry_overhead_and_ledger_claims():
    """The §8 suite (§Perf C10) must commit the tracing-overhead claim —
    a traced virtual soak within `overhead_bound` (5%) of the untraced
    one — and the compile-ledger cold/warm contract: the cold soak bills
    at least one program build, the warm re-run records zero."""
    path = RESULTS / "BENCH_obs.json"
    payload = json.loads(path.read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    for name in ("obs/soak_off", "obs/soak_on", "obs/overhead",
                 "obs/compile_ledger"):
        assert name in rows, f"missing {name}"
    assert rows["obs/soak_on"]["spans"] > 0
    ov = rows["obs/overhead"]
    for key in ("overhead_ratio", "overhead_bound", "within_bound"):
        assert key in ov, f"obs/overhead: missing {key}"
    assert ov["overhead_bound"] <= 1.05
    assert ov["within_bound"] is True, (
        f"tracing overhead {ov['overhead_ratio']} exceeds the committed "
        f"bound {ov['overhead_bound']}"
    )
    led = rows["obs/compile_ledger"]
    assert led["cold_builds"] >= 1, "cold soak billed no program builds"
    assert led["warm_builds"] == 0 and led["warm_compiles"] == 0
    assert led["warm_zero"] is True


def test_service_mesh_rows_carry_parity_and_async_claims():
    """The §6.5 suite must record the backend parity contract and the
    async-admission acceptance claim: cuts bit-identical across backends
    (and to solo `core.solve`) on every parity row, the mesh rows run on
    a real multi-device mesh, and the async loop sustains >= the
    synchronous (max_inflight=1) throughput at 8 concurrent requests."""
    path = RESULTS / "BENCH_service_mesh.json"
    payload = json.loads(path.read_text())
    modes = [r for r in payload["rows"] if "mode" in r]
    assert {r["mode"] for r in modes} == {"local", "mesh"}
    for row in modes:
        for key in ("load", "throughput_rps", "p50_s", "p99_s", "devices"):
            assert key in row, f"{row['name']}: missing {key}"
    mesh_rows = [r for r in modes if r["mode"] == "mesh"]
    assert all(r["devices"] >= 2 for r in mesh_rows), mesh_rows
    parity = [r for r in payload["rows"] if "cut_equal" in r]
    assert parity, "missing service_mesh/parity_* rows"
    assert all(r["cut_equal"] for r in parity), parity
    async_rows = [r for r in payload["rows"] if "async_over_sync" in r]
    assert async_rows, "missing service_mesh/async_vs_sync_* row"
    for row in async_rows:
        assert row["load"] >= 8, row
        assert row["async_ge_sync"] is True, row
        assert row["async_over_sync"] >= 1.0, row


def test_kernel_autotune_rows_carry_speedup_claims():
    """The §Perf C11 suite must record, per (op, shape-bucket): the tuned
    config, a tuned-vs-default speedup that can never fall below 1.0 (the
    default is in every candidate set), and the roofline achieved-vs-peak
    column; the mixer relayout-fusion rows must show the fused strided
    kernel no slower than the moveaxis path; the summary row carries the
    suite-level tuned_ge_default claim."""
    path = RESULTS / "BENCH_kernel_autotune.json"
    payload = json.loads(path.read_text())
    swept = [r for r in payload["rows"] if "speedup_vs_default" in r]
    assert swept, "missing per-op sweep rows"
    for row in swept:
        assert row["speedup_vs_default"] >= 1.0, row["name"]
        assert isinstance(row["config"], dict) and row["config"], row["name"]
        assert row["model_bound_s"] > 0, row["name"]
        assert 0 < row["achieved_frac"], row["name"]
        assert row["mode"] in ("pallas", "pallas_interpret"), row["name"]
    relayout = [r for r in payload["rows"] if "relayout_speedup" in r]
    assert relayout, "missing kernel_autotune/mixer_relayout_* rows"
    for row in relayout:
        assert row["fused_ge_unfused"] is True, row["name"]
        assert row["relayout_speedup"] >= 1.0, row["name"]
    summary = [r for r in payload["rows"] if "tuned_ge_default" in r]
    assert len(summary) == 1, "missing kernel_autotune/tuned_vs_default row"
    assert summary[0]["tuned_ge_default"] is True
    assert summary[0]["mean_speedup"] >= 1.0
    assert summary[0]["ops_swept"] == len(swept)


def test_kernel_autotune_agrees_with_committed_tuning_cache():
    """The committed trace-time tuning table must be exactly the winning
    configs the committed bench recorded (same backend, same winners) —
    the cache is a measurement artifact, not hand-edited."""
    bench = json.loads((RESULTS / "BENCH_kernel_autotune.json").read_text())
    cache_path = (
        RESULTS.parent / "src" / "repro" / "kernels" / "tuning_cache.json"
    )
    cache = json.loads(cache_path.read_text())
    assert cache["backend"] == bench["backend"]
    entries = cache["entries"]
    swept = [r for r in bench["rows"] if "speedup_vs_default" in r]
    assert len(entries) == len(swept)
    for row in swept:
        key = f"{row['op']}|{row['bucket']}|{bench['backend']}"
        assert key in entries, key
        assert entries[key] == row["config"], key

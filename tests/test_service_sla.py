"""Property soak of §6.6 deadline-aware SLA enforcement (DESIGN.md §6.6).

Randomized open-loop arrival traces (`workload.arrival_trace`: Poisson
base rates, burst episodes, skewed tenants, per-request deadline and
accuracy-floor mixes) replay against a `SolveService` under an injected
`workload.VirtualClock`, and every enforcement invariant must hold:

  - completion-or-shed: every submitted request reaches *exactly one*
    terminal state (completed / shed / expired), globally and per tenant;
  - shed only with evidence: a shed verdict records the floor plan's
    predicted time exceeding the residual budget at admission;
  - downgrades never violate the declared `SLA.floor_quality`, and a
    downgraded request's served cut is bit-identical to solo `core.solve`
    at the downgraded knobs;
  - virtual-clock replay is bit-deterministic: same trace + config →
    identical statuses, cuts, assignments, latencies, and stats;
  - attainment is monotone (non-increasing) in offered load at fixed
    capacity — same seed, scaled arrival times, same requests;
  - the CI headline: a 2,000-request open-loop soak at the calibrated
    load completes with zero deadline misses among non-shed requests.

The soak planner uses a compact single-qubit-budget grid and an inflated
`CostModel` so predicted costs span the virtual deadline mix — verdict
dynamics under a virtual clock are a pure function of the model and the
tick pacing, not of host compute. Runs under real Hypothesis when
installed, else the vendored tests/_propshim.py shim."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import solve
from repro.core.graph import Graph
from repro.service import (
    SLA,
    CostModel,
    KnobTuple,
    Planner,
    ServiceConfig,
    SolveService,
    VirtualClock,
    arrival_trace,
    run_soak_virtual,
)

# compact lattice: one qubit budget, opt_steps/top_k/beam spread quality
# and predicted cost without exploding the compiled-shape space
SOAK_GRID = tuple(
    KnobTuple(n_qubits=6, top_k=k, opt_steps=t, beam_width=w)
    for k in (1, 2)
    for t in (4, 12, 30)
    for w in (16, 64)
)
FLOOR_Q = 7.0  # met by every opt_steps>=4 tuple except none — mid-lattice

TERMINAL = ("completed", "shed", "expired")


def _soak_cost_model(batch_slots: int) -> CostModel:
    """Inflated coefficients: predicted totals span ~0.06-0.3 virtual s,
    the same order as the virtual deadline mixes below, so keep /
    downgrade / shed verdicts all occur."""
    return CostModel(c_solve=3e-5, c_dispatch=2e-2, c_merge=5e-8,
                     c_merge_base=1e-3, batch_slots=batch_slots)


def _soak_service(slots=4, inflight=1, recalibrate=False):
    clock = VirtualClock()
    planner = Planner(cost_model=_soak_cost_model(slots), grid=SOAK_GRID,
                      batch_slots=slots)
    svc = SolveService(
        ServiceConfig(batch_slots=slots, max_qubits=6,
                      recalibrate=recalibrate, max_inflight=inflight),
        planner=planner,
        clock=clock,
    )
    return svc, clock


def _run(svc, clock, trace, tick_s=0.02):
    rids = run_soak_virtual(svc, clock, trace, tick_s=tick_s)
    assert len(rids) == len(trace)
    return rids


def _check_terminal_accounting(svc, trace, rids):
    """The completion-or-shed contract plus exact stats accounting."""
    st_ = svc.stats
    load = len(trace)
    assert set(rids) == set(svc.results)
    counts = {s: 0 for s in TERMINAL}
    for a, rid in zip(trace, rids):
        r = svc.results[rid]
        assert r.status in TERMINAL, r.status
        counts[r.status] += 1
        assert r.tenant == a.tenant
        if r.status == "completed":
            assert r.assignment is not None and np.isfinite(r.cut_value)
            if a.floor_quality is not None:
                # downgrades never violate the declared accuracy floor
                assert r.plan.quality >= a.floor_quality - 1e-9, (
                    rid, r.downgrades, r.plan.knobs
                )
        else:
            assert r.assignment is None and np.isnan(r.cut_value)
            assert r.deadline_met is False
            if r.status == "shed":
                # shed only when the floor plan was predicted late
                assert r.timings["predicted_floor_s"] > r.timings["budget_s"]
    assert counts["completed"] == st_.completed
    assert counts["shed"] == st_.shed
    assert counts["expired"] == st_.expired
    assert st_.terminal == load
    assert 0.0 <= st_.attainment <= 1.0
    assert st_.downgraded <= st_.completed
    assert st_.downgraded <= st_.downgrade_events
    # per-tenant accounting sums to the global totals, and each tenant's
    # buckets partition its own submissions
    for field in ("submitted", "completed", "shed", "expired", "sla_met",
                  "sla_missed", "downgraded"):
        total = sum(getattr(t, field) for t in st_.tenants.values())
        ref = load if field == "submitted" else getattr(st_, field)
        assert total == ref, (field, total, ref)
    for t in st_.tenants.values():
        assert t.terminal == t.submitted


@given(
    seed=st.integers(0, 10**6),
    load=st.integers(10, 18),
    rate=st.sampled_from([60.0, 250.0]),
    slots=st.sampled_from([4, 8]),
    inflight=st.integers(1, 2),
    tenants=st.integers(1, 3),
    repeat=st.floats(0.0, 0.5),
)
@settings(max_examples=4, deadline=None)
def test_soak_terminal_and_floor_invariants(
    seed, load, rate, slots, inflight, tenants, repeat
):
    svc, clock = _soak_service(slots=slots, inflight=inflight)
    trace = arrival_trace(
        load, rate_rps=rate, n_range=(5, 9), p=0.5, seed=seed,
        repeat_frac=repeat, tenants=tenants,
        deadline_choices=(0.1, 0.35, 1.5), floor_choices=(None, FLOOR_Q),
    )
    rids = _run(svc, clock, trace)
    _check_terminal_accounting(svc, trace, rids)


@given(
    seed=st.integers(0, 10**6),
    load=st.integers(8, 14),
    rate=st.sampled_from([120.0, 400.0]),
)
@settings(max_examples=3, deadline=None)
def test_virtual_replay_is_bit_deterministic(seed, load, rate):
    runs = []
    for _ in range(2):
        svc, clock = _soak_service(slots=4, inflight=1)
        trace = arrival_trace(
            load, rate_rps=rate, n_range=(5, 9), p=0.5, seed=seed,
            tenants=2, deadline_choices=(0.1, 0.35, 1.5),
            floor_choices=(None, FLOOR_Q),
        )
        rids = _run(svc, clock, trace)
        runs.append((svc, rids))
    (a_svc, a_rids), (b_svc, b_rids) = runs
    assert a_rids == b_rids
    for rid in a_rids:
        ra, rb = a_svc.results[rid], b_svc.results[rid]
        assert ra.status == rb.status
        assert ra.latency_s == rb.latency_s  # virtual stamps, exact
        assert ra.downgrades == rb.downgrades
        assert ra.deadline_met == rb.deadline_met
        if ra.status == "completed":
            assert ra.cut_value == rb.cut_value
            np.testing.assert_array_equal(ra.assignment, rb.assignment)
    assert a_svc.stats.as_dict() == b_svc.stats.as_dict()


def test_attainment_monotone_in_offered_load():
    """Same seed at different rates yields the *same* requests with
    scaled arrival times (workload.arrival_trace's unit-rate draws), so
    attainment against fixed capacity must not increase with load."""
    def attainment(rate):
        svc, clock = _soak_service(slots=4, inflight=1)
        trace = arrival_trace(
            40, rate_rps=rate, n_range=(5, 9), p=0.5, seed=3, tenants=2,
            deadline_choices=(0.1, 0.35, 1.5), floor_choices=(None, FLOOR_Q),
        )
        _run(svc, clock, trace)
        assert svc.stats.terminal == 40
        return svc.stats.attainment

    atts = [attainment(r) for r in (30.0, 120.0, 480.0)]
    assert atts[0] >= atts[1] >= atts[2], atts
    assert atts[0] > atts[2], "overload never degraded attainment"


def test_downgraded_request_parity_to_solo_solve():
    """A deadline downgrade re-plans to cheaper knobs before dispatch;
    the served cut must be bit-identical to solo `core.solve` at the
    *downgraded* knobs, and the downgrade must respect the floor."""
    svc, clock = _soak_service(slots=8, inflight=1)
    g = Graph.erdos_renyi(9, 0.5, seed=17)
    sla = SLA(deadline_s=1.0, floor_quality=FLOOR_Q)
    rid = svc.submit(g, sla, defer=False)  # admitted at the full budget
    req = svc._active[rid]
    rich_pred = req.plan.predicted.total_s
    floor = svc.planner.floor_predicted(g.n, g.n_edges, FLOOR_Q)
    assert floor[1].total_s < rich_pred, "needs a real downgrade gap"
    # burn budget until the admitted plan no longer fits but the floor
    # does — the next pump's re-score must downgrade, not expire
    clock.advance(1.0 - (rich_pred + floor[1].total_s) / 2.0)
    while svc.pump():
        clock.advance(0.001)
    r = svc.results[rid]
    assert r.status == "completed"
    assert r.downgrades >= 1
    assert svc.stats.downgrade_events >= 1
    assert svc.stats.downgraded == 1
    assert r.plan.quality >= FLOOR_Q - 1e-9
    assert r.plan.predicted.total_s < rich_pred
    solo = solve(g, r.plan.to_config())
    assert r.cut_value == solo.cut_value
    np.testing.assert_array_equal(r.assignment, solo.assignment)


def test_shed_request_lands_in_exactly_one_terminal_bucket():
    """Regression for the latent pre-§6.6 `ServiceStats` gap: stats were
    recorded only for completed requests. A shed request must appear in
    exactly one terminal bucket — shed — with the result, the global
    stats, and the tenant stats all agreeing."""
    svc, clock = _soak_service(slots=4)
    g = Graph.erdos_renyi(9, 0.5, seed=23)
    floor_s = svc.planner.floor_predicted(g.n, g.n_edges, None)[1].total_s
    rid = svc.submit(g, SLA(deadline_s=floor_s * 0.5), tenant="acme")
    r = svc.results[rid]
    assert r.status == "shed" and r.deadline_met is False
    st_ = svc.stats
    assert (st_.shed, st_.completed, st_.expired) == (1, 0, 0)
    assert st_.terminal == 1 and st_.attainment == 0.0
    ten = st_.tenants["acme"]
    assert (ten.shed, ten.completed, ten.expired, ten.submitted) == (1, 0, 0, 1)
    assert rid not in svc._active, "shed request left active"
    assert not svc.pump(), "shed request left queued work"


def test_open_loop_soak_2000_requests_calibrated():
    """The acceptance headline: a seeded 2,000-request open-loop soak at
    the calibrated load (offered rate well under virtual capacity) —
    every request reaches exactly one terminal state and there are zero
    deadline misses among non-shed requests."""
    svc, clock = _soak_service(slots=16, inflight=2)
    trace = arrival_trace(
        2000, rate_rps=150.0, n_range=(4, 6), p=0.5, seed=42,
        repeat_frac=0.5, tenants=3, deadline_choices=(1.0, 4.0),
        floor_choices=(None, FLOOR_Q),
    )
    rids = _run(svc, clock, trace)
    _check_terminal_accounting(svc, trace, rids)
    st_ = svc.stats
    assert st_.terminal == 2000
    # calibrated load: nothing missed, nothing dropped
    assert st_.sla_missed == 0
    assert st_.expired == 0
    assert st_.shed == 0
    assert st_.attainment == 1.0

"""Observability layer tests (DESIGN.md §8): span tracer, metrics
registry, compile ledger, and the service-level trace invariants.

The trace invariants mirror the §6.6 soak scaffolding from
tests/test_service_sla.py: seeded open-loop arrival traces replay under
an injected `VirtualClock`, with a recording `Tracer` sharing the same
clock. The contract under test:

  - spans nest: every child interval is contained in its parent's;
  - every submitted request yields exactly one terminal "request" span
    whose `status` attr matches its `RequestResult.status`;
  - tracing is observation-only: a virtual-clock soak with tracing on is
    bit-deterministic (two identical runs → byte-identical JSONL), and
    statuses/cuts match an untraced run of the same trace;
  - the compile ledger records every program build / per-shape compile
    once, and a warm re-run after `reset()` records zero.
"""

import json

import pytest

from repro.obs import (
    CompileLedger,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Tracer,
    get_ledger,
    percentile,
    use_tracer,
)
from repro.obs import trace as trace_mod
from repro.obs.validate import (
    validate_metrics,
    validate_trace_jsonl,
    validate_trace_records,
)
from repro.service import (
    SLA,
    CostModel,
    KnobTuple,
    Planner,
    ServiceConfig,
    SolveService,
    VirtualClock,
    arrival_trace,
    run_soak_virtual,
)
from repro.service.scheduler import ServiceStats, TenantStats


# ------------------------------------------------------------------ tracer --
class FakeClock:
    """Deterministic test clock: each read advances by `step`."""

    def __init__(self, start=0.0, step=1.0):
        self.t = start
        self.step = step

    def __call__(self):
        t = self.t
        self.t += self.step
        return t


def test_tracer_nesting_ids_and_parents():
    tr = Tracer(clock=FakeClock(), record=True)
    with tr.span("outer") as outer:
        with tr.span("inner", k=1) as inner:
            pass
    assert (outer.span_id, inner.span_id) == (1, 2)
    assert outer.parent_id is None
    assert inner.parent_id == outer.span_id
    assert inner.attrs == {"k": 1}
    # containment: child interval inside parent interval
    assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
    assert validate_trace_records([s.as_dict() for s in tr.spans]) == []


def test_tracer_root_sentinel_escapes_ambient_stack():
    tr = Tracer(clock=FakeClock(), record=True)
    with tr.span("ambient"):
        root = tr.begin("request", parent=trace_mod.ROOT)
        tr.end(root)
    assert root.parent_id is None


def test_tracer_end_is_exactly_once_and_duration_guards():
    tr = Tracer(clock=FakeClock(), record=True)
    s = tr.begin("x")
    with pytest.raises(ValueError):
        s.duration_s  # noqa: B018 — still open
    tr.end(s)
    assert s.duration_s == 1.0
    with pytest.raises(ValueError):
        tr.end(s)


def test_tracer_record_off_keeps_timing_but_no_spans():
    tr = Tracer(clock=FakeClock())  # record=False is the default
    with tr.span("stage") as s:
        pass
    assert s.duration_s == 1.0  # timings still usable by callers
    assert tr.spans == []  # nothing retained


def test_tracer_span_at_is_retroactive():
    tr = Tracer(clock=FakeClock(), record=True)
    s = tr.span_at("solve", 5.0, 9.0, n_qubits=6)
    assert (s.t0, s.t1, s.duration_s) == (5.0, 9.0, 4.0)
    assert s.attrs["n_qubits"] == 6


def test_tracer_attach_reenters_open_span():
    tr = Tracer(clock=FakeClock(), record=True)
    ms = tr.begin("merge")
    with tr.attach(ms):
        with tr.span("merge_level", level=1) as lv:
            pass
    tr.end(ms)
    assert lv.parent_id == ms.span_id
    assert validate_trace_records([s.as_dict() for s in tr.spans]) == []


def test_tracer_jsonl_roundtrip_and_chrome_export(tmp_path):
    tr = Tracer(clock=FakeClock(), record=True)
    with tr.span("solve", n=10):
        with tr.span("partition"):
            pass
    text = tr.to_jsonl()
    assert validate_trace_jsonl(text) == []
    # byte-stable: same spans → same serialization
    assert text == tr.to_jsonl()

    p = tmp_path / "t.jsonl"
    tr.export(str(p), "jsonl")
    assert p.read_text().rstrip("\n") == text.rstrip("\n")

    c = tmp_path / "t.json"
    tr.export(str(c), "chrome")
    doc = json.loads(c.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == 2
    assert all(e["ph"] == "X" for e in evs)
    assert evs[0]["name"] == "solve" and evs[0]["args"]["n"] == 10


def test_use_tracer_swaps_the_ambient_tracer():
    tr = Tracer(clock=FakeClock(), record=True)
    before = trace_mod.get_tracer()
    with use_tracer(tr):
        assert trace_mod.get_tracer() is tr
        with trace_mod.get_tracer().span("stage"):
            pass
    assert trace_mod.get_tracer() is before
    assert [s.name for s in tr.spans] == ["stage"]


# ----------------------------------------------------------------- metrics --
def test_percentile_is_exact_nearest_rank():
    xs = [0.1, 0.2, 0.3, 0.4, 0.5]
    assert percentile(xs, 0.5) == 0.3
    assert percentile(xs, 0.99) == 0.5
    assert percentile(xs, 0.0) == 0.1
    assert percentile([7.0], 0.5) == 7.0
    assert percentile([], 0.5) == 0.0
    with pytest.raises(ValueError):
        percentile(xs, 1.5)


def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(2.5)
    assert g.value == 2.5


def test_histogram_summary_and_snapshot_roundtrip():
    h = Histogram()
    for v in (0.002, 0.002, 0.3, 1.5, 45.0, 120.0):  # last exceeds 60s
        h.observe(v)
    s = h.summary()
    assert s["count"] == 6
    assert s["p50"] == 0.3
    assert s["p99"] == 120.0
    # bucket counts are cumulative and end at the +inf catch-all
    cum = h.cumulative_counts()
    assert cum[-1] == 6

    h2 = Histogram.restore(h.snapshot())
    assert h2 == h
    assert h2.summary() == s
    # snapshots survive JSON (the "+inf" boundary must be encodable)
    h3 = Histogram.restore(json.loads(json.dumps(h.snapshot())))
    assert h3 == h


def test_registry_snapshot_json_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("service.completed").inc(3)
    reg.gauge("service.fill_ratio").set(0.75)
    reg.histogram("service.latency").observe(0.2)
    snap = reg.snapshot()
    assert validate_metrics(snap) == []
    assert snap["counters"]["service.completed"] == 3
    assert json.loads(reg.to_json()) == snap

    prom = reg.to_prometheus()
    assert "# TYPE service_completed counter" in prom
    assert "service_completed 3" in prom
    assert "# TYPE service_latency histogram" in prom
    assert 'service_latency_bucket{le="+Inf"} 1' in prom
    assert "service_latency_count 1" in prom


def test_registry_attach_histogram_is_a_live_view():
    reg = MetricsRegistry()
    h = Histogram()
    reg.attach_histogram("service.latency", h)
    h.observe(0.5)  # observed through the owner, after attaching
    assert reg.snapshot()["histograms"]["service.latency"]["count"] == 1


# ------------------------------------------------- stats histogram roundtrip --
def test_tenant_and_service_stats_latency_survive_snapshot_restore():
    st = ServiceStats()
    st.completed = 2
    st.latency.observe(0.25)
    st.latency.observe(0.75)
    ten = st.tenants["acme"] = TenantStats()
    ten.submitted = 2
    ten.latency.observe(0.25)

    st2 = ServiceStats.restore(st.snapshot())
    assert st2.completed == 2
    assert st2.latency == st.latency
    assert st2.as_dict() == st.as_dict()
    assert st2.tenants["acme"].latency == ten.latency
    # round-trips through JSON too (what a snapshot file would hold)
    st3 = ServiceStats.restore(json.loads(json.dumps(st.snapshot())))
    assert st3.as_dict() == st.as_dict()


# ----------------------------------------------- recalibration via the spans --
def test_observe_span_matches_direct_observe_calls():
    kn = KnobTuple(n_qubits=6, top_k=2, opt_steps=12, beam_width=16)
    mk = lambda: Planner(cost_model=CostModel(batch_slots=4), batch_slots=4)
    via_span, direct = mk(), mk()

    tr = Tracer(clock=FakeClock(), record=True)
    via_span.observe_span(tr.span_at("partition", 0.0, 0.5, n=40, n_edges=90))
    via_span.observe_span(tr.span_at(
        "solve", 0.0, 0.8, n_qubits=6, p_layers=3, opt_steps=12, slots=4))
    via_span.observe_span(tr.span_at(
        "merge", 0.0, 0.2, knobs=kn, m=5, n_edges=90))
    via_span.observe_span(tr.span_at("request", 0.0, 1.0))  # ignored

    direct.observe_partition(40, 90, 0.5)
    direct.observe_solve(6, 3, 12, 4, 0.8)
    direct.observe_merge(kn, 5, 90, 0.2)

    assert via_span.calibration.as_dict() == direct.calibration.as_dict()
    assert via_span.cost_model == direct.cost_model


# ---------------------------------------------------------- compile ledger --
def test_compile_ledger_records_and_resets():
    led = CompileLedger()
    led.note_build("solve_pool_program", "(6, 3)", 0.12)
    led.note_compile("solve_pool_program", "(6, 3)", "f32[4,16,2]", 0.8)
    led.note_op("cutvals", "xla")
    led.note_op("cutvals", "xla")
    assert led.count("build") == 1
    assert led.count("compile") == 1
    assert led.total_compile_s() == pytest.approx(0.8)
    snap = led.snapshot()
    assert snap["builds"] == 1 and snap["compiles"] == 1
    assert snap["op_traces"]["cutvals[xla]"] == 2
    led.reset()
    assert led.snapshot()["builds"] == 0
    assert led.snapshot()["op_traces"] == {}


def test_cached_programs_ledger_cold_then_warm_zero():
    from repro import compat

    calls = []

    @compat.cached_program
    def toy_program(scale):
        calls.append(scale)

        def run(x):
            return x * scale

        return run

    led = get_ledger()
    led.reset()
    f = toy_program(3)
    assert f is toy_program(3)  # identity through the cache
    assert f(2.0) == 6.0
    cold = led.snapshot()
    assert cold["builds"] == 1
    assert calls == [3]

    # warm re-run: cache intact, ledger cleared → zero build events
    led.reset()
    g = toy_program(3)
    assert g is f
    assert g(2.0) == 6.0
    warm = led.snapshot()
    assert warm["builds"] == 0
    assert warm["compiles"] == 0


def test_kernel_ops_record_trace_events():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    led = get_ledger()
    led.reset()

    @jax.jit
    def f(edges, weights):
        return ops.cutvals(2, edges, weights)

    edges = jnp.asarray([[0, 1]], dtype=jnp.int32)
    weights = jnp.ones((1,), dtype=jnp.float32)
    f(edges, weights)
    snap = led.snapshot()
    assert any(k.startswith("cutvals[") for k in snap["op_traces"])
    # cached call: no re-trace, no new events
    led.reset()
    f(edges, weights)
    assert led.snapshot()["op_traces"] == {}


# ------------------------------------------------- service trace invariants --
SOAK_GRID = tuple(
    KnobTuple(n_qubits=6, top_k=k, opt_steps=t, beam_width=w)
    for k in (1, 2)
    for t in (4, 12, 30)
    for w in (16, 64)
)
FLOOR_Q = 7.0


def _soak_cost_model(batch_slots):
    return CostModel(c_solve=3e-5, c_dispatch=2e-2, c_merge=5e-8,
                     c_merge_base=1e-3, batch_slots=batch_slots)


def _traced_service(slots=4, inflight=1, record=True):
    clock = VirtualClock()
    planner = Planner(cost_model=_soak_cost_model(slots), grid=SOAK_GRID,
                      batch_slots=slots)
    tracer = Tracer(clock=clock, record=record)
    svc = SolveService(
        ServiceConfig(batch_slots=slots, max_qubits=6, max_inflight=inflight),
        planner=planner,
        clock=clock,
        tracer=tracer,
    )
    return svc, clock


def _soak(requests=60, rate_rps=150.0, seed=42, slots=4, inflight=1,
          record=True):
    svc, clock = _traced_service(slots=slots, inflight=inflight,
                                 record=record)
    trace = arrival_trace(
        requests, rate_rps=rate_rps, n_range=(4, 6), p=0.5, seed=seed,
        repeat_frac=0.5, tenants=3, deadline_choices=(1.0, 4.0),
        floor_choices=(None, FLOOR_Q),
    )
    rids = run_soak_virtual(svc, clock, trace, tick_s=0.02)
    assert len(rids) == len(trace)
    return svc, rids


def _request_spans(svc):
    return [s for s in svc.trace.spans if s.name == "request"]


def test_soak_trace_is_schema_valid_and_nests():
    svc, _rids = _soak()
    recs = [s.as_dict() for s in svc.trace.spans]
    assert recs, "recording soak produced no spans"
    assert validate_trace_records(recs) == []
    assert validate_trace_jsonl(svc.trace.to_jsonl()) == []


def test_every_request_has_one_terminal_span_matching_result():
    svc, rids = _soak()
    spans = _request_spans(svc)
    assert len(spans) == len(rids)
    by_rid = {s.attrs["rid"]: s for s in spans}
    assert set(by_rid) == set(rids)
    for rid in rids:
        res = svc.results[rid]
        s = by_rid[rid]
        assert s.attrs["status"] == res.status
        assert s.attrs["tenant"] == res.tenant
        assert s.t1 is not None  # terminal span is closed


def test_traced_virtual_soak_is_bit_deterministic():
    a, rids_a = _soak()
    b, rids_b = _soak()
    assert rids_a == rids_b
    assert a.trace.to_jsonl() == b.trace.to_jsonl()
    assert a.stats.as_dict() == b.stats.as_dict()


def test_tracing_is_observation_only():
    """Recording spans must not perturb a single verdict, cut, or stamp."""
    on, rids_on = _soak(record=True)
    off, rids_off = _soak(record=False)
    assert rids_on == rids_off
    assert off.trace.spans == []
    assert on.stats.as_dict() == off.stats.as_dict()
    for rid in rids_on:
        ra, rb = on.results[rid], off.results[rid]
        assert (ra.status, ra.latency_s) == (rb.status, rb.latency_s)
        if ra.status == "completed":
            assert ra.cut_value == rb.cut_value


def test_service_metrics_registry_reconciles_with_stats():
    svc, _rids = _soak()
    snap = svc.metrics_registry().snapshot()
    assert validate_metrics(snap) == []
    st = svc.stats
    assert snap["counters"]["service.completed"] == st.completed
    assert snap["counters"]["service.shed"] == st.shed
    assert snap["counters"]["service.expired"] == st.expired
    assert snap["histograms"]["service.latency"] == st.latency.summary()
    for t, ten in st.tenants.items():
        assert snap["counters"][f"tenant.{t}.submitted"] == ten.submitted
        assert (snap["histograms"][f"tenant.{t}.latency"]
                == ten.latency.summary())


def test_soak_2000_requests_trace_reconciles_with_terminal_accounting():
    """The §8 acceptance headline: a 2,000-request traced virtual soak
    produces a schema-valid trace whose terminal request spans reconcile
    exactly with `ServiceStats` accounting."""
    svc, rids = _soak(requests=2000, slots=16, inflight=2)
    assert validate_trace_records(
        [s.as_dict() for s in svc.trace.spans]) == []
    spans = _request_spans(svc)
    assert len(spans) == 2000
    st = svc.stats
    counts = {"completed": 0, "shed": 0, "expired": 0}
    for s in spans:
        counts[s.attrs["status"]] += 1
    assert counts["completed"] == st.completed
    assert counts["shed"] == st.shed
    assert counts["expired"] == st.expired
    assert sum(counts.values()) == st.terminal == len(rids) == 2000
    # completed-latency stream: histogram count equals completed spans
    assert st.latency.summary()["count"] == st.completed

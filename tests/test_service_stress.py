"""Property-based soak of the solve service under the async admission
loop (DESIGN.md §6.5): randomized request mixes — sizes, SLAs, tenants,
isomorphic repeats, interleaved arrivals — must preserve the standing
service invariants:

  - every admitted request completes, and no request waits more than a
    bounded number of dispatches (anti-starvation pre-emption);
  - bucket fill never exceeds the fixed ``batch_slots`` shape;
  - non-cached cuts/assignments are bit-identical to solo `core.solve`
    on the request's own planned knobs;
  - cache hits are served only from equal-or-better-quality entries;
  - the in-flight window never exceeds ``max_inflight``.

Runs under real Hypothesis when installed, else the vendored
tests/_propshim.py shim (deterministic seeded draws)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import solve
from repro.core.graph import Graph
from repro.core.partition import partition_for_solver
from repro.service import SLA, ServiceConfig, SolveService
from repro.service.canonical import canonical_form
from repro.service.workload import request_mix, tenant_mix


def _solo_cfg(r):
    return r.plan.to_config()


def _queued(svc) -> int:
    return sum(len(q) for q in svc._buckets.values())


@given(
    seed=st.integers(0, 10**6),
    load=st.integers(4, 7),
    repeat=st.floats(0.0, 0.5),
    slots=st.sampled_from([4, 8]),
    tenants=st.integers(1, 3),
    inflight=st.integers(1, 3),
    defer=st.booleans(),
    deadline=st.sampled_from([5.0, 30.0, None]),
)
@settings(max_examples=4, deadline=None)
def test_service_soak_invariants(
    seed, load, repeat, slots, tenants, inflight, defer, deadline
):
    graphs = request_mix(load, (12, 26), 0.3, repeat, seed)
    labels = tenant_mix(load, tenants, seed)
    svc = SolveService(ServiceConfig(
        batch_slots=slots, max_qubits=6, cache_capacity=512,
        max_inflight=inflight, max_wait_dispatches=3,
        tenant_max_slots=max(slots // 2, 1),
    ))
    sla = SLA(deadline_s=deadline)

    # interleaved arrivals: half up front, a couple of event-loop ticks,
    # then the rest land while earlier batches may still be in flight
    half = load // 2
    rids, queued_at_admit = [], []
    for g, t in zip(graphs[:half], labels[:half]):
        queued_at_admit.append(_queued(svc))
        rids.append(svc.submit(g, sla, tenant=t))
    svc.pump()
    svc.pump()
    for g, t in zip(graphs[half:], labels[half:]):
        queued_at_admit.append(_queued(svc) + len(svc._admission))
        rids.append(svc.submit(g, sla, tenant=t, defer=defer))
    svc.drain()

    # completion + fixed-shape accounting
    assert svc.stats.completed == load and len(svc.results) == load
    assert svc.stats.slots_total == svc.stats.dispatches * slots
    assert svc.stats.slots_filled <= svc.stats.slots_total
    assert svc.stats.max_inflight_seen <= inflight
    assert not svc._inflight and not svc._admission and not _queued(svc)

    n_buckets = max(len(svc._buckets), 1)
    for g, rid, t, q0 in zip(graphs, rids, labels, queued_at_admit):
        r = svc.results[rid]
        assert r.tenant == t
        if r.cached:
            # hits only from equal-or-better-quality entries (§6.3 gate)
            entry = svc.cache._entries.get(canonical_form(g).key)
            assert entry is not None
            assert entry.quality >= r.plan.quality - 1e-12
            assert r.cut_value == float(
                np.float32(r.cut_value)
            )  # served cut is a real replayed score
        else:
            solo = solve(g, _solo_cfg(r))
            assert r.cut_value == solo.cut_value, (rid, r.plan.knobs)
            np.testing.assert_array_equal(r.assignment, solo.assignment)
            # bounded delay: each head-of-bucket position is dispatched
            # within max_wait_dispatches + (other overdue buckets), and
            # the request drains one head position per bucket dispatch
            m = partition_for_solver(g, r.plan.knobs.n_qubits).m
            bound = (q0 + m) * (
                svc.config.max_wait_dispatches + n_buckets
            ) + inflight + 1
            assert r.dispatches_waited <= bound, (
                rid, r.dispatches_waited, bound
            )


def test_admission_accepted_while_batches_in_flight():
    """The async loop's defining behavior: a request submitted while
    dispatched batches are still unharvested joins the queues and
    completes — no closed pump loop."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=2, recalibrate=False,
    ))
    sla = SLA(deadline_s=20.0)
    rid0 = svc.submit(Graph.erdos_renyi(22, 0.3, seed=0), sla)
    # fill the dispatch window without harvesting anything
    while len(svc._inflight) < svc.config.max_inflight:
        if not svc._dispatch_one():
            break
    assert svc._inflight, "no batch in flight"
    inflight_at_submit = len(svc._inflight)
    rid1 = svc.submit(Graph.erdos_renyi(18, 0.3, seed=1), sla, defer=True)
    assert svc._admission, "deferred request should sit on the admission queue"
    assert len(svc._inflight) == inflight_at_submit  # submit never blocks
    svc.drain()
    assert not svc.results[rid0].cached and not svc.results[rid1].cached
    assert svc.stats.completed == 2
    assert svc.stats.max_inflight_seen >= 2


def test_tenant_round_robin_and_quota():
    """Under contention the dispatcher interleaves tenants and honors
    ``tenant_max_slots``: a heavy tenant cannot fill a dispatch while a
    light tenant waits."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=1, tenant_max_slots=2, recalibrate=False,
    ))
    sla = SLA(deadline_s=20.0)
    # single-subgraph requests (n <= 6 fits one 6-qubit solver): 6 from
    # the heavy tenant, 2 from the light one, all in one bucket
    for s in range(6):
        svc.submit(Graph.erdos_renyi(6, 0.6, seed=s), sla, tenant="heavy")
    for s in range(2):
        svc.submit(Graph.erdos_renyi(6, 0.6, seed=100 + s), sla,
                   tenant="light")
    svc.pump()  # one tick = one dispatch at max_inflight=1
    assert svc.stats.dispatches == 1
    assert svc.stats.tenants["heavy"].slots == 2  # capped
    assert svc.stats.tenants["light"].slots == 2  # round-robin share
    svc.drain()
    assert svc.stats.completed == 8
    assert svc.stats.tenants["heavy"].completed == 6
    assert svc.stats.tenants["light"].completed == 2


def test_starved_bucket_preempts_fuller_one():
    """A lone request in a sparse bucket must not starve behind a flood
    in a fuller bucket: after ``max_wait_dispatches`` dispatches its
    bucket pre-empts the fullest-bucket heuristic."""
    svc = SolveService(ServiceConfig(
        batch_slots=2, max_qubits=8, enable_cache=False,
        max_inflight=1, max_wait_dispatches=2, recalibrate=False,
    ))
    # flood: best-quality knobs (no deadline → one bucket of rich knobs)
    for s in range(4):
        svc.submit(Graph.erdos_renyi(26, 0.3, seed=s), SLA())
    # the lone request: a tight deadline selects cheaper knobs → its own
    # bucket, far emptier than the flood's
    lone = svc.submit(Graph.erdos_renyi(26, 0.3, seed=50),
                      SLA(deadline_s=0.05))
    flood_cfgs = {r.cfg for rid, r in svc._active.items() if rid != lone}
    assert svc._active[lone].cfg not in flood_cfgs, (
        "test needs the lone request in its own bucket"
    )
    svc.drain()
    r = svc.results[lone]
    m = partition_for_solver(
        Graph.erdos_renyi(26, 0.3, seed=50), r.plan.knobs.n_qubits
    ).m
    # lone bucket head waits <= max_wait_dispatches per head position
    bound = m * (svc.config.max_wait_dispatches + 2) + 1
    assert r.dispatches_waited <= bound, (r.dispatches_waited, bound)
    assert svc.stats.preemptions >= 1


def test_zero_inflight_window_still_makes_progress():
    """Regression: ``max_inflight=0`` must clamp to a 1-batch window, not
    busy-loop forever in `drain` with nothing ever dispatched."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=0, recalibrate=False,
    ))
    svc.submit(Graph.erdos_renyi(14, 0.4, seed=0), SLA(deadline_s=10.0))
    svc.drain()
    assert svc.stats.completed == 1
    assert svc.stats.max_inflight_seen == 1

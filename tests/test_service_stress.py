"""Property-based soak of the solve service under the async admission
loop (DESIGN.md §6.5): randomized request mixes — sizes, SLAs, tenants,
isomorphic repeats, interleaved arrivals — must preserve the standing
service invariants:

  - every admitted request completes, and no request waits more than a
    bounded number of dispatches (anti-starvation pre-emption);
  - bucket fill never exceeds the fixed ``batch_slots`` shape;
  - non-cached cuts/assignments are bit-identical to solo `core.solve`
    on the request's own planned knobs;
  - cache hits are served only from equal-or-better-quality entries;
  - the in-flight window never exceeds ``max_inflight``.

Runs under real Hypothesis when installed, else the vendored
tests/_propshim.py shim (deterministic seeded draws)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import solve
from repro.core.graph import Graph
from repro.core.partition import partition_for_solver
from repro.service import SLA, ServiceConfig, SolveService
from repro.service.canonical import canonical_form
from repro.service.workload import request_mix, tenant_mix


def _solo_cfg(r):
    return r.plan.to_config()


def _queued(svc) -> int:
    return sum(len(q) for q in svc._buckets.values())


@given(
    seed=st.integers(0, 10**6),
    load=st.integers(4, 7),
    repeat=st.floats(0.0, 0.5),
    slots=st.sampled_from([4, 8]),
    tenants=st.integers(1, 3),
    inflight=st.integers(1, 3),
    defer=st.booleans(),
    deadline=st.sampled_from([5.0, 30.0, None]),
)
@settings(max_examples=4, deadline=None)
def test_service_soak_invariants(
    seed, load, repeat, slots, tenants, inflight, defer, deadline
):
    graphs = request_mix(load, (12, 26), 0.3, repeat, seed)
    labels = tenant_mix(load, tenants, seed)
    svc = SolveService(ServiceConfig(
        batch_slots=slots, max_qubits=6, cache_capacity=512,
        max_inflight=inflight, max_wait_dispatches=3,
        tenant_max_slots=max(slots // 2, 1),
        # §6.6 enforcement off: this soak asserts completion of *every*
        # request against wall-clock SLAs on shared CI hosts, where a GC
        # pause could legitimately shed/expire one (that behavior has its
        # own virtual-clock suite, tests/test_service_sla.py)
        enforce_deadlines=False,
    ))
    sla = SLA(deadline_s=deadline)

    # interleaved arrivals: half up front, a couple of event-loop ticks,
    # then the rest land while earlier batches may still be in flight
    half = load // 2
    rids, queued_at_admit = [], []
    for g, t in zip(graphs[:half], labels[:half]):
        queued_at_admit.append(_queued(svc))
        rids.append(svc.submit(g, sla, tenant=t))
    svc.pump()
    svc.pump()
    for g, t in zip(graphs[half:], labels[half:]):
        queued_at_admit.append(_queued(svc) + len(svc._admission))
        rids.append(svc.submit(g, sla, tenant=t, defer=defer))
    svc.drain()

    # completion + fixed-shape accounting
    assert svc.stats.completed == load and len(svc.results) == load
    assert svc.stats.slots_total == svc.stats.dispatches * slots
    assert svc.stats.slots_filled <= svc.stats.slots_total
    assert svc.stats.max_inflight_seen <= inflight
    assert not svc._inflight and not svc._admission and not _queued(svc)

    n_buckets = max(len(svc._buckets), 1)
    for g, rid, t, q0 in zip(graphs, rids, labels, queued_at_admit):
        r = svc.results[rid]
        assert r.tenant == t
        if r.cached:
            # hits only from equal-or-better-quality entries (§6.3 gate)
            entry = svc.cache._entries.get(canonical_form(g).key)
            assert entry is not None
            assert entry.quality >= r.plan.quality - 1e-12
            assert r.cut_value == float(
                np.float32(r.cut_value)
            )  # served cut is a real replayed score
        else:
            solo = solve(g, _solo_cfg(r))
            assert r.cut_value == solo.cut_value, (rid, r.plan.knobs)
            np.testing.assert_array_equal(r.assignment, solo.assignment)
            # bounded delay: each head-of-bucket position is dispatched
            # within max_wait_dispatches + (other overdue buckets), and
            # the request drains one head position per bucket dispatch
            m = partition_for_solver(g, r.plan.knobs.n_qubits).m
            bound = (q0 + m) * (
                svc.config.max_wait_dispatches + n_buckets
            ) + inflight + 1
            assert r.dispatches_waited <= bound, (
                rid, r.dispatches_waited, bound
            )


def test_admission_accepted_while_batches_in_flight():
    """The async loop's defining behavior: a request submitted while
    dispatched batches are still unharvested joins the queues and
    completes — no closed pump loop."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=2, recalibrate=False,
    ))
    sla = SLA(deadline_s=20.0)
    rid0 = svc.submit(Graph.erdos_renyi(22, 0.3, seed=0), sla)
    # fill the dispatch window without harvesting anything
    while len(svc._inflight) < svc.config.max_inflight:
        if not svc._dispatch_one():
            break
    assert svc._inflight, "no batch in flight"
    inflight_at_submit = len(svc._inflight)
    rid1 = svc.submit(Graph.erdos_renyi(18, 0.3, seed=1), sla, defer=True)
    assert svc._admission, "deferred request should sit on the admission queue"
    assert len(svc._inflight) == inflight_at_submit  # submit never blocks
    svc.drain()
    assert not svc.results[rid0].cached and not svc.results[rid1].cached
    assert svc.stats.completed == 2
    assert svc.stats.max_inflight_seen >= 2


def test_tenant_round_robin_and_quota():
    """Under contention the dispatcher interleaves tenants and honors
    ``tenant_max_slots``: a heavy tenant cannot fill a dispatch while a
    light tenant waits."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=1, tenant_max_slots=2, recalibrate=False,
    ))
    sla = SLA(deadline_s=20.0)
    # single-subgraph requests (n <= 6 fits one 6-qubit solver): 6 from
    # the heavy tenant, 2 from the light one, all in one bucket
    for s in range(6):
        svc.submit(Graph.erdos_renyi(6, 0.6, seed=s), sla, tenant="heavy")
    for s in range(2):
        svc.submit(Graph.erdos_renyi(6, 0.6, seed=100 + s), sla,
                   tenant="light")
    svc.pump()  # one tick = one dispatch at max_inflight=1
    assert svc.stats.dispatches == 1
    assert svc.stats.tenants["heavy"].slots == 2  # capped
    assert svc.stats.tenants["light"].slots == 2  # round-robin share
    svc.drain()
    assert svc.stats.completed == 8
    assert svc.stats.tenants["heavy"].completed == 6
    assert svc.stats.tenants["light"].completed == 2


def test_starved_bucket_preempts_fuller_one():
    """A lone request in a sparse bucket must not starve behind a flood
    in a fuller bucket: after ``max_wait_dispatches`` dispatches its
    bucket pre-empts the fullest-bucket heuristic."""
    svc = SolveService(ServiceConfig(
        batch_slots=2, max_qubits=8, enable_cache=False,
        max_inflight=1, max_wait_dispatches=2, recalibrate=False,
        # the 0.05s deadline below exists to steer knob selection into a
        # sparse bucket; with §6.6 enforcement it would be shed instead
        enforce_deadlines=False,
    ))
    # flood: best-quality knobs (no deadline → one bucket of rich knobs)
    for s in range(4):
        svc.submit(Graph.erdos_renyi(26, 0.3, seed=s), SLA())
    # the lone request: a tight deadline selects cheaper knobs → its own
    # bucket, far emptier than the flood's
    lone = svc.submit(Graph.erdos_renyi(26, 0.3, seed=50),
                      SLA(deadline_s=0.05))
    flood_cfgs = {r.cfg for rid, r in svc._active.items() if rid != lone}
    assert svc._active[lone].cfg not in flood_cfgs, (
        "test needs the lone request in its own bucket"
    )
    svc.drain()
    r = svc.results[lone]
    m = partition_for_solver(
        Graph.erdos_renyi(26, 0.3, seed=50), r.plan.knobs.n_qubits
    ).m
    # lone bucket head waits <= max_wait_dispatches per head position
    bound = m * (svc.config.max_wait_dispatches + 2) + 1
    assert r.dispatches_waited <= bound, (r.dispatches_waited, bound)
    assert svc.stats.preemptions >= 1


def test_recalibration_drift_never_retro_sheds_admitted_requests():
    """§6.6 under a drifting cost model: EW recalibration inflating the
    live `CostModel` mid-soak must never (a) break planner deadline
    monotonicity or (b) retroactively shed an already-admitted request —
    post-admission a shed verdict clamps to the floor plan instead, so
    every admitted request still completes (or expires on its real
    deadline, never on a prediction)."""
    from repro.service import Planner, VirtualClock
    from repro.service.planner import CostModel, KnobTuple

    grid = [
        KnobTuple(n_qubits=6, top_k=k, opt_steps=t, beam_width=w)
        for k in (1, 2) for t in (4, 12, 30) for w in (16, 64)
    ]
    clock = VirtualClock()
    planner = Planner(
        cost_model=CostModel(c_solve=3e-5, c_dispatch=2e-2, c_merge=5e-8,
                             c_merge_base=1e-3, batch_slots=4),
        grid=grid, batch_slots=4,
    )
    svc = SolveService(
        ServiceConfig(batch_slots=4, max_qubits=6, max_inflight=1),
        planner=planner, clock=clock,
    )
    # admit everything while the model still predicts cheap: virtual
    # deadlines far above any prediction, so nothing sheds at admission
    rids = [
        svc.submit(Graph.erdos_renyi(5 + (s % 5), 0.5, seed=s),
                   SLA(deadline_s=50.0, floor_quality=7.0))
        for s in range(8)
    ]
    assert svc.stats.shed == 0 and len(svc._active) == 8

    # drift: blend in observations 1000x the predicted per-unit costs —
    # the recalibrated model now predicts everything catastrophically late
    for _ in range(30):
        planner.observe_solve(6, 2, 30, 4, seconds=50.0)
        planner.observe_merge(grid[-1], 2, 20, seconds=20.0)
        planner.observe_partition(9, 20, seconds=5.0)
    assert planner.cost_model.c_solve > planner.base_model.c_solve * 10

    # (a) selection monotonicity survives the drifted coefficients
    for n, e in ((8, 14), (20, 60)):
        prev = None
        for deadline in (300.0, 5.0, 0.5, 0.01):
            t = planner.plan(n, e, SLA(deadline_s=deadline)).predicted.total_s
            if prev is not None:
                assert t <= prev + 1e-12, (n, deadline, t, prev)
            prev = t
    # ... and the replan walk stays ordered keep -> downgrade -> shed
    plan = planner.plan(8, 14, SLA(deadline_s=50.0))
    order = {"keep": 0, "downgrade": 1, "shed": 2}
    prev_rank = 0
    for budget in (50.0, 5.0, 0.5, 0.05, 0.005):
        d = planner.replan(8, 14, budget, plan, floor_quality=7.0)
        assert order[d.verdict] >= prev_rank, (budget, d.verdict)
        prev_rank = order[d.verdict]

    # (b) drain: every admitted request reaches a terminal state and
    # none of them is "shed" — predictions alone cannot evict them
    while svc.pump():
        clock.advance(0.02)
    assert svc.stats.terminal == 8
    assert svc.stats.shed == 0, "admitted request retroactively shed"
    for rid in rids:
        assert svc.results[rid].status in ("completed", "expired")
    assert svc.stats.completed == 8, "drift alone expired an admitted request"


def test_zero_inflight_window_still_makes_progress():
    """Regression: ``max_inflight=0`` must clamp to a 1-batch window, not
    busy-loop forever in `drain` with nothing ever dispatched."""
    svc = SolveService(ServiceConfig(
        batch_slots=4, max_qubits=6, enable_cache=False,
        max_inflight=0, recalibrate=False,
    ))
    svc.submit(Graph.erdos_renyi(14, 0.4, seed=0), SLA(deadline_s=10.0))
    svc.drain()
    assert svc.stats.completed == 1
    assert svc.stats.max_inflight_seen == 1

"""Docs integrity: every `DESIGN.md §N` / `EXPERIMENTS.md §Name`-style
citation in the source tree must resolve to a real section, and every
intra-repo markdown link must point at an existing file. This is the
check that keeps docstring citations from dangling again (the repo
shipped for two PRs citing DESIGN.md sections that did not exist);
CI runs it in the `docs` job, tier-1 runs it here. Pure text scanning —
no jax import.
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# where a cited doc name resolves to on disk
DOC_PATHS = {
    "DESIGN.md": REPO / "docs" / "DESIGN.md",
    "EXPERIMENTS.md": REPO / "docs" / "EXPERIMENTS.md",
    "TESTING.md": REPO / "docs" / "TESTING.md",
    "README.md": REPO / "README.md",
    "ROADMAP.md": REPO / "ROADMAP.md",
}

SOURCE_GLOBS = (
    "src/**/*.py",
    "benchmarks/**/*.py",
    "examples/**/*.py",
    "tests/**/*.py",
    "*.md",
    "docs/*.md",
)

# "DESIGN.md §2.4", "EXPERIMENTS.md §Perf", "docs/DESIGN.md`'s §2", and
# the reversed "§4 DESIGN.md" form
_FWD = re.compile(
    r"\b(DESIGN|EXPERIMENTS|TESTING|README|ROADMAP)\.md[`')»]*(?:'s)?"
    r"(?:\s*§([\w.-]+))?"
)
_REV = re.compile(r"§([\w.-]+)\s+(?:of\s+)?(?:docs/)?(DESIGN|EXPERIMENTS)\.md")
# bare perf-item citations like "§Perf C3"
_PERF_ITEM = re.compile(r"§Perf\s+(C\d+)")


def _source_files():
    for pattern in SOURCE_GLOBS:
        yield from sorted(REPO.glob(pattern))


def _doc_text(name: str) -> str:
    return DOC_PATHS[name].read_text()


def _citations(text: str):
    """Yield (doc_name, section_or_None) for every doc citation in text."""
    for m in _FWD.finditer(text):
        yield f"{m.group(1)}.md", m.group(2)
    for m in _REV.finditer(text):
        yield f"{m.group(2)}.md", m.group(1)


def test_cited_docs_exist():
    missing = []
    for path in _source_files():
        for doc, _ in _citations(path.read_text()):
            if not DOC_PATHS[doc].exists():
                missing.append(f"{path.relative_to(REPO)}: {doc}")
    assert not missing, f"citations to nonexistent docs: {missing}"


def test_cited_sections_exist():
    dangling = []
    for path in _source_files():
        if path == Path(__file__):
            continue  # this file's own regex examples
        for doc, section in _citations(path.read_text()):
            if section is None:
                continue
            section = section.rstrip(".-")
            if f"§{section}" not in _doc_text(doc):
                dangling.append(
                    f"{path.relative_to(REPO)}: {doc} §{section}"
                )
    assert not dangling, f"dangling section citations: {dangling}"


def test_perf_item_citations_exist():
    """'§Perf C3'-style item citations must match an enumerated item in
    EXPERIMENTS.md's §Perf list (written as 'C3 — ...')."""
    perf = _doc_text("EXPERIMENTS.md")
    dangling = []
    for path in _source_files():
        if path == Path(__file__):
            continue
        for m in _PERF_ITEM.finditer(path.read_text()):
            if f"{m.group(1)} —" not in perf:
                dangling.append(f"{path.relative_to(REPO)}: §Perf {m.group(1)}")
    assert not dangling, f"dangling §Perf items: {dangling}"


_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def test_markdown_links_resolve():
    broken = []
    for md in sorted(list(REPO.glob("*.md")) + list(REPO.glob("docs/*.md"))):
        for m in _MD_LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            target_path = (md.parent / target.split("#")[0]).resolve()
            if not target_path.exists():
                broken.append(f"{md.relative_to(REPO)}: {target}")
    assert not broken, f"broken intra-repo links: {broken}"


def test_experiments_placeholders_or_tables_present():
    """benchmarks/report.py --write substitutes these markers; whichever
    state the doc is in (placeholder or generated tables), the sections
    it writes into must exist."""
    text = _doc_text("EXPERIMENTS.md")
    assert "§Dry-run" in text and "§Roofline" in text
    assert "<!-- DRYRUN_TABLE -->" in text or "All cells" in text
    assert "<!-- ROOFLINE_TABLE -->" in text or "scoreboard" in text

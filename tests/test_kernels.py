"""Per-kernel validation: Pallas (interpret=True on CPU) vs ref.py oracles,
swept across shapes, plus hypothesis property tests on kernel invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph
from repro.kernels import cutbatch, cutvals, mixer, phase, ref


def _graph(n, p, seed, pad=None):
    return Graph.erdos_renyi(n, p, seed=seed, pad_to=pad)


# ---------------------------------------------------------------- cutvals --
@pytest.mark.parametrize("n", [3, 6, 10, 12])
@pytest.mark.parametrize("p", [0.2, 0.8])
def test_cutvals_kernel_matches_ref(n, p):
    g = _graph(n, p, seed=n)
    want = ref.cutvals(n, g.edges, g.weights)
    got = cutvals.cutvals(n, g.edges, g.weights, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_cutvals_kernel_edge_padding_boundary():
    # weighted multigraph with E > EDGE_CHUNK: exercises chunked accumulation
    n = 10
    e = cutvals.EDGE_CHUNK + 37
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, n, size=(e, 2))
    pairs[pairs[:, 0] == pairs[:, 1], 1] += 1
    pairs[:, 1] %= n
    w = rng.uniform(0.1, 2.0, size=e).astype(np.float32)
    g = Graph.from_edges(n, pairs, w)
    assert g.n_edges > cutvals.EDGE_CHUNK
    want = ref.cutvals(n, g.edges, g.weights)
    got = cutvals.cutvals(n, g.edges, g.weights, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@given(n=st.integers(2, 9), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_cutvals_complement_symmetry(n, seed):
    # cut(b) == cut(~b): flipping every vertex preserves the cut
    g = _graph(n, 0.5, seed=seed)
    c = np.asarray(cutvals.cutvals(n, g.edges, g.weights, interpret=True))
    np.testing.assert_allclose(c, c[::-1][np.argsort(np.argsort(c))] * 0 + c[(2**n - 1) - np.arange(2**n)], rtol=1e-6)


# ------------------------------------------------------------------ phase --
@pytest.mark.parametrize("n", [6, 10, 14])
@pytest.mark.parametrize("gamma", [0.0, 0.37, -1.2])
def test_phase_kernel_matches_ref(n, gamma):
    key = jax.random.PRNGKey(n)
    k1, k2, k3 = jax.random.split(key, 3)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    c = jax.random.uniform(k3, (dim,), jnp.float32) * 10
    wr, wi = ref.apply_phase(re, im, c, gamma)
    gr, gi = phase.apply_phase(re, im, c, gamma, interpret=True)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=1e-5)


def test_phase_preserves_norm():
    dim = 2**12
    key = jax.random.PRNGKey(0)
    re = jax.random.normal(key, (dim,), jnp.float32)
    im = jnp.zeros((dim,))
    c = jax.random.uniform(key, (dim,)) * 5
    gr, gi = phase.apply_phase(re, im, c, 0.7, interpret=True)
    np.testing.assert_allclose(
        float(jnp.sum(gr**2 + gi**2)), float(jnp.sum(re**2)), rtol=1e-5
    )


@pytest.mark.parametrize("n", [6, 12])
def test_expectation_kernel_matches_ref(n):
    key = jax.random.PRNGKey(n)
    k1, k2, k3 = jax.random.split(key, 3)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    c = jax.random.uniform(k3, (dim,), jnp.float32)
    want = float(ref.expectation(re, im, c))
    got = float(phase.expectation(re, im, c, interpret=True))
    assert got == pytest.approx(want, rel=1e-5)


# ------------------------------------------------------------------ mixer --
@pytest.mark.parametrize("n", [3, 5, 8, 10])
@pytest.mark.parametrize("beta", [0.1, 0.9, 2.5])
def test_mixer_kernel_matches_ref(n, beta):
    key = jax.random.PRNGKey(n)
    k1, k2 = jax.random.split(key)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    wr, wi = ref.apply_mixer(re, im, n, jnp.float32(beta))
    gr, gi = mixer.apply_mixer(re, im, n, jnp.float32(beta), interpret=True)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=2e-5)


@pytest.mark.parametrize("group", [2, 4, 7])
def test_mixer_group_sizes_agree(group):
    n = 8
    key = jax.random.PRNGKey(1)
    dim = 2**n
    re = jax.random.normal(key, (dim,), jnp.float32)
    im = jnp.zeros((dim,))
    w7r, w7i = ref.apply_mixer(re, im, n, 0.4, group=7)
    wgr, wgi = ref.apply_mixer(re, im, n, 0.4, group=group)
    np.testing.assert_allclose(np.asarray(wgr), np.asarray(w7r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(wgi), np.asarray(w7i), atol=2e-5)


def test_mixer_unitarity():
    n = 9
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (2**n,), jnp.float32)
    im = jax.random.normal(k2, (2**n,), jnp.float32)
    norm0 = float(jnp.sum(re**2 + im**2))
    gr, gi = mixer.apply_mixer(re, im, n, 1.3, interpret=True)
    assert float(jnp.sum(gr**2 + gi**2)) == pytest.approx(norm0, rel=1e-4)


def test_mixer_beta_zero_is_identity():
    n = 6
    re = jax.random.normal(jax.random.PRNGKey(3), (2**n,), jnp.float32)
    im = jnp.zeros((2**n,))
    gr, gi = mixer.apply_mixer(re, im, n, 0.0, interpret=True)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(re), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gi), 0.0, atol=1e-6)


# --------------------------------------------------------------- cutbatch --
@pytest.mark.parametrize("b,v", [(4, 10), (130, 50), (64, 600)])
def test_cutbatch_kernel_matches_ref(b, v):
    g = _graph(v, 0.3, seed=b)
    adj = g.dense_adjacency()
    rng = np.random.default_rng(b)
    spins = (rng.integers(0, 2, size=(b, v)) * 2 - 1).astype(np.float32)
    want = ref.cut_batch_dense(jnp.asarray(spins), adj, g.total_weight())
    got = cutbatch.cut_batch_dense(
        jnp.asarray(spins), adj, g.total_weight(), interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_cutbatch_agrees_with_edge_list_eval():
    from repro.core.graph import cut_value_batch

    v, b = 37, 12
    g = _graph(v, 0.5, seed=5)
    rng = np.random.default_rng(7)
    assign = rng.integers(0, 2, size=(b, v)).astype(np.int8)
    spins = (assign * 2 - 1).astype(np.float32)
    want = np.asarray(cut_value_batch(g, jnp.asarray(assign)))
    got = np.asarray(
        cutbatch.cut_batch_dense(
            jnp.asarray(spins), g.dense_adjacency(), g.total_weight(), interpret=True
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ------------------------------------------------------------ cutvals_at --
@pytest.mark.parametrize("n,m", [(6, 64), (10, 1000), (12, 5000)])
def test_cutvals_at_kernel_matches_ref(n, m):
    # arbitrary (shuffled, non-tile-multiple) basis indices — the sharded
    # layout-A/B gather pattern
    g = _graph(n, 0.5, seed=n)
    rng = np.random.default_rng(m)
    idx = jnp.asarray(rng.integers(0, 2**n, size=m), jnp.int32)
    want = ref.cutvals_at(idx, g.edges, g.weights)
    got = cutvals.cutvals_at(idx, g.edges, g.weights, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_cutvals_at_full_range_equals_cutvals():
    n = 9
    g = _graph(n, 0.4, seed=2)
    idx = jnp.arange(2**n, dtype=jnp.int32)
    got = cutvals.cutvals_at(idx, g.edges, g.weights, interpret=True)
    want = cutvals.cutvals(n, g.edges, g.weights, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------------- apply_mixer_bits --
@pytest.mark.parametrize("n,lo,k", [(8, 0, 3), (8, 2, 3), (9, 4, 5), (10, 3, 7)])
def test_mixer_bits_kernel_matches_ref(n, lo, k):
    key = jax.random.PRNGKey(n * 100 + lo)
    k1, k2 = jax.random.split(key)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    beta = jnp.float32(0.7)
    wr, wi = ref.apply_mixer_bits(re, im, n, lo, k, beta)
    gr, gi = mixer.apply_mixer_bits(re, im, n, lo, k, beta, interpret=True)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=2e-5)


@pytest.mark.parametrize("n,lo,k", [(8, 2, 3), (9, 4, 5), (10, 3, 7)])
def test_mixer_bits_relayout_path_matches_strided(n, lo, k):
    # the legacy moveaxis path (kept as the §Perf C11 bench baseline)
    # and the fused strided-BlockSpec kernel are the same group unitary
    key = jax.random.PRNGKey(n * 10 + k)
    k1, k2 = jax.random.split(key)
    dim = 2**n
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    beta = jnp.float32(0.7)
    sr, si = mixer.apply_mixer_bits(re, im, n, lo, k, beta, interpret=True)
    rr, ri = mixer.apply_mixer_bits_relayout(
        re, im, n, lo, k, beta, interpret=True
    )
    np.testing.assert_allclose(np.asarray(sr), np.asarray(rr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(si), np.asarray(ri), atol=2e-5)


def test_mixer_bits_composition_is_full_mixer():
    # chaining apply_mixer_bits over all groups == apply_mixer (ref oracle)
    n, group = 9, 4
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (2**n,), jnp.float32)
    im = jax.random.normal(k2, (2**n,), jnp.float32)
    beta = jnp.float32(1.1)
    wr, wi = ref.apply_mixer(re, im, n, beta, group=group)
    gr, gi = re, im
    for g0 in range(0, n, group):
        gr, gi = ref.apply_mixer_bits(gr, gi, n, g0, min(group, n - g0), beta)
    np.testing.assert_array_equal(np.asarray(gr), np.asarray(wr))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ------------------------------------------------- ops dispatch integrity --
def test_ops_dispatch_pallas_interpret_equals_xla():
    from repro.kernels import ops

    n = 8
    g = _graph(n, 0.5, seed=0)
    with ops.using_implementation("xla"):
        c_x = np.asarray(ops.cutvals(n, g.edges, g.weights))
    with ops.using_implementation("pallas_interpret"):
        c_p = np.asarray(ops.cutvals(n, g.edges, g.weights))
    assert ops.get_implementation() != "pallas_interpret"  # restored on exit
    np.testing.assert_allclose(c_p, c_x, rtol=1e-6)


def test_using_implementation_restores_on_error():
    from repro.kernels import ops

    before = ops.get_implementation()
    with pytest.raises(RuntimeError):
        with ops.using_implementation("pallas_interpret"):
            raise RuntimeError("boom")
    assert ops.get_implementation() == before


def test_ops_apply_layer_dispatch_matches_xla():
    """The engine's per-layer op: the pallas_interpret path (fused
    phase+first-group kernel + grouped mixer kernels) must agree with
    the XLA reference decomposition."""
    from repro.kernels import ops

    n, group = 9, 4
    g = _graph(n, 0.5, seed=9)
    cutv = ref.cutvals(n, g.edges, g.weights)
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (2**n,), jnp.float32)
    im = jax.random.normal(k2, (2**n,), jnp.float32)
    with ops.using_implementation("xla"):
        wr, wi = ops.apply_layer(re, im, cutv, 0.4, 0.9, n, group=group)
    with ops.using_implementation("pallas_interpret"):
        gr, gi = ops.apply_layer(re, im, cutv, 0.4, 0.9, n, group=group)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), atol=2e-5)


# ------------------------------------------- impl-keyed program caches --
def test_batch_program_cache_keys_on_implementation():
    """ROADMAP follow-up from PR 4: `solve_subgraph_batch_program` (the
    solve/service/pool solver) must key its cache on the active
    `kernels.ops` implementation — dispatch is a trace-time choice, so a
    program traced under one impl silently ignores
    `ops.using_implementation` forever after. Flipping impls must yield
    distinct cached programs; re-selecting an impl must return *its*
    program and reproduce its results bit-for-bit."""
    from repro.core import qaoa as qaoa_mod
    from repro.core.partition import partition_for_solver
    from repro.kernels import ops
    from repro.kernels import tuning

    qcfg = qaoa_mod.QAOAConfig(n_qubits=6, p_layers=2, opt_steps=4, top_k=2)
    g = _graph(16, 0.4, seed=21)
    part = partition_for_solver(g, 6)
    e, w, m = qaoa_mod.pad_subgraph_arrays(part.subgraphs, 6)

    p_x = qaoa_mod.solve_subgraph_batch_program(qcfg)
    r_x = p_x(e, w, m)
    with ops.using_implementation("pallas_interpret"):
        p_i = qaoa_mod.solve_subgraph_batch_program(qcfg)
        # same impl, same config: one compiled program (cache hit)
        assert qaoa_mod.solve_subgraph_batch_program(qcfg) is p_i
        r_i = p_i(e, w, m)
    # distinct impls: distinct programs, and flipping back returns the
    # original (the pre-fix bug: one shared program for every impl)
    assert p_x is not p_i
    assert qaoa_mod.solve_subgraph_batch_program(qcfg) is p_x
    r_x2 = qaoa_mod.solve_subgraph_batch_program(qcfg)(e, w, m)
    np.testing.assert_array_equal(
        np.asarray(r_x.bitstrings), np.asarray(r_x2.bitstrings)
    )
    np.testing.assert_array_equal(
        np.asarray(r_x.probs), np.asarray(r_x2.probs)
    )
    # the two impls agree semantically (per-candidate marginals to float32
    # tolerance; exact candidate picks may flip between prob ties)
    np.testing.assert_allclose(
        np.asarray(r_i.probs), np.asarray(r_x.probs), atol=1e-6
    )


def test_batch_program_interpret_dispatch_fires_pallas_kernels():
    """Under `pallas_interpret` the impl-keyed batch program must
    actually reach the Pallas kernels (trace-time dispatch proof), and
    the service path built on it must stay bit-identical to solo
    `core.solve` under the same flipped impl."""
    import repro.kernels.fused_layer as fused_mod
    from repro.core import solve
    from repro.kernels import ops
    from repro.service import SLA, ServiceConfig, SolveService

    calls = {"n": 0}
    orig = fused_mod.fused_phase_mixer_group

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    fused_mod.fused_phase_mixer_group = spy
    try:
        with ops.using_implementation("pallas_interpret"):
            svc = SolveService(ServiceConfig(
                batch_slots=4, max_qubits=6, enable_cache=False,
                recalibrate=False,
            ))
            g = _graph(14, 0.4, seed=22)
            rid = svc.submit(g, SLA(deadline_s=30.0))
            svc.drain()
            r = svc.results[rid]
            assert calls["n"] > 0, "pallas dispatch never fired"
            solo = solve(g, r.plan.to_config())
            assert r.cut_value == solo.cut_value
            np.testing.assert_array_equal(r.assignment, solo.assignment)
    finally:
        fused_mod.fused_phase_mixer_group = orig


def test_solve_pool_program_cache_keys_on_implementation():
    """The pool stage's shard_map program keys on the impl too (a
    1-device `data` mesh keeps this in-process); both impls' pool
    results agree semantically."""
    from repro import compat
    from repro.core import distributed as dist
    from repro.core import qaoa as qaoa_mod
    from repro.core.partition import partition_for_solver
    from repro.kernels import ops
    from repro.kernels import tuning

    qcfg = qaoa_mod.QAOAConfig(n_qubits=6, p_layers=2, opt_steps=4, top_k=2)
    mesh = compat.make_mesh((1,), ("data",))
    donate = compat.supports_donation()
    off = tuning.state()
    p_x = dist._solve_pool_program(qcfg, mesh, ("data",), donate, "xla", off)
    p_i = dist._solve_pool_program(
        qcfg, mesh, ("data",), donate, "pallas_interpret", off
    )
    assert p_x is not p_i
    assert dist._solve_pool_program(
        qcfg, mesh, ("data",), donate, "xla", off
    ) is p_x

    g = _graph(16, 0.4, seed=23)
    part = partition_for_solver(g, 6)
    e, w, m = qaoa_mod.pad_subgraph_arrays(part.subgraphs, 6)
    r_x = dist.solve_pool(e, w, m, qcfg, mesh)
    with ops.using_implementation("pallas_interpret"):
        r_i = dist.solve_pool(e, w, m, qcfg, mesh)
    np.testing.assert_allclose(
        np.asarray(r_i.probs), np.asarray(r_x.probs), atol=1e-6
    )

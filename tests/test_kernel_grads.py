"""Gradient parity for the `kernels.ops` custom-vjp layer (DESIGN.md §2.7).

The ops carry analytic vjp rules whose backward traces re-enter the same
dispatched kernels with negated angles. Ground truth for every gradient is
plain JAX autodiff through the pure-jnp `kernels.ref` oracles — NOT the
ops layer under another impl (that would test the vjp rules against
themselves). Forward values must stay bit-identical to the raw dispatch
(the custom_vjp wrapper may not perturb primal numerics), and the tuning
state must behave as honest cache-key material for cached program
builders (zero rebuilds on warm re-run, distinct programs per state).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref, tuning

ATOL = 2e-5


def _state(n: int, seed: int = 0):
    dim = 2**n
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    re = jax.random.normal(k1, (dim,), jnp.float32)
    im = jax.random.normal(k2, (dim,), jnp.float32)
    norm = jnp.sqrt(jnp.sum(re * re + im * im))
    cutv = jax.random.uniform(k3, (dim,), jnp.float32) * n
    return re / norm, im / norm, cutv


def _rand_cotangents(n: int, seed: int = 1):
    """Random linear functional over (ore, oim) so parity covers generic
    cotangents, not just the all-ones direction."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w_re = jax.random.normal(k1, (2**n,), jnp.float32)
    w_im = jax.random.normal(k2, (2**n,), jnp.float32)
    return w_re, w_im


def _assert_grads_close(got, want, atol=ATOL):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=atol, rtol=1e-4)


# ---------------------------------------------------------------------------
# per-op parity vs ref autodiff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [3, 6, 9])
def test_apply_phase_grads_match_ref_autodiff(n):
    re, im, cutv = _state(n)
    w_re, w_im = _rand_cotangents(n)

    def loss_ops(re, im, cutv, gamma):
        ore, oim = ops.apply_phase(re, im, cutv, gamma)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    def loss_ref(re, im, cutv, gamma):
        ore, oim = ref.apply_phase(re, im, cutv, gamma)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    argnums = (0, 1, 2, 3)
    want = jax.grad(loss_ref, argnums)(re, im, cutv, 0.37)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(loss_ops, argnums)(re, im, cutv, jnp.float32(0.37))
    _assert_grads_close(got, want)


@pytest.mark.parametrize(
    "n,lo,k",
    [
        (5, 0, 3),  # trailing-axis matmul path (y == 1)
        (6, 0, 6),  # whole-register group
        (7, 2, 3),  # strided mid-state path (x > 1, y > 1)
        (8, 5, 3),  # leading bits (x == 1, y > 1)
    ],
)
def test_apply_mixer_bits_grads_match_ref_autodiff(n, lo, k):
    re, im, _ = _state(n)
    w_re, w_im = _rand_cotangents(n)

    def loss_ops(re, im, beta):
        ore, oim = ops.apply_mixer_bits(re, im, n, lo, k, beta)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    def loss_ref(re, im, beta):
        ore, oim = ref.apply_mixer_bits(re, im, n, lo, k, beta)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    argnums = (0, 1, 2)
    want = jax.grad(loss_ref, argnums)(re, im, 0.61)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(loss_ops, argnums)(re, im, jnp.float32(0.61))
    _assert_grads_close(got, want)


@pytest.mark.parametrize("n,group", [(4, 7), (6, 3), (6, 7)])
def test_apply_layer_grads_match_ref_autodiff(n, group):
    re, im, cutv = _state(n)
    w_re, w_im = _rand_cotangents(n)

    def loss_ops(re, im, cutv, gamma, beta):
        ore, oim = ops.apply_layer(re, im, cutv, gamma, beta, n, group=group)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    def loss_ref(re, im, cutv, gamma, beta):
        pre, pim = ref.apply_phase(re, im, cutv, gamma)
        ore, oim = ref.apply_mixer(pre, pim, n, beta, group=group)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    argnums = (0, 1, 2, 3, 4)
    want = jax.grad(loss_ref, argnums)(re, im, cutv, 0.37, 0.61)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(loss_ops, argnums)(
            re, im, cutv, jnp.float32(0.37), jnp.float32(0.61)
        )
    _assert_grads_close(got, want)


def test_apply_layer_grads_match_ref_under_xla_dispatch():
    """The vjp rules are impl-agnostic: the xla dispatch path runs the
    same analytic bwd (via ref kernels) and must agree with autodiff."""
    n, group = 6, 3
    re, im, cutv = _state(n)
    w_re, w_im = _rand_cotangents(n)

    def loss_ops(gamma, beta):
        ore, oim = ops.apply_layer(re, im, cutv, gamma, beta, n, group=group)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    def loss_ref(gamma, beta):
        pre, pim = ref.apply_phase(re, im, cutv, gamma)
        ore, oim = ref.apply_mixer(pre, pim, n, beta, group=group)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    want = jax.grad(loss_ref, (0, 1))(0.37, 0.61)
    with ops.using_implementation("xla"):
        got = jax.grad(loss_ops, (0, 1))(jnp.float32(0.37), jnp.float32(0.61))
    _assert_grads_close(got, want)


@pytest.mark.parametrize("n", [4, 8])
def test_expectation_grads_match_ref_autodiff(n):
    re, im, cutv = _state(n)
    want = jax.grad(ref.expectation, (0, 1, 2))(re, im, cutv)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(ops.expectation, (0, 1, 2))(re, im, cutv)
    _assert_grads_close(got, want)


@given(gamma=st.floats(-2.0, 2.0), beta=st.floats(-2.0, 2.0),
       seed=st.integers(0, 64))
@settings(max_examples=25, deadline=None)
def test_layer_angle_grads_property(gamma, beta, seed):
    """Property sweep over angles: d⟨loss⟩/d(γ,β) through the custom vjp
    matches ref autodiff for arbitrary angle values and states."""
    n = 5
    re, im, cutv = _state(n, seed=seed)
    w_re, w_im = _rand_cotangents(n, seed=seed + 1)

    def loss_ops(g, b):
        ore, oim = ops.apply_layer(re, im, cutv, g, b, n, group=7)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    def loss_ref(g, b):
        pre, pim = ref.apply_phase(re, im, cutv, g)
        ore, oim = ref.apply_mixer(pre, pim, n, b, group=7)
        return jnp.sum(w_re * ore) + jnp.sum(w_im * oim)

    want = jax.grad(loss_ref, (0, 1))(gamma, beta)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(loss_ops, (0, 1))(
            jnp.float32(gamma), jnp.float32(beta)
        )
    _assert_grads_close(got, want, atol=5e-5)


# ---------------------------------------------------------------------------
# end-to-end: the ascent's gradient runs under the active implementation
# ---------------------------------------------------------------------------


def _ref_qaoa_expectation(params, cutv, n):
    gammas, betas = params
    dim = 2**n
    amp = jnp.float32(1.0 / np.sqrt(dim))
    re = jnp.full((dim,), amp)
    im = jnp.zeros((dim,), jnp.float32)
    for g, b in zip(gammas, betas):
        re, im = ref.apply_phase(re, im, cutv, g)
        re, im = ref.apply_mixer(re, im, n, b, group=7)
    return ref.expectation(re, im, cutv)


def test_qaoa_expectation_grads_match_ref_end_to_end():
    from repro.core.qaoa import qaoa_expectation

    n, p = 5, 3
    _, _, cutv = _state(n)
    gammas = jnp.linspace(0.1, 0.5, p).astype(jnp.float32)
    betas = jnp.linspace(0.6, 0.2, p).astype(jnp.float32)

    want = jax.grad(_ref_qaoa_expectation)((gammas, betas), cutv, n)
    with ops.using_implementation("pallas_interpret"):
        got = jax.grad(qaoa_expectation)((gammas, betas), cutv, n)
    _assert_grads_close(got, want, atol=5e-5)


def test_optimize_params_gradient_trace_fires_pallas_kernels():
    """The de-pin proof: `optimize_params` (and therefore the ascent) no
    longer forces the xla reference path for gradients — under
    pallas_interpret the differentiated evolution launches the fused
    Pallas kernel on both the forward and the backward trace."""
    import repro.kernels.fused_layer as fused_mod
    from repro.core import qaoa as qaoa_mod

    calls = {"fwd": 0, "rev": 0}
    orig = fused_mod.fused_phase_mixer_group

    def spy(*a, **k):
        calls["rev" if k.get("reverse") else "fwd"] += 1
        return orig(*a, **k)

    fused_mod.fused_phase_mixer_group = spy
    try:
        n = 5
        _, _, cutv = _state(n)
        cfg = qaoa_mod.QAOAConfig(n_qubits=n, p_layers=2, opt_steps=3)
        with ops.using_implementation("pallas_interpret"):
            gammas, betas = qaoa_mod.optimize_params(cutv, n, cfg)
    finally:
        fused_mod.fused_phase_mixer_group = orig

    assert calls["fwd"] > 0, "forward trace never reached the fused kernel"
    assert calls["rev"] > 0, "backward trace never reached the fused kernel"
    assert np.all(np.isfinite(np.asarray(gammas)))
    assert np.all(np.isfinite(np.asarray(betas)))


def test_optimize_params_agrees_across_implementations():
    from repro.core import qaoa as qaoa_mod

    n = 5
    _, _, cutv = _state(n)
    cfg = qaoa_mod.QAOAConfig(n_qubits=n, p_layers=2, opt_steps=4)
    with ops.using_implementation("xla"):
        g_x, b_x = qaoa_mod.optimize_params(cutv, n, cfg)
    with ops.using_implementation("pallas_interpret"):
        g_i, b_i = qaoa_mod.optimize_params(cutv, n, cfg)
    np.testing.assert_allclose(np.asarray(g_i), np.asarray(g_x), atol=1e-4)
    np.testing.assert_allclose(np.asarray(b_i), np.asarray(b_x), atol=1e-4)


# ---------------------------------------------------------------------------
# forward bit-parity: the vjp wrapper may not perturb primal numerics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_forward_values_bit_identical_through_vjp_wrapper(impl):
    n = 6
    re, im, cutv = _state(n)
    g, b = jnp.float32(0.37), jnp.float32(0.61)
    with ops.using_implementation(impl):
        pairs = [
            (ops.apply_phase(re, im, cutv, g),
             ops._phase_dispatch(re, im, cutv, g)),
            (ops.apply_mixer_bits(re, im, n, 2, 3, b),
             ops._mixer_bits_dispatch(n, 2, 3, re, im, b)),
            (ops.apply_layer(re, im, cutv, g, b, n, group=3),
             ops._layer_dispatch(n, 3, re, im, cutv, g, b)),
            ((ops.expectation(re, im, cutv),),
             (ops._expectation_dispatch(re, im, cutv),)),
        ]
    for got, want in pairs:
        for a, bb in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


# ---------------------------------------------------------------------------
# tuning state: resolution, round-trip, committed cache validity
# ---------------------------------------------------------------------------


def test_tuning_helpers():
    assert tuning.round_up(5, 4) == 8
    assert tuning.round_up(8, 4) == 8
    assert tuning.clamp_tile(256, 1024) == 256
    assert tuning.clamp_tile(1024, 256) == 256
    assert tuning.pad_chunks(5, 8) == 8
    assert tuning.pad_chunks(100, 8) == 104
    assert tuning.pad_and_tile(100, 64) == (128, 64)
    assert tuning.shape_bucket(1024) == "2^10"
    assert tuning.shape_bucket(1000) == "2^10"
    assert tuning.shape_bucket(1025) == "2^11"


def test_tuning_param_resolution_and_state_roundtrip():
    key = tuning.cache_key("apply_phase", 4096)
    assert tuning.param("apply_phase", 4096, "tile", 512) == 512  # disabled
    with tuning.using_overrides({key: {"tile": 2048}}):
        assert tuning.param("apply_phase", 4096, "tile", 512) == 2048
        st_on = tuning.state()
    assert tuning.state() == ("off",)
    assert st_on[0] == "on"
    with tuning.using_state(st_on):
        assert tuning.param("apply_phase", 4096, "tile", 512) == 2048
        assert tuning.state() == st_on
    assert tuning.param("apply_phase", 4096, "tile", 512) == 512


def test_committed_tuning_cache_is_valid():
    path = tuning.CACHE_PATH
    assert os.path.exists(path), "committed tuning cache missing"
    with open(path) as f:
        payload = json.load(f)
    assert payload["version"] == 1
    entries = payload["entries"]
    assert entries, "tuning cache has no entries"
    for key, cfg in entries.items():
        op, bucket, backend = key.split("|")
        assert op in tuning.TUNABLE_OPS, key
        assert bucket.startswith("2^"), key
        assert backend, key
        allowed = set(tuning.TUNABLE_OPS[op])
        assert set(cfg) <= allowed, (key, cfg)
        for name, val in cfg.items():
            assert isinstance(val, int) and val >= 1, (key, name, val)


def test_tuned_tiles_preserve_kernel_numerics():
    """Tile overrides change the launch geometry, never the math: an
    elementwise op stays bit-identical, reductions stay allclose."""
    n = 6
    re, im, cutv = _state(n)
    dim = 2**n
    base_phase = ops.apply_phase(re, im, cutv, jnp.float32(0.37))
    base_exp = ops.expectation(re, im, cutv)
    overrides = {
        tuning.cache_key("apply_phase", dim): {"tile": 8},
        tuning.cache_key("expectation", dim): {"tile": 16},
    }
    with ops.using_implementation("pallas_interpret"), \
            tuning.using_overrides(overrides):
        tuned_phase = ops.apply_phase(re, im, cutv, jnp.float32(0.37))
        tuned_exp = ops.expectation(re, im, cutv)
    for a, b in zip(tuned_phase, base_phase):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(
        float(tuned_exp), float(base_exp), atol=1e-5)


# ---------------------------------------------------------------------------
# compile ledger: tuning state is real cache-key material
# ---------------------------------------------------------------------------


def test_tuning_state_keys_cached_programs_and_warm_rerun_is_free():
    from repro.core import qaoa as qaoa_mod
    from repro.obs.ledger import get_ledger

    cfg = qaoa_mod.QAOAConfig(n_qubits=4, p_layers=1, opt_steps=2)
    off = tuning.state()
    with tuning.using_overrides(
            {tuning.cache_key("apply_phase", 16): {"tile": 8}}):
        on = tuning.state()
    assert on != off

    led = get_ledger()
    led.reset()
    p_off = qaoa_mod._solve_subgraph_batch_program(cfg, "pallas_interpret",
                                                   off)
    p_on = qaoa_mod._solve_subgraph_batch_program(cfg, "pallas_interpret", on)
    assert p_off is not p_on, "tuning state must key the program cache"
    assert led.count("build") == 2
    assert any(repr(on) in e.key for e in led.builds), (
        "tuning state must be visible in the ledger's build keys")

    # warm re-run: same cfg/impl/state → zero rebuilds, zero compiles
    led.reset()
    assert qaoa_mod._solve_subgraph_batch_program(
        cfg, "pallas_interpret", off) is p_off
    assert qaoa_mod._solve_subgraph_batch_program(
        cfg, "pallas_interpret", on) is p_on
    assert led.count("build") == 0
    assert led.count("compile") == 0

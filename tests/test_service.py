"""Solve service (DESIGN.md §6): cross-request batching parity with solo
`core.solve`, SLA planner monotonicity, result-cache behavior, and the
anytime merge stream."""

import numpy as np
import pytest

from repro.core import ParaQAOAConfig, solve
from repro.core.graph import Graph
from repro.service import (
    SLA,
    CostModel,
    KnobTuple,
    Planner,
    ResultCache,
    ServiceConfig,
    SolveService,
    edge_capacity,
    quality_score,
)


def _cfg_from_result(r) -> ParaQAOAConfig:
    return r.plan.to_config()


# --------------------------------------------------------------- scheduler --
def test_batched_service_bit_identical_to_solo_solve():
    """The §6.1 parity contract at >= 4 concurrent requests: cross-request
    packing into fixed-shape buckets must not change any request's answer
    relative to `core.solve` on the same knobs."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8,
                                     enable_cache=False))
    graphs = [Graph.erdos_renyi(n, 0.3, seed=s)
              for s, n in enumerate((18, 25, 21, 30))]
    sla = SLA(deadline_s=30.0)
    rids = [svc.submit(g, sla) for g in graphs]
    res = svc.drain()
    assert len(res) == 4 and svc.stats.completed == 4
    for g, rid in zip(graphs, rids):
        r = res[rid]
        solo = solve(g, _cfg_from_result(r))
        assert r.cut_value == solo.cut_value, (rid, r.cut_value, solo.cut_value)
        np.testing.assert_array_equal(r.assignment, solo.assignment)
    assert svc.stats.slots_filled > 4  # more subgraphs than requests


def test_batches_pack_across_requests():
    svc = SolveService(ServiceConfig(batch_slots=16, max_qubits=8,
                                     enable_cache=False))
    graphs = [Graph.erdos_renyi(24, 0.3, seed=s) for s in range(4)]
    for g in graphs:
        svc.submit(g, SLA(deadline_s=30.0))
    total_subgraphs = sum(
        len(req.part.subgraphs) for req in svc._active.values()
    )
    svc.drain()
    assert total_subgraphs > svc.config.batch_slots // 2
    # 4 requests' subgraphs fit far fewer dispatches than requests x rounds
    assert svc.stats.dispatches <= -(-total_subgraphs // svc.config.batch_slots) + 1
    assert svc.stats.fill_ratio > 0.5


def test_edge_capacity_covers_any_subgraph():
    for nq in (4, 6, 10):
        assert edge_capacity(nq) == nq * (nq - 1) // 2


# ----------------------------------------------------------------- planner --
def test_tighter_deadline_never_selects_slower_knobs():
    """Acceptance: for any decreasing deadline sequence the predicted time
    of the selected knob tuple is non-increasing."""
    planner = Planner(max_qubits=12)
    for n, e in ((50, 180), (200, 1200), (1000, 10000)):
        prev = None
        for deadline in (300.0, 60.0, 20.0, 5.0, 1.0, 0.1, 0.001):
            plan = planner.plan(n, e, SLA(deadline_s=deadline))
            t = plan.predicted.total_s
            if prev is not None:
                assert t <= prev + 1e-12, (n, deadline, t, prev)
            prev = t


def test_planner_respects_feasible_deadline():
    planner = Planner(max_qubits=12)
    plan = planner.plan(100, 500, SLA(deadline_s=60.0))
    assert plan.meets_deadline
    assert plan.predicted.total_s <= 60.0


def test_planner_quality_target_met_at_min_cost():
    planner = Planner(max_qubits=12)
    free = planner.plan(80, 400, SLA())
    target = quality_score(KnobTuple(10, 2, 12, 128))
    tight = planner.plan(80, 400, SLA(deadline_s=1e6, target_quality=target))
    assert tight.meets_quality and tight.quality >= target
    # meeting a target costs no more than unconstrained max-quality
    assert tight.predicted.total_s <= free.predicted.total_s + 1e-12


def test_planner_unconstrained_maximizes_quality():
    planner = Planner(max_qubits=12)
    plan = planner.plan(60, 300, SLA())
    assert plan.quality == max(quality_score(kn) for kn in planner.grid)


def test_cost_model_fit_from_bench_rows():
    knobs = KnobTuple(n_qubits=10, top_k=1, opt_steps=12, beam_width=64)
    rows = [
        {"mode": "single", "n": 1000, "partition_s": 0.03, "solve_s": 5.0,
         "merge_s": 1.2, "m": 112},
        {"mode": "single", "n": 2000, "partition_s": 0.08, "solve_s": 7.6,
         "merge_s": 0.97, "m": 223},
    ]
    cm = CostModel.fit(rows, knobs)
    pred = cm.predict(1000, int(0.02 * 1000 * 999 / 2), knobs)
    # fitted model lands within 3x of the training rows (median fit over
    # two instances; this is a sanity band, not a regression bound)
    assert 0.3 < pred.solve_s / 5.0 < 3.0
    assert pred.total_s > 0


def test_cost_model_missing_file_falls_back_to_defaults():
    cm = CostModel.from_bench_file("/nonexistent/BENCH.json")
    assert cm.predict(100, 500, KnobTuple(8, 2, 12, 128)).total_s > 0


# ------------------------------------------------------------------- cache --
from repro.service.workload import relabel as _relabel  # noqa: E402


def test_cache_replays_onto_relabeled_instance():
    g = Graph.erdos_renyi(20, 0.4, seed=1)
    out = solve(g, ParaQAOAConfig(n_qubits=8, top_k=2, opt_steps=10))
    cache = ResultCache(capacity=4)
    cache.store(g, out.assignment, out.cut_value, quality=1.0)
    perm = np.random.default_rng(0).permutation(20).astype(np.int32)
    hit = cache.lookup(_relabel(g, perm), min_quality=1.0)
    assert hit is not None
    _, cut = hit
    assert cut == pytest.approx(out.cut_value)
    assert cache.stats.hits == 1 and cache.stats.verify_failures == 0


def test_cache_quality_gate():
    g = Graph.erdos_renyi(15, 0.4, seed=2)
    cache = ResultCache(capacity=4)
    cache.store(g, np.zeros(15, dtype=np.int8), 0.0, quality=1.0)
    assert cache.lookup(g, min_quality=2.0) is None  # cached too cheap
    assert cache.stats.quality_misses == 1
    assert cache.lookup(g, min_quality=0.5) is not None


def test_cache_lru_eviction_order():
    cache = ResultCache(capacity=2)
    graphs = [Graph.erdos_renyi(10, 0.5, seed=s) for s in (10, 11, 12)]
    for g in graphs[:2]:
        cache.store(g, np.zeros(10, dtype=np.int8), 0.0)
    assert cache.lookup(graphs[0]) is not None  # touch 0: now 1 is LRU
    cache.store(graphs[2], np.zeros(10, dtype=np.int8), 0.0)
    assert len(cache) == 2 and cache.stats.evictions == 1
    assert cache.lookup(graphs[1]) is None  # evicted
    assert cache.lookup(graphs[0]) is not None  # survived the eviction


def test_cache_never_downgrades_entry():
    g = Graph.erdos_renyi(12, 0.5, seed=3)
    out = solve(g, ParaQAOAConfig(n_qubits=8, top_k=2, opt_steps=15))
    cache = ResultCache(capacity=4)
    cache.store(g, out.assignment, out.cut_value, quality=5.0)
    cache.store(g, np.zeros(12, dtype=np.int8), 0.0, quality=1.0)
    _, cut = cache.lookup(g, min_quality=5.0)
    assert cut == pytest.approx(out.cut_value)


def test_service_serves_isomorphic_repeat_from_cache():
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8))
    g = Graph.erdos_renyi(22, 0.3, seed=4)
    rid0 = svc.submit(g, SLA(deadline_s=30.0))
    svc.drain()
    perm = np.random.default_rng(1).permutation(22).astype(np.int32)
    rid1 = svc.submit(_relabel(g, perm), SLA(deadline_s=30.0))
    r0, r1 = svc.results[rid0], svc.results[rid1]
    assert not r0.cached and r1.cached
    assert r1.cut_value == pytest.approx(r0.cut_value)
    assert svc.stats.cache_served == 1


def test_concurrent_isomorphic_requests_coalesce():
    """Isomorphic twins admitted *before* their primary has solved must
    still be served from the cache at the primary's merge, not solved
    redundantly — the cache works under concurrent load, not just for
    sequential repeats."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8))
    g = Graph.erdos_renyi(22, 0.3, seed=6)
    rng = np.random.default_rng(2)
    sla = SLA(deadline_s=30.0)
    rid0 = svc.submit(g, sla)
    twins = [
        svc.submit(_relabel(g, rng.permutation(22).astype(np.int32)), sla)
        for _ in range(2)
    ]
    svc.drain()
    r0 = svc.results[rid0]
    assert not r0.cached
    for rid in twins:
        r = svc.results[rid]
        assert r.cached
        assert r.cut_value == pytest.approx(r0.cut_value)
    assert svc.stats.cache_served == 2
    assert svc.cache.stats.hits == 2  # served via a real cache lookup


# ----------------------------------------------------------------- anytime --
def test_anytime_stream_monotone_and_final_matches_default():
    g = Graph.erdos_renyi(40, 0.3, seed=5)
    sla = SLA(deadline_s=30.0)
    updates = []
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8,
                                     enable_cache=False))
    rid = svc.submit(g, sla, stream=True,
                     on_update=lambda *u: updates.append(u))
    svc.drain()
    r = svc.results[rid]
    assert r.anytime, "streamed request recorded no anytime updates"
    best = [u[2] for u in r.anytime]
    assert all(a <= b for a, b in zip(best, best[1:])), best
    assert len(updates) == len(r.anytime)
    n_levels = r.anytime[0][1]
    assert [u[0] for u in r.anytime] == list(range(1, n_levels + 1))
    # the stream's final best-known cut is the request's result
    assert r.cut_value == pytest.approx(best[-1])
    # and the assignment really achieves it
    from repro.core.graph import cut_value as cv
    import jax.numpy as jnp

    assert float(cv(g, jnp.asarray(r.assignment))) == pytest.approx(r.cut_value)


def test_streamed_cache_hit_still_fires_one_update():
    """A streaming request served from cache must still honor the anytime
    contract: exactly one (final) update instead of silence."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=8))
    g = Graph.erdos_renyi(20, 0.3, seed=7)
    sla = SLA(deadline_s=30.0)
    svc.submit(g, sla)
    svc.drain()
    updates = []
    rid = svc.submit(g, sla, stream=True,
                     on_update=lambda *u: updates.append(u))
    r = svc.results[rid]
    assert r.cached
    assert r.anytime == [(1, 1, r.cut_value)]
    assert updates == [(rid, 1, 1, r.cut_value)]


# ----------------------------------------------------- online recalibration --
def _assert_deadline_monotone(planner):
    """Tightening the deadline never selects a slower-predicted tuple —
    evaluated against the planner's *current* cost model."""
    for n, e in ((50, 180), (400, 3000)):
        prev = None
        for deadline in (300.0, 60.0, 5.0, 0.5, 0.01):
            t = planner.plan(n, e, SLA(deadline_s=deadline)).predicted.total_s
            if prev is not None:
                assert t <= prev + 1e-12, (n, deadline, t, prev)
            prev = t


def test_refit_with_zero_observations_is_noop_bit_for_bit():
    planner = Planner(max_qubits=12)
    before = planner.cost_model
    for _ in range(5):
        planner.plan(100, 500, SLA(deadline_s=10.0))
    assert planner.cost_model == before  # field-wise float equality
    assert planner.cost_model == planner.base_model
    assert planner.calibration.total == 0


def test_streaming_refit_blends_observations():
    planner = Planner(max_qubits=12, recalibrate_alpha=0.5)
    c0 = planner.cost_model.c_solve
    # a dispatch far slower than the fitted prior predicts
    planner.observe_solve(10, 2, 12, 16, seconds=50.0)
    assert planner.calibration.solve_obs == 1
    assert planner.cost_model.c_solve > c0
    assert planner.cost_model != planner.base_model
    # repeated identical observations converge c_solve to the implied
    # per-work-unit coefficient (exponentially weighted average)
    work = 16 * (12 + 1) * 2 * 2**10
    implied = (50.0 - planner.cost_model.c_dispatch) / work
    for _ in range(40):
        planner.observe_solve(10, 2, 12, 16, seconds=50.0)
    assert abs(planner.cost_model.c_solve - implied) < 0.05 * implied
    # the other stages stream too
    planner.observe_partition(1000, 9000, 0.5)
    planner.observe_merge(KnobTuple(10, 2, 12, 128), 40, 9000, 2.0)
    assert planner.calibration.partition_obs == 1
    assert planner.calibration.merge_obs == 1


def test_deadline_monotonicity_survives_streaming_refits():
    """The satellite acceptance property: monotonicity holds before,
    during, and after refits — including degenerate (zero-time) and
    extreme observations — because selection is structural over any
    non-negative coefficients."""
    planner = Planner(max_qubits=12)
    _assert_deadline_monotone(planner)  # before any refit
    planner.observe_solve(10, 2, 30, 16, seconds=50.0)
    planner.observe_partition(1000, 10000, 2.0)
    _assert_deadline_monotone(planner)  # mid-stream
    planner.observe_merge(KnobTuple(10, 2, 12, 128), 40, 5000, 9.0)
    for _ in range(10):
        planner.observe_solve(6, 2, 4, 16, seconds=0.0)  # degenerate
    planner.observe_merge(KnobTuple(12, 4, 30, 512), 3, 10, 1e4)  # extreme
    _assert_deadline_monotone(planner)  # after
    cm = planner.cost_model
    assert min(cm.c_partition, cm.c_solve, cm.c_merge) >= 0.0


def test_scheduler_streams_stage_timings_into_planner():
    """Serving real requests recalibrates the live cost model: every
    stage records observations and the model moves off the fitted prior."""
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=6))
    g = Graph.erdos_renyi(24, 0.3, seed=31)
    svc.submit(g, SLA(deadline_s=30.0))
    svc.drain()
    cal = svc.planner.calibration
    assert cal.partition_obs >= 1
    assert cal.solve_obs >= 1
    assert cal.merge_obs >= 1
    assert svc.planner.cost_model != svc.planner.base_model
    assert svc.planner.base_model == Planner(
        max_qubits=6, batch_slots=8
    ).cost_model  # the prior itself never mutates


def test_recalibrate_off_freezes_cost_model():
    svc = SolveService(ServiceConfig(batch_slots=8, max_qubits=6,
                                     recalibrate=False))
    g = Graph.erdos_renyi(24, 0.3, seed=32)
    svc.submit(g, SLA(deadline_s=30.0))
    svc.drain()
    assert svc.planner.calibration.total == 0
    assert svc.planner.cost_model == svc.planner.base_model


# ------------------------------------------------------------ mesh backend --
def test_mesh_backend_single_device_parity():
    """`MeshBackend` over a trivial 1-device `data` mesh (always
    constructible in-process) must stay bit-identical to the local
    backend; the real multi-device parity runs in
    tests/test_distributed.py::test_service_mesh_backend_parity."""
    import jax

    from repro.service import MeshBackend
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    graphs = [Graph.erdos_renyi(n, 0.3, seed=s)
              for s, n in enumerate((20, 26))]
    sla = SLA(deadline_s=30.0)

    def run(backend):
        svc = SolveService(
            ServiceConfig(batch_slots=8, max_qubits=6, enable_cache=False,
                          recalibrate=False),
            backend=backend,
        )
        rids = [svc.submit(g, sla) for g in graphs]
        svc.drain()
        return [svc.results[r] for r in rids]

    local = run(None)
    meshed = run(MeshBackend(mesh))
    for a, b in zip(local, meshed):
        assert a.cut_value == b.cut_value
        np.testing.assert_array_equal(a.assignment, b.assignment)


def test_mesh_backend_rejects_model_only_mesh():
    import jax
    from jax.sharding import Mesh

    from repro.service import MeshBackend

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError):
        MeshBackend(mesh)

"""Shared benchmark helpers: consistent graph generation, timing, CSV and
BENCH_*.json emission."""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core.graph import Graph

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def er_graph(n: int, p: float, seed: int = 0) -> Graph:
    return Graph.erdos_renyi(n, p, seed=seed)


def timed(fn, *args, repeats: int = 1, **kwargs):
    """(result, seconds). Blocks on jax arrays."""
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kwargs)
        jax.block_until_ready(
            [x for x in jax.tree.leaves(result) if hasattr(x, "block_until_ready")]
        ) if jax.tree.leaves(result) else None
        best = min(best, time.perf_counter() - t0)
    return result, best


def emit(rows, header=None):
    """Print rows as `name,us_per_call,derived` CSV (spec format)."""
    for r in rows:
        name = r["name"]
        us = r.get("us_per_call", r.get("runtime_s", 0) * 1e6)
        derived = r.get("derived", "")
        print(f"{name},{us:.1f},{derived}")


def write_bench_json(name: str, rows, out_dir: str = RESULTS_DIR) -> str:
    """Persist benchmark rows as results/BENCH_<name>.json.

    One file per suite, overwritten on re-run — the committed record of
    "measured, not just claimed" for perf assertions (e.g. the
    faithful-vs-alternating collective schedules of sharded_qaoa).
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {
                "suite": name,
                "jax_version": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "rows": rows,
            },
            f,
            indent=1,
            default=str,
        )
    return path

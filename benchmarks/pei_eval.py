"""Paper Figs. 13-14: Performance Efficiency Index across methods/scales,
GW as the medium-scale baseline (Fig 13), QAOA2 as the large-scale
baseline (Fig 14)."""

from __future__ import annotations

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import goemans_williamson, qaoa_in_qaoa
from repro.core.pei import pei


def run(sizes=(60, 120), probs=(0.1, 0.5), seed: int = 0):
    rows = []
    for p in probs:
        for n in sizes:
            g = er_graph(n, p, seed=seed)
            _, v_gw, rep_gw = goemans_williamson(g, steps=250, rounds=64)
            _, v_q2, rep_q2 = qaoa_in_qaoa(g, n_qubits=10, opt_steps=25)
            out = solve(
                g, ParaQAOAConfig(n_qubits=10, top_k=2, p_layers=3, opt_steps=25)
            )
            # Fig 13 protocol: GW is the AR + EF baseline, alpha=1e-3
            pei_q2 = pei(v_q2, v_gw, rep_q2.runtime_s, rep_gw.runtime_s)
            pei_para = pei(
                out.cut_value, v_gw, out.report.runtime_s, rep_gw.runtime_s
            )
            # Fig 14 protocol: QAOA2 as baseline, alpha=1e-4
            pei_para_vs_q2 = pei(
                out.cut_value, v_q2, out.report.runtime_s, rep_q2.runtime_s,
                alpha=1e-4,
            )
            rows.append(
                {
                    "name": f"pei/n{n}/p{p}",
                    "runtime_s": out.report.runtime_s,
                    "derived": (
                        f"PEI_qaoa2={pei_q2:.1f};PEI_paraqaoa={pei_para:.1f};"
                        f"PEI_para_vs_q2={pei_para_vs_q2:.1f}"
                    ),
                    "pei_q2": pei_q2,
                    "pei_para": pei_para,
                }
            )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

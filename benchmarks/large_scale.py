"""Paper Fig. 12 (large-scale scalability): 1,000-16,000 vertices.

CPU-scaled: edge probability lowered so the single-core container handles
the edge volume; the paper's 16k-vertex headline instance runs end to end
(see examples/solve_16k.py for the full-size driver)."""

from __future__ import annotations

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve


def run(sizes=(1000, 2000, 4000), p: float = 0.02, seed: int = 0,
        n_qubits: int = 10, opt_steps: int = 12):
    rows = []
    for n in sizes:
        g = er_graph(n, p, seed=seed)
        out = solve(
            g,
            ParaQAOAConfig(
                n_qubits=n_qubits, top_k=1, p_layers=2, opt_steps=opt_steps,
                beam_width=64,
            ),
        )
        rows.append(
            {
                "name": f"large/n{n}/p{p}",
                "runtime_s": out.report.runtime_s,
                "derived": (
                    f"cut={out.cut_value:.0f};m={out.partition.m};"
                    f"edges={g.n_edges}"
                ),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Paper Fig. 12 (large-scale scalability): 1,000-16,000 vertices.

CPU-scaled: edge probability lowered so the single-core container handles
the edge volume; the paper's 16k-vertex headline instance runs end to end
(see examples/solve_16k.py for the full-size driver).

`run_distributed` (``python benchmarks/large_scale.py --distributed``)
compares single-device vs pool-parallel *stage* timings on the same
instances, through the `solve_distributed` pipeline on emulated host
devices, and persists the comparison as results/BENCH_distributed.json
(schema: docs/EXPERIMENTS.md). On CPU emulation all shards share one
physical core, so wall-clock parity — not speedup — is the expected
outcome; the row's per-stage split is the quantity the paper's Fig. 12
scales with device count."""

from __future__ import annotations

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve


def run(sizes=(1000, 2000, 4000), p: float = 0.02, seed: int = 0,
        n_qubits: int = 10, opt_steps: int = 12):
    rows = []
    for n in sizes:
        g = er_graph(n, p, seed=seed)
        out = solve(
            g,
            ParaQAOAConfig(
                n_qubits=n_qubits, top_k=1, p_layers=2, opt_steps=opt_steps,
                beam_width=64,
            ),
        )
        rows.append(
            {
                "name": f"large/n{n}/p{p}",
                "runtime_s": out.report.runtime_s,
                "derived": (
                    f"cut={out.cut_value:.0f};m={out.partition.m};"
                    f"edges={g.n_edges}"
                ),
            }
        )
    return rows


def run_distributed(sizes=(1000, 2000), p: float = 0.02, seed: int = 0,
                    n_qubits: int = 10, opt_steps: int = 12,
                    data: int = 2, save: bool = True):
    """Single-device vs pool-parallel stage timings on the same instances.

    Requires >= `data` devices (real, or CPU host-device emulation — the
    __main__ entry arranges it). Each instance solves twice with identical
    configs; the distributed row records mesh/merge metadata so the JSON
    is self-describing.
    """
    from repro import compat
    from repro.core import solve_distributed

    rows = []
    cfg_kw = dict(n_qubits=n_qubits, top_k=1, p_layers=2,
                  opt_steps=opt_steps, beam_width=64)
    if compat.device_count() < data:
        print(f"# skip distributed suite: {compat.device_count()} devices "
              f"< data={data}")
        return rows
    mesh_spec = {"data": data}
    for n in sizes:
        g = er_graph(n, p, seed=seed)
        single = solve(g, ParaQAOAConfig(**cfg_kw))
        dist = solve_distributed(g, ParaQAOAConfig(**cfg_kw), mesh_spec)
        for label, out in (("single", single), ("pool", dist)):
            row = {
                "name": f"distributed/{label}_n{n}/p{p}",
                "runtime_s": out.report.runtime_s,
                "derived": f"cut={out.cut_value:.0f};m={out.partition.m}",
                "mode": label,
                "n": n,
                "cut": out.cut_value,
                **{k: v for k, v in out.timings.items()},
            }
            if label == "pool":
                row["mesh"] = out.report.extra["mesh"]
                row["merge_shards"] = out.report.extra["merge_shards"]
                row["merge_mode"] = out.report.extra["merge_mode"]
            rows.append(row)
        rows.append({
            "name": f"distributed/stage_speedup_n{n}",
            "runtime_s": 0.0,
            "derived": (
                f"solve={single.timings['solve_s'] / max(dist.timings['solve_s'], 1e-9):.3f}x;"
                f"merge={single.timings['merge_s'] / max(dist.timings['merge_s'], 1e-9):.3f}x;"
                f"cut_equal={abs(single.cut_value - dist.cut_value) < 0.5}"
            ),
            "n": n,
        })
    if save and rows:
        from benchmarks.common import write_bench_json

        path = write_bench_json("distributed", rows)
        print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import emit

    if "--distributed" in sys.argv:
        # emulation only for the multi-device suite (kernel_bench pattern):
        # forcing extra host devices would distort single-device timings
        from repro import compat

        compat.ensure_host_device_count(2)
        emit(run_distributed())
    else:
        emit(run())

"""Observability overhead bench (DESIGN.md §8): what tracing costs.

The §8 contract is that tracing is observation-only — enabling a
recording tracer must not perturb a single scheduling decision (the
bit-determinism tests in tests/test_obs.py) *and* must cost under 5% of
soak wall time (the overhead claim gated here). The same seed-stable
open-loop arrival trace replays under a `VirtualClock` twice per
repeat — tracing off, then tracing on — and the best-of-N wall times
are compared. Virtual time pins the *work* (verdicts, dispatch
schedule, solve batches are a pure function of the trace), so the wall
ratio isolates the tracer's bookkeeping.

The compile-ledger row records the §8 cold/warm contract: the first
soak in the process bills every cached-program build; a warm re-run
after `ledger.reset()` must record zero build *and* zero compile
events (the PR 7 warm-up problem, now a measurable quantity).

Writes `results/BENCH_obs.json`:

  obs/soak_off        untraced soak wall time (best of N)
  obs/soak_on         traced soak wall time + span count
  obs/overhead        overhead_ratio with the committed <= 1.05 claim
  obs/compile_ledger  cold builds/compiles vs the zero warm re-run

`--smoke` is the tiny CI variant; `--trace-out` / `--metrics-out`
export the final traced soak's spans and metrics for downstream
validation (`python -m repro.obs.validate`).
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, write_bench_json
from repro.obs import Tracer, get_ledger
from repro.service import (
    CostModel,
    KnobTuple,
    Planner,
    ServiceConfig,
    SolveService,
    VirtualClock,
    arrival_trace,
    run_soak_virtual,
)

# the tests/test_service_sla.py soak lattice: one qubit budget, knob
# spread wide enough that keep/downgrade/shed verdicts all occur
SOAK_GRID = tuple(
    KnobTuple(n_qubits=6, top_k=k, opt_steps=t, beam_width=w)
    for k in (1, 2)
    for t in (4, 12, 30)
    for w in (16, 64)
)
FLOOR_Q = 7.0
OVERHEAD_BOUND = 1.05  # tracing-on wall time within 5% of tracing-off


def _service(slots, inflight, record):
    clock = VirtualClock()
    planner = Planner(
        cost_model=CostModel(c_solve=3e-5, c_dispatch=2e-2, c_merge=5e-8,
                             c_merge_base=1e-3, batch_slots=slots),
        grid=SOAK_GRID, batch_slots=slots,
    )
    tracer = Tracer(clock=clock, record=True) if record else None
    svc = SolveService(
        ServiceConfig(batch_slots=slots, max_qubits=6, max_inflight=inflight),
        planner=planner, clock=clock, tracer=tracer,
    )
    return svc, clock


def _soak_wall(requests, seed, record, slots=16, inflight=2):
    """One fresh-service soak; returns (svc, wall_seconds)."""
    svc, clock = _service(slots, inflight, record)
    trace = arrival_trace(
        requests, rate_rps=150.0, n_range=(4, 6), p=0.5, seed=seed,
        repeat_frac=0.5, tenants=3, deadline_choices=(1.0, 4.0),
        floor_choices=(None, FLOOR_Q),
    )
    t0 = time.perf_counter()
    rids = run_soak_virtual(svc, clock, trace, tick_s=0.02)
    wall = time.perf_counter() - t0
    assert len(rids) == len(trace)
    assert svc.stats.terminal == len(trace)
    return svc, wall


def run(requests=1000, repeats=3, seed=42, save=True,
        trace_out=None, trace_format="jsonl",
        metrics_out=None, metrics_format="json"):
    led = get_ledger()

    # cold pass: the process's first soak bills every program build and
    # per-shape compile into the ledger — and warms the caches for the
    # timing passes below (the PR 7 lesson: never time a compile storm)
    led.reset()
    _soak_wall(requests, seed, record=False)
    cold = led.snapshot()

    # warm re-run: caches intact, ledger cleared → must record nothing
    led.reset()
    _soak_wall(requests, seed, record=False)
    warm = led.snapshot()

    best_off = best_on = float("inf")
    svc_on = None
    for _ in range(repeats):
        _, w_off = _soak_wall(requests, seed, record=False)
        best_off = min(best_off, w_off)
        svc, w_on = _soak_wall(requests, seed, record=True)
        best_on = min(best_on, w_on)
        svc_on = svc

    ratio = best_on / best_off if best_off > 0 else float("inf")
    n_spans = len(svc_on.trace.spans)
    rows = [
        {
            "name": "obs/soak_off",
            "runtime_s": best_off,
            "derived": f"requests={requests};repeats={repeats}",
            "requests": requests,
            "repeats": repeats,
        },
        {
            "name": "obs/soak_on",
            "runtime_s": best_on,
            "derived": f"requests={requests};spans={n_spans}",
            "requests": requests,
            "spans": n_spans,
        },
        {
            "name": "obs/overhead",
            "runtime_s": best_on,
            "derived": (
                f"overhead_ratio={ratio:.4f};"
                f"overhead_bound={OVERHEAD_BOUND}"
            ),
            "overhead_ratio": round(ratio, 4),
            "overhead_bound": OVERHEAD_BOUND,
            "within_bound": bool(ratio <= OVERHEAD_BOUND),
        },
        {
            "name": "obs/compile_ledger",
            "runtime_s": cold["compile_s"],
            "derived": (
                f"cold_builds={cold['builds']};"
                f"cold_compiles={cold['compiles']};"
                f"warm_builds={warm['builds']};"
                f"warm_compiles={warm['compiles']}"
            ),
            "cold_builds": cold["builds"],
            "cold_compiles": cold["compiles"],
            "warm_builds": warm["builds"],
            "warm_compiles": warm["compiles"],
            "warm_zero": bool(warm["builds"] == 0 and warm["compiles"] == 0),
        },
    ]

    if trace_out:
        svc_on.trace.export(trace_out, trace_format)
        print(f"# trace ({trace_format}, {n_spans} spans): {trace_out}")
    if metrics_out:
        reg = svc_on.metrics_registry()
        with open(metrics_out, "w") as f:
            f.write(reg.to_json() if metrics_format == "json"
                    else reg.to_prometheus())
        print(f"# metrics ({metrics_format}): {metrics_out}")

    emit(rows)
    if save:
        path = write_bench_json("obs", rows)
        print(f"# wrote {path}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="benchmarks.obs_bench",
        description="Measure the §8 tracing overhead and the compile-"
        "ledger cold/warm contract on a virtual-clock service soak.",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI variant (fewer requests and repeats)")
    ap.add_argument("--requests", type=int, default=None,
                    help="soak length (default 1000; 200 under --smoke)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="best-of-N timing repeats (default 3; 2 smoke)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--no-save", action="store_true",
                    help="skip writing results/BENCH_obs.json")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH")
    ap.add_argument("--trace-format", choices=("jsonl", "chrome"),
                    default="jsonl")
    ap.add_argument("--metrics-out", type=str, default=None, metavar="PATH")
    ap.add_argument("--metrics-format", choices=("json", "prom"),
                    default="json")
    args = ap.parse_args(argv)
    requests = args.requests or (200 if args.smoke else 1000)
    repeats = args.repeats or (2 if args.smoke else 3)
    return run(
        requests=requests, repeats=repeats, seed=args.seed,
        save=not args.no_save,
        trace_out=args.trace_out, trace_format=args.trace_format,
        metrics_out=args.metrics_out, metrics_format=args.metrics_format,
    )


if __name__ == "__main__":
    main()

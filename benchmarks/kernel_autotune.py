"""Per-kernel block-shape autotune harness (§Perf C11).

Sweeps Pallas block/tile candidates per (op, shape-bucket) — always
including the kernel's hard-coded default, so the winning config is never
slower than the default by construction — and records achieved time vs
the `repro.roofline.analysis` single-kernel peak model. Winning configs
land in `src/repro/kernels/tuning_cache.json` (``--write-cache``), the
committed table `kernels.tuning` serves at trace time when tuning is
enabled; measured rows land in `results/BENCH_kernel_autotune.json`
(``--write``).

Off-TPU the sweep runs the kernels in Pallas interpret mode (recorded
honestly as ``mode=pallas_interpret``): grid-step count still dominates
interpreter wall-clock, so tile choice is measurable, but the committed
cache is keyed per backend — a TPU run writes separate `|tpu` entries.

All timing flows through `kernels.tuning.measure`, i.e. the injectable
`repro.obs.clock` boundary (the reprolint hot-nondeterminism contract).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import er_graph, write_bench_json
from repro.kernels import cutbatch, cutvals, fused_layer, mixer, phase, tuning
from repro.roofline.analysis import achieved_fraction, kernel_bound_s

SUITE = "kernel_autotune"


def _pow2_divisors(dim: int, lo: int = 1):
    t = lo
    out = []
    while t <= dim:
        if dim % t == 0:
            out.append(t)
        t *= 2
    return out


def _dedup(cands):
    seen, out = set(), []
    for c in cands:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def _sweep(op, dim, call, candidates, flops, nbytes, repeats, backend):
    """Time every candidate config (default first); returns the row dict
    plus the winning config for the cache writer."""
    results = []
    for cand in candidates:
        key = tuning.cache_key(op, dim, backend)
        with tuning.using_overrides({key: cand}):
            _, t = tuning.measure(call, repeats=repeats)
        results.append((t, cand))
    default_s = results[0][0]
    tuned_s, best = min(results, key=lambda r: r[0])
    bucket = tuning.shape_bucket(dim)
    bound = kernel_bound_s(flops, nbytes, backend)
    cfg_str = ";".join(f"{k}={v}" for k, v in sorted(best.items()))
    row = {
        "name": f"{SUITE}/{op}_{bucket}",
        "runtime_s": tuned_s,
        "op": op,
        "bucket": bucket,
        "mode": "pallas" if backend == "tpu" else "pallas_interpret",
        "default_s": default_s,
        "tuned_s": tuned_s,
        "speedup_vs_default": default_s / tuned_s if tuned_s else 1.0,
        "config": best,
        "candidates": len(candidates),
        "flops": flops,
        "bytes_accessed": nbytes,
        "model_bound_s": bound,
        "achieved_frac": achieved_fraction(flops, nbytes, tuned_s, backend),
        "derived": f"{cfg_str};default_s={default_s:.3e};bucket={bucket}",
    }
    return row, (tuning.cache_key(op, dim, backend), best)


def _state(n, seed=0):
    dim = 2**n
    key = jax.random.PRNGKey(seed)
    kr, kc = jax.random.split(key)
    re = jax.random.normal(kr, (dim,), jnp.float32)
    im = jnp.zeros((dim,), jnp.float32)
    cutv = jax.random.uniform(kc, (dim,), jnp.float32) * n
    return re, im, cutv


def sweep_all(dims, repeats: int):
    backend = jax.default_backend()
    interp = backend != "tpu"
    rows, entries = [], {}

    def record(row_entry):
        row, (key, cfg) = row_entry
        rows.append(row)
        entries[key] = cfg

    def swept(op, dim):
        # several qubit counts can hit one (op, shape-bucket) — e.g. the
        # trailing mixer group is (1, 2^k, 128) for every n ≥ 14 — so
        # skip re-sweeping a cache key that already has a winner
        return tuning.cache_key(op, dim, backend) in entries

    for n in dims:
        dim = 2**n
        re, im, cutv = _state(n)

        tiles = [min(phase.TILE, dim)] + _pow2_divisors(dim, lo=min(128, dim))
        record(_sweep(
            "apply_phase", dim,
            lambda: phase.apply_phase(re, im, cutv, 0.37, interpret=interp),
            _dedup([{"tile": t} for t in tiles]),
            flops=8.0 * dim, nbytes=20.0 * dim,
            repeats=repeats, backend=backend,
        ))
        record(_sweep(
            "expectation", dim,
            lambda: phase.expectation(re, im, cutv, interpret=interp),
            _dedup([{"tile": t} for t in tiles]),
            flops=4.0 * dim, nbytes=12.0 * dim,
            repeats=repeats, backend=backend,
        ))

        # trailing-axis mixer group + the fused layer share geometry
        k = min(7, n)
        dk = 2**k
        r = dim // dk
        re_m, im_m = re.reshape(r, dk), im.reshape(r, dk)
        cv_m = cutv.reshape(r, dk)
        rtiles = [min(mixer.ROW_TILE, r)] + _pow2_divisors(r)
        record(_sweep(
            "mixer_matmul", r,
            lambda: mixer.mixer_group_matmul(re_m, im_m, 0.7, k,
                                             interpret=interp),
            _dedup([{"row_tile": t} for t in rtiles]),
            flops=8.0 * r * dk * dk, nbytes=16.0 * r * dk,
            repeats=repeats, backend=backend,
        ))
        record(_sweep(
            "fused_layer", r,
            lambda: fused_layer.fused_phase_mixer_group(
                re_m, im_m, cv_m, 0.37, 0.7, k, interpret=interp),
            _dedup([{"row_tile": t} for t in rtiles]),
            flops=8.0 * r * dk * dk + 8.0 * r * dk, nbytes=20.0 * r * dk,
            repeats=repeats, backend=backend,
        ))

        # mid-state mixer group (lo_bit=7): the strided kernel's shape
        if n >= 9:
            k2 = min(7, n - 7)
            x, y = 2 ** (n - 7 - k2), 2**7
            re3 = re.reshape(x, 2**k2, y)
            im3 = im.reshape(x, 2**k2, y)
            cands = [{"tile_x": min(mixer.X_TILE, x),
                      "tile_y": min(mixer.Y_TILE, y)}]
            cands += [{"tile_x": tx, "tile_y": ty}
                      for tx in _pow2_divisors(x)
                      for ty in _pow2_divisors(y, lo=min(32, y))]
            if not swept("mixer_strided", x * y):
                record(_sweep(
                    "mixer_strided", x * y,
                    lambda: mixer.mixer_group_strided(re3, im3, 0.7, k2,
                                                      interpret=interp),
                    _dedup(cands),
                    flops=8.0 * x * y * (2**k2) ** 2,
                    nbytes=16.0 * dim,
                    repeats=repeats, backend=backend,
                ))

        # relayout fusion: strided in-kernel contraction vs the old
        # moveaxis-to-trailing-axis path, both under default tiles. Use a
        # mid-state group with a real leading axis (x = 16) — that is the
        # large-n regime the fusion targets; the trailing-group x = 1
        # shapes have almost no relayout to elide and just measure noise.
        if n >= 12:
            k_r = min(7, n - 11)
            fused_fn = jax.jit(lambda a, b: mixer.apply_mixer_bits(
                a, b, n, 7, k_r, 0.7, interpret=interp))
            unfused_fn = jax.jit(lambda a, b: mixer.apply_mixer_bits_relayout(
                a, b, n, 7, k_r, 0.7, interpret=interp))
            # the two paths differ by tens of microseconds here, so use
            # enough repeats that best-of-N converges below that spread
            rr = max(repeats, 9)
            _, t_fused = tuning.measure(fused_fn, re, im, repeats=rr)
            _, t_unf = tuning.measure(unfused_fn, re, im, repeats=rr)
            bucket = tuning.shape_bucket(dim)
            rows.append({
                "name": f"{SUITE}/mixer_relayout_{bucket}",
                "runtime_s": t_fused,
                "op": "mixer_relayout",
                "bucket": bucket,
                "mode": "pallas" if backend == "tpu" else "pallas_interpret",
                "fused_s": t_fused,
                "unfused_s": t_unf,
                "relayout_speedup": t_unf / t_fused if t_fused else 1.0,
                "fused_ge_unfused": bool(t_fused <= t_unf),
                "derived": f"fused_s={t_fused:.3e};unfused_s={t_unf:.3e}",
            })

        # cutvals over the same dim; cutvals_at over a candidate slice
        g = er_graph(n, 0.5, seed=3)
        edges = jnp.asarray(g.edges, jnp.int32)
        weights = jnp.asarray(g.weights, jnp.float32)
        e = int(edges.shape[0])
        cv_cands = [{"tile_b": min(cutvals.TILE_B, dim),
                     "edge_chunk": cutvals.EDGE_CHUNK}]
        cv_cands += [{"tile_b": t, "edge_chunk": cutvals.EDGE_CHUNK}
                     for t in _pow2_divisors(dim, lo=min(256, dim))]
        cv_cands += [{"tile_b": min(cutvals.TILE_B, dim), "edge_chunk": c}
                     for c in (64, 128, 256, 512)]
        record(_sweep(
            "cutvals", dim,
            lambda: cutvals.cutvals(n, edges, weights, interpret=interp),
            _dedup(cv_cands),
            flops=2.0 * dim * e, nbytes=4.0 * dim + 12.0 * e,
            repeats=repeats, backend=backend,
        ))

        m = min(dim, 1024)
        idx = jnp.arange(m, dtype=jnp.int32)
        at_cands = [{"tile_b": min(cutvals.TILE_B, m),
                     "edge_chunk": cutvals.EDGE_CHUNK}]
        at_cands += [{"tile_b": t, "edge_chunk": cutvals.EDGE_CHUNK}
                     for t in _pow2_divisors(m, lo=min(128, m))]
        if not swept("cutvals_at", m):
            record(_sweep(
                "cutvals_at", m,
                lambda: cutvals.cutvals_at(idx, edges, weights,
                                           interpret=interp),
                _dedup(at_cands),
                flops=2.0 * m * e, nbytes=8.0 * m + 12.0 * e,
                repeats=repeats, backend=backend,
            ))

    # merge-phase batch scorer: one representative (B, V) shape
    bsz, v = 256, 512
    key = jax.random.PRNGKey(7)
    spins = jax.random.bernoulli(key, 0.5, (bsz, v)).astype(jnp.float32) * 2 - 1
    gg = er_graph(v, 0.05, seed=5)
    adj = jnp.asarray(gg.dense_adjacency(), jnp.float32)
    wtot = float(gg.weights.sum())
    cb_cands = [{"batch_tile": min(cutbatch.BATCH_TILE, bsz),
                 "k_chunk": min(cutbatch.K_CHUNK, v)}]
    cb_cands += [{"batch_tile": bt, "k_chunk": kc}
                 for bt in _pow2_divisors(bsz, lo=32)
                 for kc in _pow2_divisors(v, lo=128)]
    backend = jax.default_backend()
    record(_sweep(
        "cut_batch_dense", v,
        lambda: cutbatch.cut_batch_dense(spins, adj, wtot, interpret=interp),
        _dedup(cb_cands),
        flops=2.0 * bsz * v * v + 3.0 * bsz * v,
        nbytes=4.0 * (bsz * v + v * v + bsz),
        repeats=repeats, backend=backend,
    ))

    # summary: the tuned-vs-default acceptance claim (tuned config can
    # never lose — the default is in every candidate set)
    swept = [r for r in rows if "speedup_vs_default" in r]
    speedups = [r["speedup_vs_default"] for r in swept]
    rows.append({
        "name": f"{SUITE}/tuned_vs_default",
        "runtime_s": sum(r["tuned_s"] for r in swept),
        "ops_swept": len(swept),
        "tuned_ge_default": bool(all(s >= 1.0 for s in speedups)),
        "mean_speedup": sum(speedups) / len(speedups) if speedups else 1.0,
        "max_speedup": max(speedups) if speedups else 1.0,
        "derived": f"ops={len(swept)};mean_speedup="
                   f"{sum(speedups) / len(speedups):.3f}",
    })
    return rows, entries


def write_cache(entries, path=tuning.CACHE_PATH):
    payload = {
        "version": 1,
        "generated_by": "benchmarks/kernel_autotune.py",
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "entries": {k: entries[k] for k in sorted(entries)},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    tuning.invalidate_committed()
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, nargs="*", default=None,
                    help="qubit counts to sweep (default: 10 12 14)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims, 1 repeat, no files unless asked")
    ap.add_argument("--write", action="store_true",
                    help="write results/BENCH_kernel_autotune.json")
    ap.add_argument("--write-cache", action="store_true",
                    help="write src/repro/kernels/tuning_cache.json")
    args = ap.parse_args()

    dims = args.n if args.n else ([8, 9] if args.smoke else [10, 12, 14])
    repeats = 1 if args.smoke and args.repeats == 3 else args.repeats

    rows, entries = sweep_all(dims, repeats)
    for r in rows:
        extra = (f" speedup={r['speedup_vs_default']:.2f}x {r['config']}"
                 if "config" in r else "")
        print(f"{r['name']},{r['runtime_s'] * 1e6:.1f}us{extra}")

    if args.write:
        print("wrote", write_bench_json(SUITE, rows))
    if args.write_cache:
        print("wrote", write_cache(entries))


if __name__ == "__main__":
    main()

"""Paper Table 2 (small-scale): runtime + AR vs brute-force optimum for
GW, QAOA² (CQ's niche: tiny graphs), local search, and ParaQAOA.

CPU-scaled: 14–20 vertices with a 10-qubit solver pool (the paper uses
20–26 vertices on 26-qubit GPU solvers; ratios, not absolutes, transfer).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import (
    brute_force_maxcut,
    goemans_williamson,
    local_search,
    qaoa_in_qaoa,
)


def run(sizes=(14, 16, 20), probs=(0.3, 0.5), seed: int = 0):
    rows = []
    for p in probs:
        for n in sizes:
            g = er_graph(n, p, seed=seed)
            _, opt, _ = brute_force_maxcut(g)
            if opt <= 0:
                continue

            _, v_gw, rep_gw = goemans_williamson(g, steps=200, rounds=64)
            _, v_q2, rep_q2 = qaoa_in_qaoa(g, n_qubits=10, opt_steps=25)
            _, v_ls, rep_ls = local_search(g, restarts=4, steps=120)
            out = solve(
                g,
                ParaQAOAConfig(n_qubits=10, top_k=3, p_layers=3, opt_steps=30),
            )

            for method, v, t in (
                ("gw", v_gw, rep_gw.runtime_s),
                ("qaoa2", v_q2, rep_q2.runtime_s),
                ("local_search", v_ls, rep_ls.runtime_s),
                ("paraqaoa", out.cut_value, out.report.runtime_s),
            ):
                rows.append(
                    {
                        "name": f"small/{method}/n{n}/p{p}",
                        "runtime_s": t,
                        "derived": f"AR={v / opt:.3f}",
                        "ar": v / opt,
                        "n": n,
                        "p": p,
                        "method": method,
                    }
                )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Kernel microbenchmarks: XLA reference path timings on CPU (the Pallas
path targets TPU; interpret mode is a correctness tool, not a timing one).
Derived column reports achieved GFLOP/s or GB/s on this host."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import er_graph, timed
from repro.kernels import ref


def run(n_qubits: int = 16, repeats: int = 3):
    rows = []
    g = er_graph(n_qubits, 0.5, seed=0)
    dim = 2**n_qubits

    cv = jax.jit(lambda e, w: ref.cutvals(n_qubits, e, w))
    _, t = timed(cv, g.edges, g.weights, repeats=repeats)
    rows.append({
        "name": "kernel/cutvals",
        "runtime_s": t,
        "derived": f"Melem_per_s={dim * g.n_edges / t / 1e6:.0f}",
    })

    key = jax.random.PRNGKey(0)
    re = jax.random.normal(key, (dim,), jnp.float32)
    im = jnp.zeros((dim,))
    c = jax.random.uniform(key, (dim,))

    ph = jax.jit(lambda r, i: ref.apply_phase(r, i, c, 0.3))
    _, t = timed(ph, re, im, repeats=repeats)
    rows.append({
        "name": "kernel/phase",
        "runtime_s": t,
        "derived": f"GBps={dim * 4 * 5 / t / 1e9:.2f}",
    })

    mx = jax.jit(lambda r, i: ref.apply_mixer(r, i, n_qubits, 0.7))
    _, t = timed(mx, re, im, repeats=repeats)
    flops = 4 * 2 * dim * 128 * (n_qubits / 7)
    rows.append({
        "name": "kernel/mixer",
        "runtime_s": t,
        "derived": f"GFLOPs={flops / t / 1e9:.2f}",
    })

    spins = jax.random.rademacher(key, (256, 512), jnp.float32) if hasattr(jax.random, "rademacher") else (jax.random.bernoulli(key, 0.5, (256, 512)).astype(jnp.float32) * 2 - 1)
    g2 = er_graph(512, 0.2, seed=1)
    adj = g2.dense_adjacency()
    cb = jax.jit(lambda s: ref.cut_batch_dense(s, adj, g2.total_weight()))
    _, t = timed(cb, spins, repeats=repeats)
    rows.append({
        "name": "kernel/cutbatch",
        "runtime_s": t,
        "derived": f"GFLOPs={2 * 256 * 512 * 512 / t / 1e9:.2f}",
    })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Kernel microbenchmarks: XLA reference path timings on CPU (the Pallas
path targets TPU; interpret mode is a correctness tool, not a timing one).
Derived column reports achieved GFLOP/s or GB/s on this host.

`run_schedules` measures the distributed-statevector collective schedules
(faithful 2-a2a/layer vs alternating 1-a2a/layer) on an emulated host
mesh — the measurement behind the optimization claimed in the
`sharded_qaoa` docstring."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import er_graph, timed, write_bench_json
from repro.kernels import ref


def run(n_qubits: int = 16, repeats: int = 3):
    rows = []
    g = er_graph(n_qubits, 0.5, seed=0)
    dim = 2**n_qubits

    cv = jax.jit(lambda e, w: ref.cutvals(n_qubits, e, w))
    _, t = timed(cv, g.edges, g.weights, repeats=repeats)
    rows.append({
        "name": "kernel/cutvals",
        "runtime_s": t,
        "derived": f"Melem_per_s={dim * g.n_edges / t / 1e6:.0f}",
    })

    key = jax.random.PRNGKey(0)
    re = jax.random.normal(key, (dim,), jnp.float32)
    im = jnp.zeros((dim,))
    c = jax.random.uniform(key, (dim,))

    ph = jax.jit(lambda r, i: ref.apply_phase(r, i, c, 0.3))
    _, t = timed(ph, re, im, repeats=repeats)
    rows.append({
        "name": "kernel/phase",
        "runtime_s": t,
        "derived": f"GBps={dim * 4 * 5 / t / 1e9:.2f}",
    })

    mx = jax.jit(lambda r, i: ref.apply_mixer(r, i, n_qubits, 0.7))
    _, t = timed(mx, re, im, repeats=repeats)
    flops = 4 * 2 * dim * 128 * (n_qubits / 7)
    rows.append({
        "name": "kernel/mixer",
        "runtime_s": t,
        "derived": f"GFLOPs={flops / t / 1e9:.2f}",
    })

    spins = jax.random.rademacher(key, (256, 512), jnp.float32) if hasattr(jax.random, "rademacher") else (jax.random.bernoulli(key, 0.5, (256, 512)).astype(jnp.float32) * 2 - 1)
    g2 = er_graph(512, 0.2, seed=1)
    adj = g2.dense_adjacency()
    cb = jax.jit(lambda s: ref.cut_batch_dense(s, adj, g2.total_weight()))
    _, t = timed(cb, spins, repeats=repeats)
    rows.append({
        "name": "kernel/cutbatch",
        "runtime_s": t,
        "derived": f"GFLOPs={2 * 256 * 512 * 512 / t / 1e9:.2f}",
    })
    return rows


def run_schedules(
    n_qubits: int = 14,
    axis_sizes=(4, 8),
    p_layers: int = 3,
    repeats: int = 10,
    save: bool = True,
):
    """Time sharded_qaoa's faithful vs alternating collective schedules.

    Requires a multi-device view (real, or CPU host-device emulation —
    see docs/TESTING.md); axis sizes larger than the visible device count
    are skipped with a note so the suite degrades gracefully.

    On emulated CPU devices an all_to_all is a local memcpy, so the
    1-vs-2 a2a/layer difference shows up as only a few percent of wall
    clock (the a2a_total column records the collective count halving —
    the quantity that matters on a real interconnect); treat the CPU
    numbers as a harness smoke-check, not the paper claim.
    """
    from repro import compat
    from repro.core import distributed as dist

    rows = []
    g = er_graph(n_qubits, 0.4, seed=3)
    gammas = jnp.linspace(0.2, 0.8, p_layers).astype(jnp.float32)
    betas = jnp.linspace(0.8, 0.2, p_layers).astype(jnp.float32)
    for d in axis_sizes:
        if compat.device_count() < d:
            print(f"# skip axis={d}: only {compat.device_count()} devices")
            continue
        mesh = compat.make_mesh((d,), ("model",))
        times = {}
        for schedule in ("faithful", "alternating"):
            def call():
                return dist.sharded_qaoa(
                    g.edges, g.weights, n_qubits, gammas, betas, mesh,
                    axis="model", top_k=4, schedule=schedule,
                )
            call()  # compile outside the timed region
            _, t = timed(call, repeats=repeats)
            times[schedule] = t
            a2a = (2 if schedule == "faithful" else 1) * p_layers
            rows.append({
                "name": f"schedules/sched_{schedule}_d{d}",
                "runtime_s": t,
                "derived": f"a2a_total={a2a}",
                "n_qubits": n_qubits,
                "p_layers": p_layers,
                "axis_size": d,
                "schedule": schedule,
            })
        if len(times) == 2:
            rows.append({
                "name": f"schedules/sched_speedup_d{d}",
                "runtime_s": 0.0,
                "derived": (
                    f"alt_vs_faithful={times['faithful'] / times['alternating']:.3f}x"
                ),
                "axis_size": d,
            })
    if save and rows:
        path = write_bench_json("schedules", rows)
        print(f"# wrote {path}")
    return rows


def run_sharded_engine(
    n_qubits: int = 14,
    axis_size: int = 4,
    p_layers: int = 2,
    opt_steps: int = 20,
    repeats: int = 5,
    save: bool = True,
):
    """Statevector-engine benchmark (DESIGN.md §2.6, §Perf C7).

    Two measurements:

    (a) fused vs unfused per-shard layer: one jitted `ops.apply_layer`
        program (phase fused into the mixer pipeline — the CPU-measurable
        form of the §Perf C3 fusion; on TPU the fused Pallas kernel fires
        on the same dispatch) vs separate phase/mixer programs with a
        statevector round trip between them.
    (b) opt-vs-ramp cut quality: `sharded_qaoa` at linear-ramp parameters
        vs `opt_steps` of the sharded Adam ascent on the same instance —
        the accuracy knob the engine unlocks for oversized subproblems.
        Asserts ⟨cut⟩_opt >= ⟨cut⟩_ramp before persisting.
    """
    import numpy as np

    from repro import compat
    from repro.core import distributed as dist
    from repro.core import qaoa as qaoa_mod
    from repro.kernels import ops, ref as ref_mod

    rows = []
    h = int(np.log2(axis_size))
    n_local = n_qubits - h
    dim = 2**n_local
    g_loc = er_graph(n_local, 0.4, seed=7)
    cutv = ref_mod.cutvals(n_local, g_loc.edges, g_loc.weights)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (dim,), jnp.float32) * 2.0 ** (-n_local / 2)
    im = jax.random.normal(k2, (dim,), jnp.float32) * 2.0 ** (-n_local / 2)

    gamma, beta = 0.4, 0.9
    phase_prog = jax.jit(lambda r, i: ref_mod.apply_phase(r, i, cutv, gamma))
    mixer_prog = jax.jit(lambda r, i: ref_mod.apply_mixer(r, i, n_local, beta))

    def unfused():
        r, i = phase_prog(re, im)
        return mixer_prog(r, i)  # separate program: state round-trips

    fused_prog = jax.jit(
        lambda r, i: ops.apply_layer(r, i, cutv, gamma, beta, n_local)
    )

    def fused():
        return fused_prog(re, im)

    unfused(), fused()  # compile outside the timed region
    _, t_unfused = timed(unfused, repeats=repeats)
    _, t_fused = timed(fused, repeats=repeats)
    bytes_moved = dim * 4 * 4  # two planes in + out, per pass
    rows.append({
        "name": f"sharded_engine/layer_unfused_n{n_local}",
        "runtime_s": t_unfused,
        "derived": f"GBps={2 * bytes_moved / t_unfused / 1e9:.2f}",
        "n_local": n_local,
    })
    rows.append({
        "name": f"sharded_engine/layer_fused_n{n_local}",
        "runtime_s": t_fused,
        "derived": f"GBps={bytes_moved / t_fused / 1e9:.2f}",
        "n_local": n_local,
    })
    rows.append({
        "name": "sharded_engine/layer_fusion_speedup",
        "runtime_s": 0.0,
        "derived": f"fused_vs_unfused={t_unfused / t_fused:.3f}x",
        "n_local": n_local,
    })

    quality_ran = False
    if compat.device_count() < axis_size:
        print(f"# skip opt-vs-ramp: only {compat.device_count()} devices")
    else:
        mesh = compat.make_mesh((axis_size,), ("model",))
        g_big = er_graph(n_qubits, 0.4, seed=3)
        gammas, betas = qaoa_mod.linear_ramp_init(p_layers, 0.75)
        results = {}
        for label, steps in (("ramp", 0), ("opt", opt_steps)):
            def call():
                return dist.sharded_qaoa(
                    g_big.edges, g_big.weights, n_qubits, gammas, betas,
                    mesh, top_k=4, opt_steps=steps,
                )
            res = call()  # compile outside the timed region
            _, t = timed(call, repeats=max(2, repeats // 2))
            exp = float(np.asarray(res.expectation).reshape(-1)[0])
            results[label] = exp
            rows.append({
                "name": f"sharded_engine/{label}_d{axis_size}",
                "runtime_s": t,
                "derived": f"exp={exp:.4f};opt_steps={steps}",
                "n_qubits": n_qubits,
                "axis_size": axis_size,
                "p_layers": p_layers,
            })
        assert results["opt"] >= results["ramp"], results
        rows.append({
            "name": f"sharded_engine/opt_vs_ramp_d{axis_size}",
            "runtime_s": 0.0,
            "derived": (
                f"exp_ramp={results['ramp']:.4f};exp_opt={results['opt']:.4f};"
                f"improvement={results['opt'] / results['ramp']:.4f}x"
            ),
            "opt_ge_ramp": True,
        })
        quality_ran = True

    if save and quality_ran:
        path = write_bench_json("sharded_engine", rows)
        print(f"# wrote {path}")
    elif save:
        # don't clobber the committed record with a quality-less partial
        # file (tests/test_bench_schema.py asserts the opt_vs_ramp row)
        print("# skip save: opt-vs-ramp rows missing "
              f"(need >= {axis_size} devices)")
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import emit

    if "--schedules" in sys.argv:
        # emulation only for the multi-device suite: forcing 8 devices
        # would distort the single-device microbenchmark timings
        from repro import compat

        compat.ensure_host_device_count(8)
        emit(run_schedules())
    elif "--sharded-engine" in sys.argv:
        from repro import compat

        compat.ensure_host_device_count(8)
        emit(run_sharded_engine())
    else:
        emit(run())

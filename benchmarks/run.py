"""Benchmark entrypoint: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` selects paper-sized
instances (hours on this CPU container); default sizes finish in minutes
and preserve every trend the paper reports.

  Table 2  → small_scale      Fig. 9  → k_sweep
  Table 3  → medium_scale     Fig. 10 → l_sweep
  Fig. 11  → medium_scale     Fig. 12 → large_scale
  Figs. 13-14 → pei_eval      (plus kernel microbenches)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import (
    k_sweep,
    kernel_bench,
    l_sweep,
    large_scale,
    medium_scale,
    pei_eval,
    small_scale,
)
from benchmarks.common import emit

SUITES = {
    "small_scale": lambda full: small_scale.run(
        sizes=(14, 16, 18, 20) if full else (14, 16)
    ),
    "medium_scale": lambda full: medium_scale.run(
        sizes=(100, 200, 400) if full else (60, 120)
    ),
    "k_sweep": lambda full: k_sweep.run(ks=(1, 2, 3, 4) if full else (1, 2, 4)),
    "l_sweep": lambda full: l_sweep.run(),
    "large_scale": lambda full: large_scale.run(
        sizes=(1000, 2000, 4000, 8000, 16000) if full else (1000, 2000)
    ),
    "pei_eval": lambda full: pei_eval.run(),
    "kernel_bench": lambda full: kernel_bench.run(),
    "sched_bench": lambda full: kernel_bench.run_schedules(
        n_qubits=16 if full else 14
    ),
    "sharded_engine": lambda full: kernel_bench.run_sharded_engine(
        n_qubits=16 if full else 14, opt_steps=30 if full else 20
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(SUITES) + [None])
    ap.add_argument("--save", default=None, help="write rows to JSON")
    args = ap.parse_args()

    # sched_bench/sharded_engine need a multi-device view; emulate before
    # jax initializes — but only when one of them is the *sole* selected
    # suite, because forcing 8 emulated devices distorts the other suites'
    # single-device timings. In a combined run they degrade to per-axis
    # skip notes unless XLA_FLAGS already provides the devices.
    if args.only in ("sched_bench", "sharded_engine"):
        from repro import compat

        compat.ensure_host_device_count(8)

    all_rows = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===", flush=True)
        rows = fn(args.full)
        emit(rows)
        all_rows.extend(rows)
    if args.save:
        os.makedirs(os.path.dirname(args.save) or ".", exist_ok=True)
        with open(args.save, "w") as f:
            json.dump(all_rows, f, indent=1, default=str)


if __name__ == "__main__":
    main()

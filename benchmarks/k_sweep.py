"""Paper Fig. 9: the K knob (Selective Distribution Exploration) —
cut value and runtime vs K on a fixed medium graph."""

from __future__ import annotations

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve


def run(n: int = 80, probs=(0.3, 0.8), ks=(1, 2, 3, 4), seed: int = 0):
    rows = []
    for p in probs:
        g = er_graph(n, p, seed=seed)
        for k in ks:
            out = solve(
                g, ParaQAOAConfig(n_qubits=10, top_k=k, p_layers=3, opt_steps=25)
            )
            rows.append(
                {
                    "name": f"k_sweep/K{k}/p{p}",
                    "runtime_s": out.report.runtime_s,
                    "derived": f"cut={out.cut_value:.0f}",
                    "cut": out.cut_value,
                    "k": k,
                    "p": p,
                }
            )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Paper Fig. 10: the L knob (level-aware merge parallelism).

The paper doubles worker processes (2K^L) and shows runtime halving. Our
TPU-native dual shards the frontier: worker count = frontier stripes. On
this single-core container we report (a) the per-worker work volume
(rows x levels), which halves per doubling exactly as in the paper, and
(b) measured single-core merge runtime vs beam width (linearity check).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import er_graph, timed
from repro.core import ParaQAOAConfig, solve
from repro.core import merge as mm
from repro.core.partition import connectivity_preserving_partition


def run(n: int = 120, p: float = 0.5, k: int = 2, ls=(1, 2, 3), seed: int = 0):
    g = er_graph(n, p, seed=seed)
    part = connectivity_preserving_partition(g, max(n // 9, 2))
    rng = np.random.default_rng(seed)
    cand = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = mm.build_merge_plan(part, cand, k)
    full = mm.exact_beam_width(k, part.m, cap=1 << 14)

    rows = []
    for l in ls:
        workers = 2 * k**l
        local_rows = max(full // workers, 2 * k)
        # measured: one worker's stripe swept on this core
        res, t = timed(lambda w=local_rows: mm.merge_scan(plan, w))
        rows.append(
            {
                "name": f"l_sweep/L{l}",
                "runtime_s": t,
                "derived": (
                    f"workers={workers};rows_per_worker={local_rows};"
                    f"cut={float(res.cut_value):.0f}"
                ),
            }
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

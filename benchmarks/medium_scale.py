"""Paper Table 3 + Fig. 11 (medium-scale): ParaQAOA vs QAOA² runtime and
speedup; AR heatmap against the GW reference (brute force infeasible).

CPU-scaled to 60–200 vertices (paper: 100–400). The paper's QAOA² numbers
come from its host-side exhaustive merge; our reimplementation solves the
same contracted problem on-device, so speedups here are *conservative*.
"""

from __future__ import annotations

from benchmarks.common import er_graph
from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import goemans_williamson, qaoa_in_qaoa


def run(sizes=(60, 120, 200), probs=(0.1, 0.5), seed: int = 0):
    rows = []
    for p in probs:
        for n in sizes:
            g = er_graph(n, p, seed=seed)
            _, v_gw, rep_gw = goemans_williamson(g, steps=250, rounds=64)
            _, v_q2, rep_q2 = qaoa_in_qaoa(g, n_qubits=10, opt_steps=25)
            out = solve(
                g, ParaQAOAConfig(n_qubits=10, top_k=2, p_layers=3, opt_steps=25)
            )
            speedup = rep_q2.runtime_s / max(out.report.runtime_s, 1e-9)
            for method, v, t in (
                ("gw", v_gw, rep_gw.runtime_s),
                ("qaoa2", v_q2, rep_q2.runtime_s),
                ("paraqaoa", out.cut_value, out.report.runtime_s),
            ):
                rows.append(
                    {
                        "name": f"medium/{method}/n{n}/p{p}",
                        "runtime_s": t,
                        "derived": (
                            f"AR_vs_gw={v / max(v_gw, 1e-9):.3f}"
                            + (f";speedup_vs_qaoa2={speedup:.1f}x"
                               if method == "paraqaoa" else "")
                        ),
                        "method": method,
                        "ar_vs_gw": v / max(v_gw, 1e-9),
                        "n": n,
                        "p": p,
                    }
                )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Closed-loop load generator for the Max-Cut solve service (DESIGN.md §6).

For each offered load R the same seed-stable request mix (varied sizes,
a fraction of relabeled repeats) runs twice:

  - **sequential** — one `core.solve` per request with the *same* planner
    knobs, back to back: the per-invocation baseline, which re-traces a
    fresh XLA program for every distinct (subgraph count, edge pad) shape;
  - **batched** — through `SolveService`: cross-request packing into the
    shape-bucketed cached program, canonical-graph cache on.

Per-request cuts of non-cached batched requests are asserted bit-identical
to their sequential twins (the §6.1 parity contract). Writes
`results/BENCH_service.json` (schema: docs/EXPERIMENTS.md): throughput and
p50/p99 latency per mode and load, speedup, cache-hit and batch-fill
ratios. `--smoke` is the tiny CI variant (emulated devices are irrelevant
here — the service is a single-process scheduler).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core import ParaQAOAConfig, solve
from repro.core.graph import Graph
from repro.service import SLA, Planner, ServiceConfig, SolveService
from repro.service.workload import request_mix


def _cfg_from_plan(plan) -> ParaQAOAConfig:
    kn = plan.knobs
    return ParaQAOAConfig(
        n_qubits=kn.n_qubits, top_k=kn.top_k, merge_level=plan.merge_level,
        p_layers=kn.p_layers, opt_steps=kn.opt_steps,
        beam_width=kn.beam_width,
    )


def _latency_row(name, mode, load, wall, latencies, **extra):
    lat = sorted(latencies)
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, max(int(np.ceil(0.99 * len(lat))) - 1, 0))]
    tput = load / wall if wall > 0 else 0.0
    return {
        "name": name,
        "runtime_s": wall,
        "derived": f"throughput={tput:.3f}rps;p50={p50:.3f}s;p99={p99:.3f}s",
        "mode": mode,
        "load": load,
        "throughput_rps": tput,
        "p50_s": p50,
        "p99_s": p99,
        **extra,
    }


def run(loads=(1, 2, 4, 8), n_range=(40, 100), p=0.15, seed=0,
        repeat_frac=0.25, deadline_s=20.0, batch_slots=16, max_qubits=10,
        save=True):
    planner = Planner(max_qubits=max_qubits, batch_slots=batch_slots)
    sla = SLA(deadline_s=deadline_s)

    # absorb one-time backend/compile noise outside the timed sections
    warm = Graph.erdos_renyi(n_range[0], p, seed=seed + 999)
    solve(warm, _cfg_from_plan(planner.plan(warm.n, warm.n_edges, sla)))

    rows = []
    for load in loads:
        graphs = request_mix(load, n_range, p, repeat_frac, seed)
        plans = [planner.plan(g.n, g.n_edges, sla) for g in graphs]

        # ---- sequential per-request baseline -----------------------------
        seq_lat, seq_out = [], []
        t0 = time.perf_counter()
        for g, plan in zip(graphs, plans):
            ts = time.perf_counter()
            seq_out.append(solve(g, _cfg_from_plan(plan)))
            seq_lat.append(time.perf_counter() - ts)
        seq_wall = time.perf_counter() - t0
        rows.append(_latency_row(
            f"service/seq_load{load}", "sequential", load, seq_wall, seq_lat,
        ))

        # ---- batched service (fresh instance per load point) -------------
        svc = SolveService(
            ServiceConfig(batch_slots=batch_slots, max_qubits=max_qubits),
            planner=planner,
        )
        t0 = time.perf_counter()
        rids = [svc.submit(g, sla) for g in graphs]
        svc.drain()
        bat_wall = time.perf_counter() - t0
        bat_lat = [svc.results[rid].latency_s for rid in rids]
        rows.append(_latency_row(
            f"service/batched_load{load}", "batched", load, bat_wall, bat_lat,
            cache_hit_ratio=round(svc.cache.stats.hit_ratio, 4),
            fill_ratio=round(svc.stats.fill_ratio, 4),
            dispatches=svc.stats.dispatches,
        ))

        # ---- parity + speedup summary ------------------------------------
        cut_equal = True
        for rid, solo in zip(rids, seq_out):
            r = svc.results[rid]
            if r.cached:
                continue  # served isomorphic twin; cut checked by the cache
            cut_equal &= bool(
                r.cut_value == solo.cut_value
                and np.array_equal(r.assignment, solo.assignment)
            )
        speedup = seq_wall / bat_wall if bat_wall > 0 else float("inf")
        rows.append({
            "name": f"service/speedup_load{load}",
            "runtime_s": 0.0,
            "derived": f"speedup={speedup:.3f}x;cut_equal={cut_equal}",
            "load": load,
            "speedup": speedup,
            "cut_equal": cut_equal,
        })

    if save and rows:
        path = write_bench_json("service", rows)
        print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        emit(run(loads=(1, 4), n_range=(24, 40), p=0.2, deadline_s=10.0,
                 batch_slots=8, save=False))
    else:
        emit(run())

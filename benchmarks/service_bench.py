"""Closed-loop load generator for the Max-Cut solve service (DESIGN.md §6).

For each offered load R the same seed-stable request mix (varied sizes,
a fraction of relabeled repeats) runs twice:

  - **sequential** — one `core.solve` per request with the *same* planner
    knobs, back to back: the per-invocation baseline, which re-traces a
    fresh XLA program for every distinct (subgraph count, edge pad) shape;
  - **batched** — through `SolveService`: cross-request packing into the
    shape-bucketed cached program, canonical-graph cache on.

Per-request cuts of non-cached batched requests are asserted bit-identical
to their sequential twins (the §6.1 parity contract). Writes
`results/BENCH_service.json` (schema: docs/EXPERIMENTS.md): throughput and
p50/p99 latency per mode and load, speedup, cache-hit and batch-fill
ratios. `--smoke` is the tiny CI variant (emulated devices are irrelevant
here — the service is a single-process scheduler).

`--distributed` (§Perf C8) instead exercises the §6.5 backends on an
emulated `data` mesh: the same mix through the single-device
`LocalBackend` and through `MeshBackend` (`solve_pool` over the mesh),
asserting bit-identical per-request cuts across backends *and* against
solo `core.solve` (`cut_equal`), plus a sync-vs-async admission pair
(`max_inflight` 1 vs 4) at the highest load. Writes
`results/BENCH_service_mesh.json`. Recalibration is pinned off
throughout: in the parity runs so both backends plan identically, and
in the async pair so both loops do identical work (refits are
timing-dependent, so leaving it on would measure the planner, not the
loop). Deadline enforcement (§6.6) is pinned off in both parity modes
for the same reason: a shed or downgraded request has no
bit-identical sequential twin to compare against.

`--sla-soak` (§Perf C9) is the open-loop SLA attainment suite: for each
offered load (requests/s) the seed-stable `workload.arrival_trace`
(Poisson base rate + burst episodes + skewed tenants + a per-request
deadline/floor mix) replays in wall-clock time via
`workload.run_soak_wall` against a deadline-enforcing service with live
recalibration. Writes `results/BENCH_service_sla.json`: attainment,
shed/expired/downgrade rates, p50/p99 latency per offered load and per
tenant, with the `attainment_ge_threshold` claim asserted at the
calibrated (lowest) load point.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core import ParaQAOAConfig, solve
from repro.core.graph import Graph
from repro.obs.metrics import percentile
from repro.service import SLA, Planner, ServiceConfig, SolveService
from repro.service.workload import request_mix, tenant_mix


def _cfg_from_plan(plan) -> ParaQAOAConfig:
    return plan.to_config()


def _latency_row(name, mode, load, wall, latencies, **extra):
    # §8: percentiles come from the shared obs helper (exact nearest-rank),
    # the same math behind every Histogram.summary() in the service stats
    p50 = percentile(latencies, 0.5)
    p99 = percentile(latencies, 0.99)
    tput = load / wall if wall > 0 else 0.0
    return {
        "name": name,
        "runtime_s": wall,
        "derived": f"throughput={tput:.3f}rps;p50={p50:.3f}s;p99={p99:.3f}s",
        "mode": mode,
        "load": load,
        "throughput_rps": tput,
        "p50_s": p50,
        "p99_s": p99,
        **extra,
    }


def run(loads=(1, 2, 4, 8), n_range=(40, 100), p=0.15, seed=0,
        repeat_frac=0.25, deadline_s=20.0, batch_slots=16, max_qubits=10,
        save=True):
    planner = Planner(max_qubits=max_qubits, batch_slots=batch_slots)
    sla = SLA(deadline_s=deadline_s)

    # absorb one-time backend/compile noise outside the timed sections
    warm = Graph.erdos_renyi(n_range[0], p, seed=seed + 999)
    solve(warm, _cfg_from_plan(planner.plan(warm.n, warm.n_edges, sla)))

    rows = []
    for load in loads:
        graphs = request_mix(load, n_range, p, repeat_frac, seed)
        plans = [planner.plan(g.n, g.n_edges, sla) for g in graphs]

        # ---- sequential per-request baseline -----------------------------
        seq_lat, seq_out = [], []
        t0 = time.perf_counter()
        for g, plan in zip(graphs, plans):
            ts = time.perf_counter()
            seq_out.append(solve(g, _cfg_from_plan(plan)))
            seq_lat.append(time.perf_counter() - ts)
        seq_wall = time.perf_counter() - t0
        rows.append(_latency_row(
            f"service/seq_load{load}", "sequential", load, seq_wall, seq_lat,
        ))

        # ---- batched service (fresh instance per load point; the planner
        # is shared with the sequential baseline, so recalibration is off
        # to keep the two modes' knob choices identical) ------------------
        svc = SolveService(
            ServiceConfig(batch_slots=batch_slots, max_qubits=max_qubits,
                          recalibrate=False, enforce_deadlines=False),
            planner=planner,
        )
        t0 = time.perf_counter()
        rids = [svc.submit(g, sla) for g in graphs]
        svc.drain()
        bat_wall = time.perf_counter() - t0
        bat_lat = [svc.results[rid].latency_s for rid in rids]
        rows.append(_latency_row(
            f"service/batched_load{load}", "batched", load, bat_wall, bat_lat,
            cache_hit_ratio=round(svc.cache.stats.hit_ratio, 4),
            fill_ratio=round(svc.stats.fill_ratio, 4),
            dispatches=svc.stats.dispatches,
        ))

        # ---- parity + speedup summary ------------------------------------
        cut_equal = True
        for rid, solo in zip(rids, seq_out):
            r = svc.results[rid]
            if r.cached:
                continue  # served isomorphic twin; cut checked by the cache
            cut_equal &= bool(
                r.cut_value == solo.cut_value
                and np.array_equal(r.assignment, solo.assignment)
            )
        speedup = seq_wall / bat_wall if bat_wall > 0 else float("inf")
        rows.append({
            "name": f"service/speedup_load{load}",
            "runtime_s": 0.0,
            "derived": f"speedup={speedup:.3f}x;cut_equal={cut_equal}",
            "load": load,
            "speedup": speedup,
            "cut_equal": cut_equal,
        })

    if save and rows:
        path = write_bench_json("service", rows)
        print(f"# wrote {path}")
    return rows


def _service_run(graphs, labels, sla, *, mesh=None, max_inflight=2,
                 recalibrate=False, batch_slots=16, max_qubits=10):
    svc = SolveService(ServiceConfig(
        batch_slots=batch_slots, max_qubits=max_qubits, mesh=mesh,
        max_inflight=max_inflight, recalibrate=recalibrate,
        enforce_deadlines=False,
    ))
    t0 = time.perf_counter()
    rids = [svc.submit(g, sla, tenant=t) for g, t in zip(graphs, labels)]
    svc.drain()
    wall = time.perf_counter() - t0
    return svc, rids, wall


def run_distributed(loads=(2, 4, 8), mesh_devices=4, n_range=(40, 100),
                    p=0.15, seed=0, repeat_frac=0.25, deadline_s=20.0,
                    batch_slots=16, max_qubits=10, async_reps=2, save=True):
    """§6.5 backend + async-admission load curve → BENCH_service_mesh.json.

    Requires ``mesh_devices`` visible jax devices (the `__main__` hook
    arranges CPU emulation before the backend initializes).
    """
    import jax

    assert jax.device_count() >= mesh_devices, (
        f"need {mesh_devices} devices (run via __main__, which emulates)"
    )
    mesh = f"data={mesh_devices}"
    sla = SLA(deadline_s=deadline_s)
    kw = dict(batch_slots=batch_slots, max_qubits=max_qubits)

    # absorb one-time compile noise for both backends
    warm_planner = Planner(max_qubits=max_qubits, batch_slots=batch_slots)
    warm = Graph.erdos_renyi(n_range[0], p, seed=seed + 999)
    _service_run([warm], ["t0"], sla, **kw)
    _service_run([warm], ["t0"], sla, mesh=mesh, **kw)
    solve(warm, _cfg_from_plan(warm_planner.plan(warm.n, warm.n_edges, sla)))

    rows = []
    for load in loads:
        graphs = request_mix(load, n_range, p, repeat_frac, seed)
        labels = tenant_mix(load, 2, seed)

        svc_l, rids_l, wall_l = _service_run(graphs, labels, sla, **kw)
        svc_m, rids_m, wall_m = _service_run(graphs, labels, sla, mesh=mesh,
                                             **kw)
        rows.append(_latency_row(
            f"service_mesh/local_load{load}", "local", load, wall_l,
            [svc_l.results[r].latency_s for r in rids_l],
            fill_ratio=round(svc_l.stats.fill_ratio, 4),
            dispatches=svc_l.stats.dispatches,
            devices=1,
        ))
        rows.append(_latency_row(
            f"service_mesh/mesh_load{load}", "mesh", load, wall_m,
            [svc_m.results[r].latency_s for r in rids_m],
            fill_ratio=round(svc_m.stats.fill_ratio, 4),
            dispatches=svc_m.stats.dispatches,
            devices=svc_m.backend.describe()["devices"],
        ))

        # ---- the §6.5 parity contract ------------------------------------
        cut_equal = True
        for g, rl, rm in zip(graphs, rids_l, rids_m):
            ra, rb = svc_l.results[rl], svc_m.results[rm]
            cut_equal &= bool(
                ra.cut_value == rb.cut_value
                and np.array_equal(ra.assignment, rb.assignment)
            )
            if not ra.cached:  # and against solo core.solve on its knobs
                solo = solve(g, _cfg_from_plan(ra.plan))
                cut_equal &= bool(ra.cut_value == solo.cut_value)
        rows.append({
            "name": f"service_mesh/parity_load{load}",
            "runtime_s": 0.0,
            "derived": (
                f"cut_equal={cut_equal};"
                f"mesh_over_local={wall_l / wall_m if wall_m else 0:.3f}x"
            ),
            "load": load,
            "cut_equal": cut_equal,
            "mesh_over_local": wall_l / wall_m if wall_m else 0.0,
        })

    # ---- async admission vs the PR 3-style synchronous loop --------------
    # max_inflight=1 reproduces the closed pump (dispatch, block, merge);
    # the async window overlaps host packing/merging with device batches.
    # Recalibration off so both loops plan identical knobs — with it on,
    # timing-dependent refits give the two runs different work and the
    # comparison measures the planner, not the loop.
    load = max(loads)
    graphs = request_mix(load, n_range, p, repeat_frac, seed)
    labels = tenant_mix(load, 2, seed)
    sync_wall = min(
        _service_run(graphs, labels, sla, max_inflight=1, **kw)[2]
        for _ in range(async_reps)
    )
    async_wall = min(
        _service_run(graphs, labels, sla, max_inflight=4, **kw)[2]
        for _ in range(async_reps)
    )
    sync_tput = load / sync_wall if sync_wall else 0.0
    async_tput = load / async_wall if async_wall else 0.0
    ratio = async_tput / sync_tput if sync_tput else float("inf")
    rows.append({
        "name": f"service_mesh/async_vs_sync_load{load}",
        "runtime_s": async_wall,
        "derived": (
            f"async={async_tput:.3f}rps;sync={sync_tput:.3f}rps;"
            f"ratio={ratio:.3f}x"
        ),
        "load": load,
        "async_throughput_rps": async_tput,
        "sync_throughput_rps": sync_tput,
        "async_over_sync": ratio,
        "async_ge_sync": bool(ratio >= 1.0),
    })

    if save and rows:
        path = write_bench_json("service_mesh", rows)
        print(f"# wrote {path}")
    return rows


def run_sla_soak(loads=(1.0, 4.0, 16.0, 64.0), requests=120, n_range=(10, 24),
                 p=0.3, seed=0, repeat_frac=0.4, tenants=2,
                 deadline_choices=(5.0, 15.0), floor_choices=(None, 6.0),
                 batch_slots=8, max_qubits=6, attainment_threshold=0.95,
                 save=True):
    """§Perf C9: open-loop SLA soak → BENCH_service_sla.json.

    ``loads`` are offered arrival rates (requests/s); the *lowest* is the
    calibrated point, where the deadline-enforcing service is expected to
    hold attainment >= ``attainment_threshold``. Higher rates chart the
    degradation curve: shed/expired rates rise, attainment falls — the
    falsifiable wall-clock serving story the ROADMAP asks for. Every row
    carries the boolean ``attainment_ge_threshold`` claim (checked by
    tests/test_bench_schema.py at the calibrated point) plus per-tenant
    attainment accounting.

    Default loads bracket measured single-host capacity: the batched
    solver amortizes across requests, but each *novel* graph shape pays
    a per-shape merge trace (~0.5-1 s on CPU), so fresh-graph capacity
    sits near 1-2 req/s and the deadline mix must clear that service
    time.
    """
    from repro.core import qaoa as qaoa_mod
    from repro.core.partition import partition_for_solver
    from repro.service import edge_capacity, make_backend
    from repro.service.workload import (
        arrival_trace,
        latency_summary,
        run_soak_wall,
    )

    # pre-compile every solver program the planner could pick at the
    # scheduler's exact batch shapes (the program cache is global, keyed
    # on config): a multi-second XLA compile landing mid-soak would be
    # billed against a 2-8s deadline and read as an SLA miss of the
    # *service*, not of the measurement
    backend = make_backend(None)
    probe = Planner(max_qubits=max_qubits, batch_slots=batch_slots)
    seen = set()
    for kn in probe.grid:
        qcfg = ParaQAOAConfig(
            n_qubits=kn.n_qubits, top_k=kn.top_k, merge_level=2,
            p_layers=kn.p_layers, opt_steps=kn.opt_steps,
            beam_width=kn.beam_width,
        ).qaoa_config()
        if qcfg in seen:
            continue
        seen.add(qcfg)
        g = Graph.erdos_renyi(kn.n_qubits, 0.8, seed=seed + 999)
        part = partition_for_solver(g, kn.n_qubits)
        edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
            part.subgraphs[:1], qcfg.n_qubits,
            e_pad=edge_capacity(qcfg.n_qubits), n_rows=batch_slots,
        )
        np.asarray(backend.solve_batch(qcfg, edges, weights, masks).bitstrings)

    # ... and the merge programs for the soak's actual graph mix (traces
    # at every rate share the same graphs — only arrival times rescale),
    # since the merge stage traces per novel graph shape. Without this the
    # *first* load point alone would be billed every merge compile and the
    # degradation curve would read backwards
    warm_svc = SolveService(ServiceConfig(
        batch_slots=batch_slots, max_qubits=max_qubits, recalibrate=False,
        enforce_deadlines=False,
    ))
    for g in request_mix(requests, n_range, p, repeat_frac, seed):
        warm_svc.submit(g, SLA())
    warm_svc.drain()

    rows = []
    calibrated_rate = min(loads)
    for rate in loads:
        trace = arrival_trace(
            requests, rate, n_range, p, seed, repeat_frac=repeat_frac,
            tenants=tenants, deadline_choices=deadline_choices,
            floor_choices=floor_choices,
        )
        svc = SolveService(ServiceConfig(
            batch_slots=batch_slots, max_qubits=max_qubits,
        ))  # recalibration on: enforcement uses the live cost model
        rids, wall = run_soak_wall(svc, trace)
        res = [svc.results[r] for r in rids]
        assert len(res) == len(trace)
        st = svc.stats
        assert st.terminal == len(trace), "request missing a terminal state"
        n_req = len(res)
        # §8: completed-request percentiles straight from the service's
        # obs histogram — the same stream `st.latency` accumulates live
        lat = latency_summary(svc)
        p50, p99 = lat["p50"], lat["p99"]
        att = st.attainment
        shed_rate = st.shed / n_req
        expired_rate = st.expired / n_req
        dg_rate = st.downgraded / max(st.completed, 1)
        rows.append({
            "name": f"service_sla/load{rate:g}rps",
            "runtime_s": wall,
            "derived": (
                f"attainment={att:.3f};shed={shed_rate:.3f};"
                f"expired={expired_rate:.3f};downgrade={dg_rate:.3f};"
                f"p50={p50:.3f}s;p99={p99:.3f}s"
            ),
            "mode": "sla_soak",
            "offered_rps": rate,
            "load": n_req,
            "throughput_rps": st.completed / wall if wall > 0 else 0.0,
            "p50_s": p50,
            "p99_s": p99,
            "attainment": round(att, 4),
            "shed_rate": round(shed_rate, 4),
            "expired_rate": round(expired_rate, 4),
            "downgrade_rate": round(dg_rate, 4),
            "downgrade_events": st.downgrade_events,
            "completed": st.completed,
            "shed": st.shed,
            "expired": st.expired,
            "attainment_threshold": attainment_threshold,
            "attainment_ge_threshold": bool(att >= attainment_threshold),
            "calibrated": bool(rate == calibrated_rate),
            "tenants": {t: s.as_dict() for t, s in st.tenants.items()},
        })

    if save and rows:
        path = write_bench_json("service_sla", rows)
        print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    import sys

    if "--sla-soak" in sys.argv:
        if "--smoke" in sys.argv:
            emit(run_sla_soak(loads=(1.0, 3.0, 9.0), requests=24,
                              save=False))
        else:
            emit(run_sla_soak())
    elif "--distributed" in sys.argv:
        # emulate the mesh *before* the first jax backend touch
        from repro import compat

        compat.ensure_host_device_count(4)
        if "--smoke" in sys.argv:
            emit(run_distributed(loads=(2, 4), n_range=(24, 40), p=0.2,
                                 deadline_s=10.0, batch_slots=8,
                                 max_qubits=8, async_reps=1, save=False))
        else:
            emit(run_distributed())
    elif "--smoke" in sys.argv:
        emit(run(loads=(1, 4), n_range=(24, 40), p=0.2, deadline_s=10.0,
                 batch_slots=8, save=False))
    else:
        emit(run())

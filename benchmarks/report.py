"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json (written by repro.launch.dryrun).

  PYTHONPATH=src:. python -m benchmarks.report            # print tables
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

ARCH_ORDER = [
    "qwen1_5_0_5b", "gemma3_4b", "internlm2_20b", "gemma3_27b",
    "internvl2_2b", "moonshot_v1_16b_a3b", "arctic_480b", "whisper_medium",
    "zamba2_2_7b", "mamba2_1_3b", "paraqaoa",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir=RESULTS):
    recs = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        r["_pod"] = "multi" if "multipod" in fn else "single"
        recs.append(r)
    return recs


def _key(r):
    a = ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99
    return (a, s, r["_pod"])


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | compile_s | params/dev | HLO FLOPs/dev | HLO bytes/dev | wire bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=_key):
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | SKIP (see §4 DESIGN.md) | - | - | - | - | - |"
            )
            continue
        pb = r.get("param_bytes")
        chips_model = 16
        lines.append(
            "| {arch} | {shape} | {mesh} | {status} | {c:.0f} | {pd} | {fl:.2e} | {by:.2e} | {wb} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r.get("mesh", "-"),
                status=r["status"].upper(), c=r.get("compile_s", 0),
                pd=_fmt_bytes(pb / chips_model if pb else None),
                fl=r.get("flops_per_device", 0) or 0,
                by=r.get("bytes_per_device", 0) or 0,
                wb=_fmt_bytes(r.get("collective_wire_bytes")),
            )
        )
    return "\n".join(lines)


def roofline_table(recs, pod="single"):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | MODEL_FLOPS | useful ratio | roofline fraction |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=_key):
        if r.get("_pod") != pod:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP | - | - | - |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | - | - | - |"
            )
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ideal = r["model_flops"] / (r["chips"] * 197e12)
        frac = ideal / dom if dom > 0 else 0.0
        lines.append(
            "| {arch} | {shape} | {c:.4f} | {m:.4f} | {x:.4f} | {b} | {mf:.2e} | {u:.2f} | {f:.1%} |".format(
                arch=r["arch"], shape=r["shape"], c=r["compute_s"],
                m=r["memory_s"], x=r["collective_s"], b=r["bottleneck"],
                mf=r["model_flops"], u=r["useful_ratio"], f=frac,
            )
        )
    return "\n".join(lines)


def write_experiments_md(path="docs/EXPERIMENTS.md"):
    """Substitute the generated tables into EXPERIMENTS.md placeholders
    (the §Dry-run / §Roofline sections of docs/EXPERIMENTS.md)."""
    recs = [r for r in load() if not r.get("tag")]
    with open(path) as f:
        text = f.read()
    text = text.replace(
        "<!-- DRYRUN_TABLE -->",
        "### All cells × both meshes\n\n" + dryrun_table(recs),
    )
    roof = (
        "### Single-pod 16×16 (the §Roofline scoreboard)\n\n"
        + roofline_table(recs, pod="single")
        + "\n\n### Multi-pod 2×16×16\n\n"
        + roofline_table(recs, pod="multi")
    )
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote tables into {path}")


def main():
    import sys

    if "--write" in sys.argv:
        write_experiments_md()
        return
    recs = [r for r in load() if not r.get("tag")]
    print("## §Dry-run (both meshes)\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16×16)\n")
    print(roofline_table(recs, pod="single"))
    print("\n## §Roofline (multi-pod 2×16×16)\n")
    print(roofline_table(recs, pod="multi"))


if __name__ == "__main__":
    main()

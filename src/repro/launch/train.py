"""Training driver: config-driven, mesh-aware, checkpointed, restartable.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

On a real cluster this binary runs once per host (jax.distributed
initializes from the cluster env); here it drives the same code path on
CPU with the reduced configs. Restart-resume: re-running with the same
--ckpt-dir continues from the latest checkpoint (fault tolerance — kill it
mid-run and rerun to test).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, synthetic_batch
from repro.training.train_step import (
    TrainConfig,
    TrainState,
    init_state,
    train_step,
)


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--remat", action="store_true", default=True)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="fault-injection: crash at this step (FT testing)")
    args = ap.parse_args(argv)

    cfg = (
        configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    )
    model = build_model(cfg)
    tcfg = TrainConfig(
        adamw=opt.AdamWConfig(
            learning_rate=args.lr, warmup_steps=min(20, args.steps // 10),
            total_steps=args.steps,
        ),
        remat=args.remat,
        grad_compression=args.grad_compression,
    )
    dcfg = DataConfig(seed=args.seed, batch=args.batch, seq=args.seq)

    state = init_state(model, jax.random.PRNGKey(args.seed), tcfg)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        start_step, state, meta = ckpt.restore(state)
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(lambda s, b: train_step(s, b, model, tcfg), donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if step == args.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        batch = synthetic_batch(cfg, dcfg, step)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            print(
                f"[train] step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)"
            )
        if ckpt and step > 0 and step % args.ckpt_every == 0:
            ckpt.save(step + 1, state, {"loss": float(metrics["loss"])})
    if ckpt:
        ckpt.save(args.steps, state, {"loss": float(metrics["loss"])})
        ckpt.wait()
    print(f"[train] done: first logged loss {losses[0]:.4f} → last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    run()

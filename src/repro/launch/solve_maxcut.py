"""Max-Cut solve driver: the paper's pipeline as a CLI.

Single device:

  PYTHONPATH=src python -m repro.launch.solve_maxcut --n 2000 --p 0.05 \
      --qubits 10 --k 2 --compare-gw

Distributed (the paper's pool-parallel architecture; on a laptop/CI the
mesh is CPU host-device emulation, arranged automatically):

  PYTHONPATH=src python -m repro.launch.solve_maxcut --n 400 --mesh data=2
  PYTHONPATH=src python -m repro.launch.solve_maxcut --n 400 \
      --mesh data=2,model=4 --schedule alternating

See docs/DESIGN.md §2 for the mesh axes and README.md for a quickstart.
"""

from __future__ import annotations

import argparse


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.solve_maxcut",
        description="Solve Max-Cut with the ParaQAOA divide-and-conquer "
        "pipeline (partition → QAOA solver pool → level-aware merge).",
    )
    ap.add_argument("--n", type=int, default=400,
                    help="vertex count of the Erdős-Rényi instance")
    ap.add_argument("--p", type=float, default=0.1,
                    help="Erdős-Rényi edge probability")
    ap.add_argument("--seed", type=int, default=0,
                    help="graph-generation seed (runs are seed-stable)")
    ap.add_argument("--problem", choices=("maxcut", "qubo", "mis"),
                    default="maxcut",
                    help="problem family: Max-Cut on the generated graph, "
                    "a random QUBO over its topology (quadratic + N(0,1) "
                    "linear terms), or penalty-encoded maximum independent "
                    "set — all through the same diagonal-cost oracle")
    ap.add_argument("--weights", choices=("unit", "uniform", "spin"),
                    default="unit",
                    help="edge-weight family: unit weights, "
                    "uniform(0.1,1) weights, or ±1 spin-glass couplings")
    ap.add_argument("--check-oracle", action="store_true",
                    help="small-n only (n <= 18): compare the solved "
                    "objective against exhaustive brute force and, for "
                    "--problem mis, assert the selected set is independent")
    ap.add_argument("--qubits", type=int, default=10,
                    help="per-device qubit budget N (paper: 26 on GPU); "
                    "a model mesh axis lifts it to N + log2(model)")
    ap.add_argument("--k", type=int, default=2,
                    help="top-K candidates kept per subgraph (paper's K)")
    ap.add_argument("--layers", type=int, default=3,
                    help="QAOA circuit depth p")
    ap.add_argument("--opt-steps", type=int, default=25,
                    help="Adam steps on <cut>; 0 keeps the linear-ramp init")
    ap.add_argument("--beam", type=int, default=None,
                    help="merge frontier width (default: exact 2*K^M, capped)")
    ap.add_argument("--refine", type=int, default=0,
                    help="1-flip local-search sweeps on the merged cut "
                    "(beyond-paper; 0 disables)")
    ap.add_argument("--mesh", type=str, default=None, metavar="SPEC",
                    help="device mesh spec, e.g. 'data=2' or 'data=2,model=4' "
                    "(axes: pod/data/model; model must be a power of two). "
                    "Omit for the single-device pipeline. On a single-CPU "
                    "host the devices are emulated (docs/TESTING.md)")
    ap.add_argument("--schedule", choices=("faithful", "alternating"),
                    default="alternating",
                    help="collective schedule for model-axis sharded "
                    "subproblems: 2 vs 1 all_to_all per layer")
    ap.add_argument("--sharded-opt-steps", type=int, default=0,
                    help="Adam steps on oversized (model-sharded) "
                    "subproblem parameters, optimized through the sharded "
                    "evolution (DESIGN.md §2.6); 0 keeps the linear ramp")
    ap.add_argument("--merge", choices=("auto", "striped", "single"),
                    default="auto", dest="merge_mode",
                    help="distributed merge policy: 'auto' stripes the "
                    "frontier across data shards only when provably "
                    "exhaustive (cut identical to the single-device run); "
                    "'striped' always stripes (the paper's independent "
                    "workers — may differ in the beam-pruned regime); "
                    "'single' keeps the merge on one device")
    ap.add_argument("--compare-gw", action="store_true",
                    help="also run the Goemans-Williamson baseline and "
                    "report AR / PEI against it")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="export the pipeline span trace here (tracing is "
                    "off unless this is set; DESIGN.md §8)")
    ap.add_argument("--trace-format", choices=("jsonl", "chrome"),
                    default="jsonl",
                    help="trace export format: 'jsonl' (one span per "
                    "line) or 'chrome' (Perfetto-loadable trace events)")
    return ap


def run(argv=None):
    args = build_parser().parse_args(argv)

    mesh_spec = None
    if args.mesh:
        # parse + emulate *before* the first jax backend touch (graph
        # construction below creates device arrays)
        from repro import compat
        from repro.launch.mesh import mesh_spec_size, parse_mesh_spec

        mesh_spec = parse_mesh_spec(args.mesh)
        need = mesh_spec_size(mesh_spec)
        have = compat.ensure_host_device_count(need)
        if have < need:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices but the jax "
                f"backend is already up with {have}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}"
            )

    import contextlib

    import numpy as np

    from repro.core import ParaQAOAConfig, solve, solve_distributed
    from repro.core.graph import (
        Graph,
        Problem,
        independent_set_violations,
    )
    from repro.core.pei import pei
    from repro.obs.trace import Tracer, use_tracer

    if args.weights == "uniform":
        graph = Graph.erdos_renyi_weighted(args.n, args.p, seed=args.seed)
    elif args.weights == "spin":
        graph = Graph.spin_glass(args.n, args.p, seed=args.seed)
    else:
        graph = Graph.erdos_renyi(args.n, args.p, seed=args.seed)
    if args.problem == "mis":
        instance = Problem.mis(graph)
    elif args.problem == "qubo":
        rng = np.random.default_rng(args.seed + 0x9B0)
        e = np.asarray(graph.edges)[: graph.n_edges]
        q = np.asarray(graph.weights)[: graph.n_edges]
        instance = Problem.qubo(
            graph.n, e, q, linear=rng.normal(size=graph.n).astype(np.float32)
        )
    else:
        instance = graph
    print(f"[maxcut] G({args.n}, {args.p}): {graph.n_edges} edges "
          f"({args.problem}, {args.weights} weights)")
    cfg = ParaQAOAConfig(
        n_qubits=args.qubits, top_k=args.k, p_layers=args.layers,
        opt_steps=args.opt_steps, beam_width=args.beam,
        refine_steps=args.refine,
        sharded_opt_steps=args.sharded_opt_steps,
    )
    # §8: tracing is enabled only when an export path is requested; the
    # pipeline's ambient-tracer spans become the exported trace
    tracer = Tracer(record=True) if args.trace_out else None
    scope = use_tracer(tracer) if tracer else contextlib.nullcontext()
    with scope:
        if mesh_spec is not None:
            out = solve_distributed(
                instance, cfg, mesh_spec,
                schedule=args.schedule, merge_mode=args.merge_mode,
            )
            extra = out.report.extra
            print(f"[maxcut] mesh {extra['mesh']}: "
                  f"{extra['merge_shards']} merge shards "
                  f"({extra['merge_mode']}), "
                  f"{extra['sharded_subproblems']} model-sharded subproblems "
                  f"(sharded_opt_steps={extra['sharded_opt_steps']})")
        else:
            out = solve(instance, cfg)
    if tracer is not None:
        tracer.export(args.trace_out, args.trace_format)
        print(f"[maxcut] trace ({args.trace_format}, "
              f"{len(tracer.spans)} spans): {args.trace_out}")
    print(f"[maxcut] value = {out.cut_value:.2f}  "
          f"(M={out.partition.m}, K={args.k}, {out.report.runtime_s:.2f}s)")
    for stage, t in out.timings.items():
        print(f"  {stage:12s} {t:.2f}s")

    if args.problem == "mis":
        viol = independent_set_violations(graph, out.assignment)
        size = int(np.sum(np.asarray(out.assignment)))
        print(f"[maxcut] mis: |S|={size}, conflict edges inside S: {viol}")
        assert viol == 0, (
            f"penalty-QUBO MIS produced {viol} conflict edge(s) — raise "
            "the penalty or the refine/merge budget"
        )

    if args.check_oracle:
        if args.n > 18:
            raise SystemExit("--check-oracle needs --n <= 18 (exhaustive)")
        from repro.core.baselines.brute_force import brute_force_problem

        _, opt, rep = brute_force_problem(instance)
        gap = opt - out.cut_value
        print(f"[maxcut] oracle: brute-force optimum {opt:.2f} "
              f"({rep.runtime_s:.2f}s), gap {gap:.4f}")
        assert gap > -1e-3 * max(1.0, abs(opt)), (
            "solver reported a value above the exhaustive optimum — "
            "objective accounting is broken", out.cut_value, opt,
        )

    if args.compare_gw:
        from repro.core.baselines import goemans_williamson

        _, v_gw, rep = goemans_williamson(graph, steps=250, rounds=64)
        print(f"[maxcut] GW reference: {v_gw:.0f} ({rep.runtime_s:.2f}s)  "
              f"AR={out.cut_value / v_gw:.3f}  "
              f"PEI={pei(out.cut_value, v_gw, out.report.runtime_s, rep.runtime_s):.1f}")
    return out


if __name__ == "__main__":
    run()

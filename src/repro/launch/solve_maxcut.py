"""Max-Cut solve driver: the paper's pipeline as a CLI.

  PYTHONPATH=src python -m repro.launch.solve_maxcut --n 2000 --p 0.05 \
      --qubits 10 --k 2 --compare-gw
"""

from __future__ import annotations

import argparse

from repro.core import ParaQAOAConfig, solve
from repro.core.graph import Graph
from repro.core.pei import pei


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--qubits", type=int, default=10)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--opt-steps", type=int, default=25)
    ap.add_argument("--beam", type=int, default=None)
    ap.add_argument("--refine", type=int, default=0)
    ap.add_argument("--compare-gw", action="store_true")
    args = ap.parse_args(argv)

    graph = Graph.erdos_renyi(args.n, args.p, seed=args.seed)
    print(f"[maxcut] G({args.n}, {args.p}): {graph.n_edges} edges")
    cfg = ParaQAOAConfig(
        n_qubits=args.qubits, top_k=args.k, p_layers=args.layers,
        opt_steps=args.opt_steps, beam_width=args.beam,
        refine_steps=args.refine,
    )
    out = solve(graph, cfg)
    print(f"[maxcut] cut = {out.cut_value:.0f}  "
          f"(M={out.partition.m}, K={args.k}, {out.report.runtime_s:.2f}s)")
    for stage, t in out.timings.items():
        print(f"  {stage:12s} {t:.2f}s")

    if args.compare_gw:
        from repro.core.baselines import goemans_williamson

        _, v_gw, rep = goemans_williamson(graph, steps=250, rounds=64)
        print(f"[maxcut] GW reference: {v_gw:.0f} ({rep.runtime_s:.2f}s)  "
              f"AR={out.cut_value / v_gw:.3f}  "
              f"PEI={pei(out.cut_value, v_gw, out.report.runtime_s, rep.runtime_s):.1f}")
    return out


if __name__ == "__main__":
    run()

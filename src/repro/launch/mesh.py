"""Production mesh builders and the `--mesh` CLI spec (DESIGN.md §2.1).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run (and the CPU
host-device emulation in repro.compat) must set XLA_FLAGS before any jax
initialization. All construction goes through `repro.compat.make_mesh` so
the same code runs on jax versions with and without `jax.make_mesh`.

The CLI mesh spec is a comma-separated `axis=size` list, e.g.
``data=8``, ``data=2,model=4``, ``pod=2,data=16,model=16``. Axis names are
restricted to the runtime's three roles (`pod`/`data`/`model`) and
normalized to that canonical order regardless of how the flag spells them;
`model` must be a power of two (the sharded-statevector qubit-swap
all_to_all of core/distributed.py rotates log2(model) qubits).
"""

from __future__ import annotations

from repro import compat

#: Canonical mesh axis order — every mesh the runtime builds uses a
#: (sub)tuple of these names, outermost first.
AXIS_ORDER = ("pod", "data", "model")


def parse_mesh_spec(spec: str) -> dict:
    """Parse ``"data=2,model=4"`` into ``{"data": 2, "model": 4}``.

    Pure string processing (no jax): safe to call before backend init, so
    drivers can size CPU host-device emulation from the parsed product.
    Raises ValueError on malformed specs: unknown/duplicate axis names,
    missing ``=``, non-integer or non-positive sizes, a non-power-of-two
    `model` axis, or an empty spec.
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty mesh spec: {spec!r} (expected e.g. 'data=2,model=4')")
    axes: dict = {}
    for item in spec.split(","):
        item = item.strip()
        if "=" not in item:
            raise ValueError(
                f"malformed mesh spec entry {item!r}: expected 'axis=size'"
            )
        name, _, size_s = item.partition("=")
        name = name.strip()
        if name not in AXIS_ORDER:
            raise ValueError(
                f"unknown mesh axis {name!r}: expected one of {AXIS_ORDER}"
            )
        if name in axes:
            raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
        try:
            size = int(size_s)
        except ValueError:
            raise ValueError(
                f"mesh axis size must be an integer: {item!r}"
            ) from None
        if size < 1:
            raise ValueError(f"mesh axis size must be >= 1: {item!r}")
        axes[name] = size
    if "model" in axes and axes["model"] & (axes["model"] - 1):
        raise ValueError(
            f"model axis size must be a power of two (got {axes['model']}): "
            "the sharded statevector rotates log2(model) qubits per all_to_all"
        )
    return {a: axes[a] for a in AXIS_ORDER if a in axes}


def mesh_spec_size(spec: dict) -> int:
    """Total device count a parsed mesh spec requires."""
    total = 1
    for s in spec.values():
        total *= s
    return total


def build_mesh(spec: dict):
    """Device mesh for a parsed spec, over the first prod(sizes) devices.

    Unlike `compat.make_mesh` (which uses *all* visible devices), this
    tolerates a backend exposing more devices than the spec asks for —
    the CLI case where `ensure_host_device_count` found the backend
    already initialized with a larger emulated count.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    shape = tuple(spec.values())
    names = tuple(spec.keys())
    total = mesh_spec_size(spec)
    devices = jax.devices()
    if len(devices) < total:
        raise ValueError(
            f"mesh spec {spec} needs {total} devices but only "
            f"{len(devices)} are visible — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={total} "
            "(or call compat.ensure_host_device_count before jax initializes)"
        )
    if len(devices) == total:
        return compat.make_mesh(shape, names)
    return Mesh(np.asarray(devices[:total]).reshape(shape), names)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (8 fake devices by default).

    Run under `XLA_FLAGS=--xla_force_host_platform_device_count=8` (or call
    `compat.ensure_host_device_count(8)` before jax initializes).
    """
    return compat.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All batch-shardable axes present in the mesh."""
    return compat.mesh_data_axes(mesh)


def model_axis(mesh) -> str:
    return compat.mesh_model_axis(mesh) or "model"

"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All batch-shardable axes present in the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis(mesh) -> str:
    return "model"

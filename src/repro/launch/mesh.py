"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run (and the CPU
host-device emulation in repro.compat) must set XLA_FLAGS before any jax
initialization. All construction goes through `repro.compat.make_mesh` so
the same code runs on jax versions with and without `jax.make_mesh`.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for multi-device CPU tests (8 fake devices by default).

    Run under `XLA_FLAGS=--xla_force_host_platform_device_count=8` (or call
    `compat.ensure_host_device_count(8)` before jax initializes).
    """
    return compat.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All batch-shardable axes present in the mesh."""
    return compat.mesh_data_axes(mesh)


def model_axis(mesh) -> str:
    return compat.mesh_model_axis(mesh) or "model"

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell against the production meshes, with zero device allocation.

The two lines above MUST stay first: jax locks the device count at first
backend initialization, and the production meshes need 512 placeholder
devices. (Tests and benchmarks never import this module — they see 1 CPU.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
  PYTHONPATH=src python -m repro.launch.dryrun --qaoa   # the paper's workload

Each run writes JSON records under results/dryrun/ that EXPERIMENTS.md's
tables are generated from (benchmarks/report.py).
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _mesh_desc(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())


def _count_bytes(tree) -> int:
    return sum(
        int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(tree)
    )


def lower_cell(cell: SP.Cell, mesh, *, unroll: int = 1):
    """Lower + compile one cell. Returns (compiled, lowered, meta).

    `unroll` sets the layer-scan unroll factor: the dry-run compiles each
    cell at unroll=1 and unroll=2 to undo cost_analysis's count-the-loop-
    body-once behaviour (total = m1 + (L-1)·(m2-m1)).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import layers as ML
    from repro.models import transformer as MT
    from repro.models.model import build_model
    from repro.training import optimizer as opt
    from repro.training.train_step import TrainConfig, train_step

    ML.configure_shard_hints(mesh.axis_names)
    MT.set_layer_unroll(unroll)
    import contextlib
    ctx = contextlib.ExitStack()
    ctx.enter_context(mesh)
    overrides = {"param_dtype": "bfloat16"}
    if getattr(lower_cell, "_cap_factor", None):
        overrides["moe_capacity_factor"] = lower_cell._cap_factor
    cfg = dataclasses.replace(cell.cfg, **overrides)
    model = build_model(cfg)
    abstract_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if cell.kind == "train":
        p_shard = SH.params_shardings(abstract_params, cfg, mesh, fsdp=True)
        tcfg = TrainConfig(remat=True)
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        opt_shard = opt.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=SH.params_shardings(abstract_opt.mu, cfg, mesh, fsdp=True),
            nu=SH.params_shardings(abstract_opt.nu, cfg, mesh, fsdp=True),
        )
        from repro.training.train_step import TrainState

        state_abstract = TrainState(params=abstract_params, opt=abstract_opt, ef=None)
        state_shard = TrainState(params=p_shard, opt=opt_shard, ef=None)
        b_spec = SH.batch_specs(cfg, mesh, "train")
        batch_abstract = SP.input_specs(cell)
        batch_shard = {
            k: NamedSharding(mesh, b_spec[k]) for k in batch_abstract
        }

        def step(state, batch):
            return train_step(state, batch, model, tcfg)

        jitted = jax.jit(
            step,
            in_shardings=(state_shard, batch_shard),
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_abstract, batch_abstract)

    elif cell.kind == "prefill":
        serve_fsdp = _count_bytes(abstract_params) / mesh.shape["model"] > 8e9
        p_shard = SH.params_shardings(abstract_params, cfg, mesh, fsdp=serve_fsdp)
        b_spec = SH.batch_specs(cfg, mesh, "prefill")
        batch_abstract = SP.input_specs(cell)
        batch_shard = {k: NamedSharding(mesh, b_spec[k]) for k in batch_abstract}

        def step(params, batch):
            return model.prefill(params, batch, s_max=cell.seq)

        jitted = jax.jit(
            step, in_shardings=(p_shard, batch_shard)
        )
        lowered = jitted.lower(abstract_params, batch_abstract)

    else:  # decode
        serve_fsdp = _count_bytes(abstract_params) / mesh.shape["model"] > 8e9
        p_shard = SH.params_shardings(abstract_params, cfg, mesh, fsdp=serve_fsdp)
        state_abstract = SP.decode_state_specs_abstract(cell)
        ds_spec = SH.decode_state_specs(cfg, mesh, cell.batch)
        state_shard = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec),
            ds_spec,
            is_leaf=lambda x: isinstance(x, P),
        )
        # drop specs for absent cache fields
        from repro.models.decode import DecodeState

        state_shard = DecodeState(
            **{
                f: getattr(state_shard, f)
                if getattr(state_abstract, f) is not None
                else None
                for f in DecodeState._fields
            }
        )
        tok_shard = NamedSharding(
            mesh, P(SH._dp(mesh)) if cell.batch >= 16 else P()
        )

        def step(params, token, state):
            return model.decode_step(params, token, state)

        jitted = jax.jit(
            step,
            in_shardings=(p_shard, tok_shard, state_shard),
            out_shardings=(None, state_shard),
            donate_argnums=(2,),
        )
        token_abstract = jax.ShapeDtypeStruct((cell.batch,), jnp.int32)
        lowered = jitted.lower(abstract_params, token_abstract, state_abstract)

    compiled = lowered.compile()
    ctx.close()
    ML.configure_shard_hints(())
    MT.set_layer_unroll(1)
    return compiled, lowered, {"param_bytes": _count_bytes(abstract_params)}


def run_cell(cell: SP.Cell, *, multi_pod: bool, save: bool = True,
             tag: str = ""):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": cell.arch,
        "shape": cell.shape,
        "mesh": _mesh_desc(mesh),
        "chips": chips,
        "kind": cell.kind,
    }
    try:
        def measure(unroll):
            compiled, lowered, meta = lower_cell(cell, mesh, unroll=unroll)
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            cost = dict(cost) if cost else {}
            coll = RA.parse_collectives(compiled.as_text())
            return compiled, cost, coll, meta

        compiled1, cost1, coll1, meta = measure(1)
        try:
            mem = compiled1.memory_analysis()
            mem_str = str(mem)
        except Exception as e:  # pragma: no cover
            mem, mem_str = None, f"unavailable: {e}"
        _, cost2, coll2, _ = measure(2)
        n_l = cell.cfg.n_layers
        cost, wire = RA.descanned_totals(cost1, coll1, cost2, coll2, n_l)
        roof = RA.build_roofline(
            arch=cell.arch,
            shape=cell.shape,
            mesh_desc=_mesh_desc(mesh),
            chips=chips,
            cost=cost,
            hlo_text=None,
            wire_bytes=wire,
            collective_counts=coll1.counts,
            model_flops=RA.model_flops_for_cell(cell, cell.cfg.n_active_params()),
            memory_analysis=mem_str,
        )
        rec.update(roof.to_dict())
        rec["status"] = "ok"
        rec["compile_s"] = time.time() - t0
        rec.update(meta)
        print(
            f"[dryrun] {cell.arch} × {cell.shape} × {rec['mesh']}: OK "
            f"({rec['compile_s']:.1f}s) bottleneck={roof.bottleneck} "
            f"compute={roof.compute_s:.4f}s memory={roof.memory_s:.4f}s "
            f"collective={roof.collective_s:.4f}s"
        )
        print(f"  memory_analysis: {mem_str[:300]}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {cell.arch} × {cell.shape}: FAILED — {rec['error']}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        pod = "multipod" if multi_pod else "singlepod"
        suffix = f"__{tag}" if tag else ""
        fn = f"{cell.arch}__{cell.shape}__{pod}{suffix}.json"
        slim = {k: v for k, v in rec.items() if k != "traceback"}
        slim["tag"] = tag
        with open(os.path.join(RESULTS_DIR, fn), "w") as f:
            json.dump(slim, f, indent=1, default=str)
    return rec


def run_qaoa_dryrun(*, multi_pod: bool, save: bool = True,
                    schedule: str = "alternating", tag: str = "",
                    group: int = 7):
    """Dry-run the paper's own workload on the production mesh: the
    solver-pool + sharded-statevector QAOA program (26 + log2(16) qubits)."""
    from repro.core import distributed as dist
    from repro.core.graph import Graph

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    n = 26 + int(np.log2(mesh.shape["model"]))  # 30 qubits on 16-way TP
    rec = {
        "arch": "paraqaoa",
        "shape": f"sharded_statevector_{n}q",
        "mesh": _mesh_desc(mesh),
        "chips": chips,
        "kind": "qaoa",
        "schedule": schedule,
    }
    try:
        e_abs = jax.ShapeDtypeStruct((2048, 2), jnp.int32)
        w_abs = jax.ShapeDtypeStruct((2048,), jnp.float32)
        g_abs = jax.ShapeDtypeStruct((3,), jnp.float32)

        def run(edges, weights, gammas, betas):
            return dist.sharded_qaoa(
                edges, weights, n, gammas, betas, mesh,
                schedule=schedule, top_k=4, group=group,
            )

        lowered = jax.jit(run).lower(e_abs, w_abs, g_abs, g_abs)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        try:
            mem_str = str(compiled.memory_analysis())
        except Exception as e:
            mem_str = f"unavailable: {e}"
        roof = RA.build_roofline(
            arch="paraqaoa",
            shape=rec["shape"],
            mesh_desc=rec["mesh"],
            chips=chips,
            cost=dict(cost) if cost else {},
            hlo_text=compiled.as_text(),
            # statevector "model flops": p layers × (mixer matmuls + phase)
            model_flops=3 * (2 ** n) * (2 * 128 + 8.0),
            memory_analysis=mem_str,
        )
        rec.update(roof.to_dict())
        rec["status"] = "ok"
        rec["compile_s"] = time.time() - t0
        print(
            f"[dryrun] paraqaoa {n}q × {rec['mesh']}: OK "
            f"({rec['compile_s']:.1f}s) bottleneck={roof.bottleneck}"
        )
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] paraqaoa: FAILED — {rec['error']}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        pod = "multipod" if multi_pod else "singlepod"
        suffix = f"__{tag}" if tag else ""
        rec["tag"] = tag
        with open(
            os.path.join(RESULTS_DIR, f"paraqaoa__qaoa_{schedule}__{pod}{suffix}.json"),
            "w",
        ) as f:
            json.dump({k: v for k, v in rec.items() if k != "traceback"}, f,
                      indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SP.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--qaoa", action="store_true")
    ap.add_argument(
        "--multi-pod", default="single", choices=["single", "multi", "both"]
    )
    ap.add_argument("--attn-shard", default="auto",
                    choices=["auto", "heads", "head_dim", "replicated"])
    ap.add_argument("--moe-shard", default="expert",
                    choices=["expert", "expert_ff"])
    ap.add_argument("--remat-policy", default="batch_dots",
                    choices=["batch_dots", "dots", "everything", "off"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-cap-shard", action="store_true")
    ap.add_argument("--moe-cap-factor", type=float, default=None)
    ap.add_argument("--qaoa-group", type=int, default=7)
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    args = ap.parse_args()
    SH.set_strategy(attn=args.attn_shard, moe=args.moe_shard)
    from repro.models import transformer as _MT

    _MT.set_remat_policy(args.remat_policy)
    _MT.set_seq_parallel(args.seq_parallel)
    from repro.models import moe as _MOE

    _MOE.set_capacity_sharding(args.moe_cap_shard)
    if args.moe_cap_factor:
        lower_cell._cap_factor = args.moe_cap_factor

    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    if args.qaoa:
        for mp in pods:
            for schedule in ("faithful", "alternating"):
                run_qaoa_dryrun(multi_pod=mp, schedule=schedule, tag=args.tag,
                                group=args.qaoa_group)
        return

    if args.all:
        cells = SP.all_cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all/--qaoa)"
        cells = [SP.get_cell(args.arch, args.shape)]

    failures = 0
    for cell in cells:
        if isinstance(cell, SP.SkipCell):
            print(f"[dryrun] SKIP {cell.arch} × {cell.shape}: {cell.reason}")
            os.makedirs(RESULTS_DIR, exist_ok=True)
            for mp in pods:
                pod = "multipod" if mp else "singlepod"
                fn = f"{cell.arch}__{cell.shape}__{pod}.json"
                with open(os.path.join(RESULTS_DIR, fn), "w") as f:
                    json.dump(
                        {
                            "arch": cell.arch,
                            "shape": cell.shape,
                            "status": "skipped",
                            "reason": cell.reason,
                        },
                        f,
                        indent=1,
                    )
            continue
        for mp in pods:
            if args.resume:
                pod = "multipod" if mp else "singlepod"
                suffix = f"__{args.tag}" if args.tag else ""
                fn = os.path.join(
                    RESULTS_DIR, f"{cell.arch}__{cell.shape}__{pod}{suffix}.json"
                )
                if os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("status") == "ok":
                            print(f"[dryrun] resume-skip {cell.arch} × {cell.shape} × {pod}")
                            continue
            rec = run_cell(cell, multi_pod=mp, tag=args.tag)
            failures += rec["status"] != "ok"
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()

"""GSPMD sharding rules: parameter-path → PartitionSpec.

Megatron-style tensor parallelism on the `model` axis, batch data
parallelism on `(pod, data)`:

  embeddings / unembedding   vocab on `model`
  attention q/o projections  head axis on `model` (falls back to head_dim
                             when the head count doesn't divide the axis,
                             e.g. gemma3-4b's 8 heads on a 16-way axis)
  attention k/v projections  kv-head axis when divisible, else replicated
  MLP up/gate ⊥ down         d_ff on `model` (column- then row-parallel)
  MoE experts                expert axis on `model` (expert parallelism)
  SSM in/out projections     d_inner on `model`
  norms / biases / scalars   replicated

Optimizer moments follow their parameter's spec (ZeRO-style sharding of
optimizer state along `model` comes for free; `data`-axis ZeRO is left as a
documented extension).

Batch specs: tokens/labels on `(pod+data, None)`; decode KV caches shard
the *sequence* axis across `data` when the batch is too small to shard
(long_500k), else the batch axis.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.models.config import ModelConfig


# ---------------------------------------------------------------- strategy --
# Perf-iteration knobs (EXPERIMENTS.md §Perf). Defaults reproduce the
# baseline; the dry-run's --attn-shard/--moe-shard flags override them.
STRATEGY = {
    # attention projections: auto (heads→head_dim fallback) | heads |
    # head_dim | replicated (no attention TP; MLP TP only)
    "attn": "auto",
    # moe experts: expert (E on model) | expert_ff (E on model, F on data)
    "moe": "expert",
}


def set_strategy(**kwargs):
    for k, v in kwargs.items():
        assert k in STRATEGY, k
        STRATEGY[k] = v


def _dp(mesh: Mesh):
    axes = compat.mesh_data_axes(mesh)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _div(n: int, mesh: Mesh, axis: str = "model") -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def param_spec(path: tuple, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter. `path` is a tuple of str keys
    (jax.tree_util key path entries stringified); `leaf` is the abstract
    array (its rank may include the stacked leading layer axis)."""
    keys = [str(k) for k in path]
    name = keys[-1]
    rank = len(leaf.shape)
    tp = mesh.shape.get("model", 1)

    def spec(*tail):
        """Pad with leading Nones for the stacked layer axis if present."""
        lead = rank - len(tail)
        return P(*([None] * lead + list(tail)))

    # ---- embeddings ----------------------------------------------------
    if "embed" in keys or "unembed" in keys:
        if _div(cfg.vocab_size, mesh):
            return spec("model", None)
        return spec(None, None)

    # ---- attention -----------------------------------------------------
    if name in ("wq", "wo"):
        mode = STRATEGY["attn"]
        heads_ok = cfg.n_heads and _div(cfg.n_heads, mesh) and mode in ("auto", "heads")
        hd_ok = (
            cfg.n_heads and _div(cfg.head_dim_, mesh)
            and mode in ("auto", "head_dim")
        )
        if name == "wq":  # (d, H, hd)
            if heads_ok:
                return spec(None, "model", None)
            if hd_ok:
                return spec(None, None, "model")
            return spec(None, None, None)
        # wo: (H, hd, d)
        if heads_ok:
            return spec("model", None, None)
        if hd_ok:
            return spec(None, "model", None)
        return spec(None, None, None)
    if name in ("wk", "wv"):  # (d, Hkv, hd)
        mode = STRATEGY["attn"]
        if (
            cfg.n_kv_heads and _div(cfg.n_kv_heads, mesh)
            and mode in ("auto", "heads")
        ):
            return spec(None, "model", None)
        if (
            cfg.n_heads and _div(cfg.head_dim_, mesh)
            and mode in ("auto", "head_dim")
        ):
            return spec(None, None, "model")
        return spec(None, None, None)
    if name in ("bq", "bk", "bv"):  # (H, hd)
        nh = cfg.n_heads if name == "bq" else cfg.n_kv_heads
        if nh and _div(nh, mesh):
            return spec("model", None)
        return spec(None, None)

    # ---- MoE -----------------------------------------------------------
    if name == "router":
        return spec(None, None)
    # expert weights live directly under "moe"; the arctic dense residual
    # lives under "moe"/"dense" and follows the dense-MLP rules below
    if "moe" in keys and "dense" not in keys and name in (
        "w_gate", "w_up", "w_down"
    ):
        if _div(cfg.n_experts, mesh):
            if STRATEGY["moe"] == "expert_ff" and _div(cfg.d_ff, mesh, "data"):
                # E on model + F on data: halves per-device expert weights
                # and lets the dispatch all-gather shrink accordingly
                if name == "w_down":  # (E, F, D)
                    return spec("model", "data", None)
                return spec("model", None, "data")  # (E, D, F)
            return spec("model", None, None)  # expert parallelism
        return spec(None, None, None)

    # ---- dense MLP (incl. arctic dense residual, zamba2 shared block) ---
    if name in ("w_gate", "w_up"):
        if _div(_d_ff_for(cfg, keys), mesh):
            return spec(None, "model")
        return spec(None, None)
    if name == "w_down":
        if _div(_d_ff_for(cfg, keys), mesh):
            return spec("model", None)
        return spec(None, None)
    if name in ("b_up",):
        return spec("model") if _div(_d_ff_for(cfg, keys), mesh) else spec(None)
    if name in ("b_down",):
        return spec(None)

    # ---- SSM -----------------------------------------------------------
    if name == "in_proj":  # (d, 2*di + 2*N + H) — heterogeneous columns
        return spec(None, None)  # replicated; see DESIGN notes
    if name == "out_proj":  # (di, d)
        if _div(cfg.d_inner, mesh):
            return spec("model", None)
        return spec(None, None)
    if name in ("conv_w", "conv_b", "a_log", "dt_bias", "d_skip"):
        return P(*([None] * rank))

    # ---- norms, scalars --------------------------------------------------
    return P(*([None] * rank))


def with_fsdp(spec: P, shape, mesh: Mesh, axes=("data",)) -> P:
    """ZeRO-3-style extension: additionally shard the largest still-
    unsharded, divisible dimension over the data axes. Parameters (and the
    optimizer moments that follow their spec) then scale with the full
    device count instead of only the model axis; GSPMD inserts the
    all-gathers at use sites."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    size = int(np.prod([mesh.shape[a] for a in axes]))
    cands = [
        (shape[i], i)
        for i in range(len(shape))
        if parts[i] is None and shape[i] % size == 0 and shape[i] >= size
    ]
    if not cands:
        return P(*parts)
    _, best = max(cands)
    parts[best] = axes if len(axes) > 1 else axes[0]
    return P(*parts)


def params_shardings(abstract_params, cfg: ModelConfig, mesh: Mesh,
                     fsdp: bool = False, fsdp_min_size: int = 1 << 20):
    """fsdp=True: train-style ZeRO-3 sharding over the data axes (skips
    small leaves where gather latency would dominate)."""
    axes = compat.mesh_data_axes(mesh)

    def per_leaf(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        spec = param_spec(keys, leaf, cfg, mesh)
        if fsdp and int(np.prod(leaf.shape)) >= fsdp_min_size:
            spec = with_fsdp(spec, leaf.shape, mesh, axes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(per_leaf, abstract_params)


def _key_str(k) -> str:
    # DictKey('x') → x ; SequenceKey(i) → str(i)
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str):
    """PartitionSpecs for the data batch of a given shape kind."""
    dp = _dp(mesh)
    if kind == "train":
        specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        if cfg.family == "vlm":
            specs["patches"] = P(dp, None, None)
        if cfg.family == "audio":
            specs["frames"] = P(dp, None, None)
        return specs
    if kind == "prefill":
        specs = {"tokens": P(dp, None)}
        if cfg.family == "vlm":
            specs["patches"] = P(dp, None, None)
        if cfg.family == "audio":
            specs["frames"] = P(dp, None, None)
        return specs
    raise ValueError(kind)


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, batch: int):
    """Shardings for DecodeState. Batch axis when it divides the dp axes;
    otherwise sequence-parallel over `data` (long-context single-request)."""
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,)) if a]))
    shard_batch = batch % max(dp_size, 1) == 0 and batch >= dp_size
    b_ax = dp if shard_batch else None
    s_ax = None if shard_batch else "data"
    kv_head_ax = "model" if _div(cfg.n_kv_heads or 1, mesh) else None

    from repro.models.decode import DecodeState

    def kv(_):
        return P(None, b_ax, s_ax, kv_head_ax, None)

    specs = {}
    specs["kv_k"] = kv(None)
    specs["kv_v"] = kv(None)
    specs["ssm_h"] = P(None, b_ax, "model" if _div(cfg.ssm_heads, mesh) and cfg.ssm_state else None, None, None)
    specs["ssm_conv"] = P(None, b_ax, None, None)
    specs["shared_k"] = kv(None)
    specs["shared_v"] = kv(None)
    specs["cross_k"] = kv(None)
    specs["cross_v"] = kv(None)
    specs["pos"] = P(b_ax)
    return DecodeState(**specs)


def _d_ff_for(cfg: ModelConfig, keys) -> int:
    # the zamba2 shared block and whisper MLPs use cfg.d_ff; arctic's dense
    # residual uses d_ff_dense (== d_ff here). One width fits all.
    return max(cfg.d_ff, 1)

"""Input shape cells: the assigned (architecture × input-shape) grid.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every
input of the lowered step function — weak-type-correct, shardable, zero
device allocation. `step_kind` tells the dry-run which program to lower:
train_step for `train_*`, prefill for `prefill_*`, decode_step for
`decode_*` / `long_*`.

Skip policy (DESIGN.md §4): long_500k runs only for sub-quadratic archs
(ssm / hybrid / gemma3's 5:1 local:global); pure full-attention archs skip
it. Every skip is an explicit `SkipCell` with the reason string that lands
in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32_768, batch=128, kind="decode"),
    "long_500k": dict(seq=524_288, batch=1, kind="decode"),
}

# archs allowed to run long_500k (sub-quadratic decode paths)
LONG_OK = {"mamba2_1_3b", "zamba2_2_7b", "gemma3_4b", "gemma3_27b"}


@dataclasses.dataclass(frozen=True)
class SkipCell:
    arch: str
    shape: str
    reason: str


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int
    cfg: ModelConfig


def all_cells():
    """The 40-cell grid; skipped cells appear as SkipCell records."""
    out = []
    for arch in configs.lm_arch_ids():
        cfg = configs.get_config(arch)
        for shape, meta in SHAPES.items():
            if shape == "long_500k" and arch not in LONG_OK:
                out.append(
                    SkipCell(
                        arch,
                        shape,
                        "pure full-attention decode at 524k context is "
                        "quadratic-cost/cache-infeasible by design; run only "
                        "for SSM/hybrid/5:1-local archs (DESIGN.md §4)",
                    )
                )
                continue
            out.append(
                Cell(arch, shape, meta["kind"], meta["seq"], meta["batch"], cfg)
            )
    return out


def get_cell(arch: str, shape: str) -> Cell:
    arch = configs.canonical(arch)
    meta = SHAPES[shape]
    return Cell(
        arch, shape, meta["kind"], meta["seq"], meta["batch"],
        configs.get_config(arch),
    )


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cell: Cell):
    """Abstract data inputs for the cell's step function."""
    cfg = cell.cfg
    b, s = cell.batch, cell.seq
    if cell.kind == "train":
        text = s - (cfg.frontend_seq if cfg.family == "vlm" else 0)
        specs = {
            "tokens": _sds((b, text), jnp.int32),
            "labels": _sds((b, text), jnp.int32),
        }
        if cfg.family == "vlm":
            specs["patches"] = _sds((b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            specs["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if cell.kind == "prefill":
        text = s - (cfg.frontend_seq if cfg.family == "vlm" else 0)
        specs = {"tokens": _sds((b, text), jnp.int32)}
        if cfg.family == "vlm":
            specs["patches"] = _sds((b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            specs["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if cell.kind == "decode":
        return {"token": _sds((b,), jnp.int32)}
    raise ValueError(cell.kind)


def decode_state_specs_abstract(cell: Cell):
    """Abstract DecodeState for decode cells (cache sized to the cell seq)."""
    from repro.models import decode as D

    return jax.eval_shape(
        lambda: D.init_decode_state(cell.cfg, cell.batch, cell.seq)
    )

"""Serving driver: batched generation over the prefill/decode substrate.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --batch 4 --prompt-len 16 --new-tokens 32 --reduced

Reduced (CPU smoke) configs are the default; pass ``--full-size`` for the
published shapes.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import build_model
from repro.serving.engine import Engine, ServeConfig


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    # paired on/off flags (portable argparse.BooleanOptionalAction): the
    # old `--reduced` was store_true with default=True, which made the
    # full-size path unreachable from the CLI
    ap.add_argument("--reduced", dest="reduced", action="store_true",
                    default=True,
                    help="CPU smoke-test config (default)")
    ap.add_argument("--full-size", dest="reduced", action="store_false",
                    help="published full-size config")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def run(argv=None):
    args = build_parser().parse_args(argv)

    cfg = (
        configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(
        model, params,
        ServeConfig(max_new_tokens=args.new_tokens, temperature=args.temperature),
    )

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.frontend_seq, cfg.d_model)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)
        )

    t0 = time.time()
    out = engine.generate(batch)
    dt = time.time() - t0
    tps = out.size / dt
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({tps:.0f} tok/s on this host)")
    print(f"[serve] first rows: {out[:2, :12].tolist()}")
    return out


if __name__ == "__main__":
    run()

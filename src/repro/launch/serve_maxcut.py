"""Max-Cut solve-service driver: concurrent requests through the batched
scheduler (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 8 \
      --n-min 40 --n-max 120 --deadline 30 --repeat-frac 0.25

  # anytime streaming: print the best-known cut after every merge level
  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 2 --stream
"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve_maxcut",
        description="Serve a batch of concurrent Max-Cut solve requests "
        "through the cross-request batching scheduler (SLA planner + "
        "canonical-graph result cache + anytime merge stream).",
    )
    ap.add_argument("--requests", type=int, default=8,
                    help="number of concurrent solve requests to admit")
    ap.add_argument("--n-min", type=int, default=40,
                    help="smallest request vertex count")
    ap.add_argument("--n-max", type=int, default=120,
                    help="largest request vertex count")
    ap.add_argument("--p", type=float, default=0.15,
                    help="Erdős-Rényi edge probability of the request mix")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-mix seed (runs are seed-stable)")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    help="fraction of requests that repeat an earlier graph "
                    "under a random vertex relabeling (exercises the "
                    "canonical-graph cache)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request SLA deadline in seconds (omit for "
                    "best-quality planning)")
    ap.add_argument("--target-quality", type=float, default=None,
                    help="per-request accuracy-proxy target (planner "
                    "quality scale); the planner meets it at minimum "
                    "predicted cost")
    ap.add_argument("--qubits", type=int, default=12,
                    help="hardware qubit budget cap for the SLA planner")
    ap.add_argument("--batch", type=int, default=16,
                    help="solver batch slots per dispatch (cross-request)")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="result-cache entries (LRU beyond this)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the canonical-graph result cache")
    ap.add_argument("--stream", action="store_true",
                    help="anytime mode: print the best-known cut after "
                    "every merge level of every request")
    return ap


def run(argv=None):
    args = build_parser().parse_args(argv)

    from repro.service import SLA, ServiceConfig, SolveService
    from repro.service.workload import request_mix

    requests = request_mix(
        args.requests, (args.n_min, args.n_max), args.p,
        args.repeat_frac, args.seed,
    )

    svc = SolveService(
        ServiceConfig(
            batch_slots=args.batch,
            cache_capacity=args.cache_capacity,
            enable_cache=not args.no_cache,
            max_qubits=args.qubits,
        )
    )
    sla = SLA(deadline_s=args.deadline, target_quality=args.target_quality)

    def on_update(rid, level, n_levels, cut):
        print(f"[serve_maxcut]   req {rid} level {level}/{n_levels}: "
              f"best-known cut {cut:.0f}")

    t0 = time.perf_counter()
    rids = [
        svc.submit(g, sla, stream=args.stream,
                   on_update=on_update if args.stream else None)
        for g in requests
    ]
    svc.drain()
    wall = time.perf_counter() - t0

    for g, rid in zip(requests, rids):
        r = svc.results[rid]
        kn = r.plan.knobs
        src = "cache" if r.cached else (
            f"N={kn.n_qubits} K={kn.top_k} T={kn.opt_steps} W={kn.beam_width}"
        )
        print(f"[serve_maxcut] req {rid}: n={g.n} cut={r.cut_value:.0f} "
              f"latency={r.latency_s:.2f}s ({src})")

    lat = sorted(r.latency_s for r in svc.results.values())
    p50 = lat[len(lat) // 2]
    print(f"[serve_maxcut] {len(rids)} requests in {wall:.2f}s "
          f"({len(rids) / wall:.2f} req/s), p50 latency {p50:.2f}s")
    print(f"[serve_maxcut] batching: {svc.stats.as_dict()}")
    print(f"[serve_maxcut] cache: {svc.cache.stats.as_dict()}")
    return svc


if __name__ == "__main__":
    run()

"""Max-Cut solve-service driver: concurrent requests through the batched
scheduler (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 8 \
      --n-min 40 --n-max 120 --deadline 30 --repeat-frac 0.25

  # route the packed buckets through solve_pool over a 4-device `data`
  # mesh (emulated on a single-CPU host, like solve_maxcut --mesh)
  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 8 --mesh data=4

  # two tenants with skewed traffic: per-tenant fairness accounting
  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 8 --tenants 2

  # anytime streaming: print the best-known cut after every merge level
  PYTHONPATH=src python -m repro.launch.serve_maxcut --requests 2 --stream
"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve_maxcut",
        description="Serve a batch of concurrent Max-Cut solve requests "
        "through the cross-request batching scheduler (SLA planner + "
        "canonical-graph result cache + anytime merge stream).",
    )
    ap.add_argument("--requests", type=int, default=8,
                    help="number of concurrent solve requests to admit")
    ap.add_argument("--n-min", type=int, default=40,
                    help="smallest request vertex count")
    ap.add_argument("--n-max", type=int, default=120,
                    help="largest request vertex count")
    ap.add_argument("--p", type=float, default=0.15,
                    help="Erdős-Rényi edge probability of the request mix")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-mix seed (runs are seed-stable)")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    help="fraction of requests that repeat an earlier graph "
                    "under a random vertex relabeling (exercises the "
                    "canonical-graph cache)")
    ap.add_argument("--problem", choices=("maxcut", "qubo", "mis"),
                    default="maxcut",
                    help="problem family of the request mix: Max-Cut "
                    "graphs, random QUBOs (quadratic + linear terms), or "
                    "penalty-encoded maximum-independent-set instances — "
                    "all served through the same diagonal-cost oracle")
    ap.add_argument("--weights", choices=("unit", "uniform", "spin"),
                    default="unit",
                    help="edge-weight family of the instance topology: "
                    "unit weights, uniform(0.1,1) weights, or ±1 "
                    "spin-glass couplings")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request SLA deadline in seconds (omit for "
                    "best-quality planning)")
    ap.add_argument("--floor-quality", type=float, default=None,
                    help="per-request accuracy floor (planner quality "
                    "scale): deadline downgrades never re-plan below it, "
                    "and admission sheds when even the floor plan is "
                    "predicted to miss the deadline (DESIGN.md §6.6)")
    ap.add_argument("--no-enforce-sla", action="store_true",
                    help="disable §6.6 deadline enforcement (downgrade/"
                    "shed/expire); predicted-late requests are admitted "
                    "and served late, as in the pre-enforcement service")
    ap.add_argument("--target-quality", type=float, default=None,
                    help="per-request accuracy-proxy target (planner "
                    "quality scale); the planner meets it at minimum "
                    "predicted cost")
    ap.add_argument("--qubits", type=int, default=12,
                    help="hardware qubit budget cap for the SLA planner")
    ap.add_argument("--batch", type=int, default=16,
                    help="solver batch slots per dispatch (cross-request)")
    ap.add_argument("--mesh", type=str, default=None, metavar="SPEC",
                    help="route packed buckets through solve_pool over this "
                    "device mesh, e.g. 'data=4' (axes: pod/data; cuts stay "
                    "bit-identical to the single-device service). On a "
                    "single-CPU host the devices are emulated "
                    "(docs/TESTING.md). Omit for the single-device backend")
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of tenants the request mix is (skew-)"
                    "assigned to; the dispatcher round-robins slots across "
                    "tenants and reports per-tenant stats")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="solver batches in flight before the event loop "
                    "blocks on the oldest (async admission window)")
    ap.add_argument("--no-recalibrate", action="store_true",
                    help="freeze the planner's cost model at the committed "
                    "benchmark fit instead of streaming served-request "
                    "timings back into it")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="result-cache entries (LRU beyond this)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the canonical-graph result cache")
    ap.add_argument("--stream", action="store_true",
                    help="anytime mode: print the best-known cut after "
                    "every merge level of every request")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="export the request-to-kernel span trace here "
                    "(tracing is off unless this is set; DESIGN.md §8)")
    ap.add_argument("--trace-format", choices=("jsonl", "chrome"),
                    default="jsonl",
                    help="trace export format: 'jsonl' (one span per "
                    "line) or 'chrome' (Perfetto-loadable trace events)")
    ap.add_argument("--metrics-out", type=str, default=None, metavar="PATH",
                    help="export the service metrics snapshot here "
                    "(counters, gauges, latency histograms)")
    ap.add_argument("--metrics-format", choices=("json", "prom"),
                    default="json",
                    help="metrics export format: JSON snapshot or "
                    "Prometheus text exposition")
    return ap


def run(argv=None):
    args = build_parser().parse_args(argv)

    mesh_spec = None
    if args.mesh:
        # parse + emulate *before* the first jax backend touch (graph
        # construction below creates device arrays)
        from repro import compat
        from repro.launch.mesh import mesh_spec_size, parse_mesh_spec

        mesh_spec = parse_mesh_spec(args.mesh)
        need = mesh_spec_size(mesh_spec)
        have = compat.ensure_host_device_count(need)
        if have < need:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices but the jax "
                f"backend is already up with {have}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}"
            )

    from repro.obs.trace import Tracer
    from repro.service import SLA, ServiceConfig, SolveService
    from repro.service.workload import problem_mix, tenant_mix

    requests = problem_mix(
        args.requests, (args.n_min, args.n_max), args.p,
        args.repeat_frac, args.seed,
        problem=args.problem, weights=args.weights,
    )
    tenants = tenant_mix(args.requests, args.tenants, args.seed)

    # §8: tracing is enabled only when an export path is requested; the
    # tracer shares the service's clock (the default here)
    tracer = Tracer(record=True) if args.trace_out else None
    svc = SolveService(
        ServiceConfig(
            batch_slots=args.batch,
            cache_capacity=args.cache_capacity,
            enable_cache=not args.no_cache,
            max_qubits=args.qubits,
            mesh=mesh_spec,
            max_inflight=args.max_inflight,
            recalibrate=not args.no_recalibrate,
            enforce_deadlines=not args.no_enforce_sla,
        ),
        tracer=tracer,
    )
    sla = SLA(deadline_s=args.deadline, target_quality=args.target_quality,
              floor_quality=args.floor_quality)

    def on_update(rid, level, n_levels, cut):
        print(f"[serve_maxcut]   req {rid} level {level}/{n_levels}: "
              f"best-known cut {cut:.0f}")

    t0 = time.perf_counter()
    rids = [
        svc.submit(g, sla, stream=args.stream,
                   on_update=on_update if args.stream else None,
                   tenant=tenant)
        for g, tenant in zip(requests, tenants)
    ]
    svc.drain()
    wall = time.perf_counter() - t0

    for g, rid in zip(requests, rids):
        r = svc.results[rid]
        if r.status != "completed":
            # shed at admission (floor plan predicted late) or expired
            # pre-dispatch — no cut was served (DESIGN.md §6.6)
            print(f"[serve_maxcut] req {rid} ({r.tenant}): n={g.n} "
                  f"{r.status.upper()} after {r.latency_s:.2f}s")
            continue
        kn = r.plan.knobs
        src = "cache" if r.cached else (
            f"N={kn.n_qubits} K={kn.top_k} T={kn.opt_steps} W={kn.beam_width}"
        )
        tail = f" [{r.downgrades} downgrade(s)]" if r.downgrades else ""
        integral = args.problem == "maxcut" and args.weights == "unit"
        val = f"{r.cut_value:.0f}" if integral else f"{r.cut_value:.2f}"
        print(f"[serve_maxcut] req {rid} ({r.tenant}): n={g.n} "
              f"value={val} latency={r.latency_s:.2f}s ({src})"
              f"{tail}")

    st = svc.stats
    p50 = st.latency.percentile(0.5)
    print(f"[serve_maxcut] {len(rids)} requests in {wall:.2f}s "
          f"({len(rids) / wall:.2f} req/s), p50 latency {p50:.2f}s")
    if args.deadline is not None and not args.no_enforce_sla:
        print(f"[serve_maxcut] sla: attainment={st.attainment:.3f} "
              f"completed={st.completed} shed={st.shed} "
              f"expired={st.expired} downgrades={st.downgrade_events}")
    print(f"[serve_maxcut] backend: {svc.backend.describe()}")
    print(f"[serve_maxcut] batching: {svc.stats.as_dict()}")
    print(f"[serve_maxcut] cache: {svc.cache.stats.as_dict()}")
    if not args.no_recalibrate:
        print(f"[serve_maxcut] recalibration: "
              f"{svc.planner.calibration.as_dict()}")
    if args.trace_out:
        svc.trace.export(args.trace_out, args.trace_format)
        print(f"[serve_maxcut] trace ({args.trace_format}, "
              f"{len(svc.trace.spans)} spans): {args.trace_out}")
    if args.metrics_out:
        reg = svc.metrics_registry()
        with open(args.metrics_out, "w") as f:
            f.write(reg.to_json() if args.metrics_format == "json"
                    else reg.to_prometheus())
        print(f"[serve_maxcut] metrics ({args.metrics_format}): "
              f"{args.metrics_out}")
    return svc


if __name__ == "__main__":
    run()

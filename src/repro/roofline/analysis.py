"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / 197e12          (bf16 MXU peak)
  memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
  collective = Σ_ops bytes·factor / 50e9              (per-link ICI)

FLOPs/bytes come from compiled.cost_analysis() of the *partitioned*
module (i.e. per-device numbers). Collective bytes are parsed from the
post-SPMD HLO text; per-op wire factors use the ring-algorithm byte counts
with the op's replica-group size g:

  all-reduce      2·(g−1)/g · size     all-gather      (g−1)/g · size(out)
  reduce-scatter  (g−1)/g · size(in)   all-to-all      (g−1)/g · size
  collective-permute  1 · size

MODEL_FLOPS uses 6·N·D (train) / 2·N·D (forward-only), N = active params.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

# per-backend (peak_flops, memory_bandwidth) envelopes for single-kernel
# bounds. The tpu row is the v5e chip above; the cpu row is a nominal
# host envelope so the autotune harness's achieved-vs-peak column stays
# defined on CPU/interpret sweeps — a scoreboard for relative tile
# quality there, not silicon truth.
KERNEL_PEAKS = {
    "tpu": (PEAK_FLOPS, HBM_BW),
    "cpu": (2.0e11, 5.0e10),
}


def kernel_bound_s(flops: float, bytes_accessed: float,
                   backend: str = "tpu") -> float:
    """Roofline lower bound for one kernel launch on `backend`:
    max(compute-limited, memory-limited) seconds."""
    pf, pb = KERNEL_PEAKS.get(backend, KERNEL_PEAKS["tpu"])
    return max(flops / pf, bytes_accessed / pb)


def achieved_fraction(flops: float, bytes_accessed: float, seconds: float,
                      backend: str = "tpu") -> float:
    """bound/measured — 1.0 means the launch hit the peak model; the
    autotuner records this per (op, shape-bucket) candidate."""
    if seconds <= 0.0:
        return 0.0
    return kernel_bound_s(flops, bytes_accessed, backend) / seconds

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict
    wire_bytes: float  # factor-adjusted bytes on the wire per device


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    raw: dict = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":  # avoid double counting start/done pairs
            continue
        size = _shape_bytes(type_str)
        g = _group_size(line)
        if op == "all-reduce":
            factor = 2.0 * (g - 1) / g
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            factor = (g - 1) / g
        else:  # collective-permute
            factor = 1.0
        counts[op] = counts.get(op, 0) + 1
        raw[op] = raw.get(op, 0) + size
        wire += size * factor
    return CollectiveStats(counts=counts, bytes_by_op=raw, wire_bytes=wire)


def _group_size(line: str) -> int:
    m = _GROUPS_ALT_RE.search(line)
    if m:
        return max(int(m.group(2)), 2)
    m = _GROUPS_RE.search(line)
    if m:
        inner = m.group(1).strip("{}")
        n = len([x for x in inner.split(",") if x.strip() != ""])
        return max(n, 2)
    return 2


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    collectives: dict
    memory_analysis: Optional[str] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def descanned_totals(cost1, coll1, cost2, coll2, n_layers: int):
    """Undo cost_analysis's count-the-while-body-once behaviour.

    With layer-scan unroll u, every per-layer quantity appears u times:
    m(u) = a + u·b, so total = a + L·b = m1 + (L-1)·(m2-m1). Negative
    deltas (CSE noise) clamp to zero, leaving m1 as a lower bound.
    """
    def solve(m1, m2):
        delta = max(m2 - m1, 0.0)
        return m1 + (n_layers - 1) * delta

    cost = dict(cost1)
    for key in ("flops", "bytes accessed"):
        cost[key] = solve(float(cost1.get(key, 0.0)), float(cost2.get(key, 0.0)))
    wire = solve(coll1.wire_bytes, coll2.wire_bytes)
    return cost, wire


def build_roofline(
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    cost: dict,
    model_flops: float,
    hlo_text: Optional[str] = None,
    wire_bytes: Optional[float] = None,
    collective_counts: Optional[dict] = None,
    memory_analysis: Optional[str] = None,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if wire_bytes is None:
        coll = parse_collectives(hlo_text or "")
        wire_bytes = coll.wire_bytes
        collective_counts = coll.counts
    coll = CollectiveStats(
        counts=collective_counts or {}, bytes_by_op={}, wire_bytes=wire_bytes
    )
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops * chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_wire_bytes=coll.wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=useful,
        collectives={"counts": coll.counts, "bytes": coll.bytes_by_op},
        memory_analysis=memory_analysis,
    )


def model_flops_for_cell(cell, n_params_active: int) -> float:
    """6·N·D for train, 2·N·D for prefill, 2·N·B (+ attention KV read
    flops) for one decode step."""
    if cell.kind == "train":
        return 6.0 * n_params_active * cell.batch * cell.seq
    if cell.kind == "prefill":
        return 2.0 * n_params_active * cell.batch * cell.seq
    # decode: one token per request
    flops = 2.0 * n_params_active * cell.batch
    cfg = cell.cfg
    if cfg.n_heads:  # attention reads the KV cache: 2·2·S·H·hd per layer
        windows = cfg.layer_windows()
        for w in windows:
            s_eff = cell.seq if w == 0 else min(w, cell.seq)
            flops += 4.0 * cell.batch * s_eff * cfg.n_heads * cfg.head_dim_
    return flops

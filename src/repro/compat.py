"""Version-portability layer for JAX SPMD APIs.

The distributed runtime (core/distributed.py, launch/mesh.py,
launch/sharding.py) must run unchanged on:

  - stock JAX 0.4.x, where ``shard_map`` lives at
    ``jax.experimental.shard_map.shard_map`` and takes ``check_rep=``;
  - new-style JAX (>= 0.6), where it is ``jax.shard_map`` and the kwarg
    was renamed ``check_vma=``;
  - a laptop / CI runner with one physical CPU (via
    ``--xla_force_host_platform_device_count`` host-device emulation) or a
    real multi-device mesh.

Everything version- or platform-conditional funnels through this module so
call sites stay clean:

  ``shard_map(f, mesh, in_specs, out_specs, check=False)``
      Resolved implementation with the check kwarg adapted.
  ``jit(f, donate_argnums=...)``
      ``jax.jit`` that drops buffer donation on backends that do not
      implement it (CPU), avoiding per-call "donation not usable" warnings.
  ``make_mesh(shape, axis_names)``
      ``jax.make_mesh`` when present, else mesh_utils + Mesh.
  ``ensure_host_device_count(n)``
      Idempotent CPU host-device emulation: appends the XLA flag if the
      backend is not yet initialized (no-op, with the actual count
      returned, when it is).

See docs/TESTING.md for the support matrix.
"""

from __future__ import annotations

import functools
import inspect
import os
from typing import Any, Callable, Sequence

import jax

JAX_VERSION: tuple = tuple(int(x) for x in jax.__version__.split(".")[:3])


# ------------------------------------------------------------- shard_map --
def _resolve_shard_map() -> Callable:
    sm = getattr(jax, "shard_map", None)  # new-style (jax >= 0.6)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # 0.4.x
    return sm


_RAW_SHARD_MAP: Callable = _resolve_shard_map()


def _check_kwarg_name() -> str | None:
    """'check_vma' (new), 'check_rep' (0.4.x), or None if neither exists."""
    try:
        params = inspect.signature(_RAW_SHARD_MAP).parameters
    except (TypeError, ValueError):  # builtins / odd wrappers: be permissive
        return None
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


_CHECK_KWARG: str | None = _check_kwarg_name()


def shard_map(f: Callable, mesh, in_specs, out_specs, *, check: bool = False):
    """Portable shard_map. ``check`` maps onto check_vma/check_rep.

    The runtime disables replication/VMA checking by default: the merge
    winner-select and top-k reductions produce values that *are* replicated
    but that the static checkers of several JAX versions cannot prove so.
    """
    kwargs: dict = {}
    if _CHECK_KWARG is not None:
        kwargs[_CHECK_KWARG] = check
    return _RAW_SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# -------------------------------------------------------------------- jit --
def supports_donation(platform: str | None = None) -> bool:
    """Buffer donation is implemented on TPU/GPU; CPU silently ignores it
    and warns per call."""
    platform = platform or jax.default_backend()
    return platform in ("tpu", "gpu", "cuda", "rocm")


def jit(f: Callable, *, donate_argnums: Sequence[int] = (), **kwargs):
    """jax.jit that applies ``donate_argnums`` only where donation works."""
    if donate_argnums and supports_donation():
        kwargs["donate_argnums"] = tuple(donate_argnums)
    return jax.jit(f, **kwargs)


# ------------------------------------------------------------------- mesh --
def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """Portable dense device mesh over the default backend's devices."""
    mk = getattr(jax, "make_mesh", None)  # jax >= 0.4.35
    if mk is not None:
        return mk(tuple(shape), tuple(axis_names))
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    return Mesh(mesh_utils.create_device_mesh(tuple(shape)), tuple(axis_names))


def _backend_initialized() -> bool:
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:  # private API moved: assume initialized (conservative)
        return True


_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_device_count(n: int) -> int:
    """Arrange for >= n devices on the host platform (CPU emulation).

    Must run before the first jax backend touch (device queries, array
    creation). Idempotent; returns the device count that will be (or
    already is) visible. When the backend is already up with fewer
    devices, returns that smaller count — callers should size their mesh
    by the return value or skip.
    """
    if _backend_initialized():
        return len(jax.devices())
    flags = os.environ.get("XLA_FLAGS", "")
    if _HOST_COUNT_FLAG in flags:
        # operator already chose a count: the environment wins
        return int(flags.split(f"{_HOST_COUNT_FLAG}=")[1].split()[0])
    os.environ["XLA_FLAGS"] = f"{flags} {_HOST_COUNT_FLAG}={n}".strip()
    return n


def device_count() -> int:
    return len(jax.devices())


def mesh_data_axes(mesh) -> tuple:
    """All batch-shardable axes present in the mesh, in canonical order."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def mesh_model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.shape else None


# ------------------------------------------------------- program caching --
_PROGRAM_CACHE_SIZE = 32


def _arg_signature(args: tuple, kwargs: dict) -> str:
    """Shape/dtype signature of a program call — the axis jit's own cache
    keys on beyond the builder's static key."""
    parts = []
    for leaf in jax.tree_util.tree_leaves((args, kwargs)):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is not None and dtype is not None:
            parts.append(f"{dtype}{list(shape)}")
        else:
            parts.append(type(leaf).__name__)
    return ";".join(parts)


class _LedgerProgram:
    """Pass-through wrapper over a built program that records each first
    call at a novel shape signature in the compile ledger — the call that
    pays trace + XLA compile. Same-signature calls are ledger-free."""

    __slots__ = ("_program", "_name", "_key", "_seen")

    def __init__(self, program: Callable, name: str, key: str):
        self._program = program
        self._name = name
        self._key = key
        self._seen: set = set()

    def __call__(self, *args, **kwargs):
        sig = _arg_signature(args, kwargs)
        if sig in self._seen:
            return self._program(*args, **kwargs)
        self._seen.add(sig)
        from repro.obs.clock import default_clock
        from repro.obs.ledger import get_ledger

        t0 = default_clock()
        out = self._program(*args, **kwargs)
        get_ledger().note_compile(self._name, self._key, sig,
                                  default_clock() - t0)
        return out

    def __getattr__(self, attr):
        return getattr(self._program, attr)


def cached_program(builder: Callable) -> Callable:
    """LRU-cache a compiled-program builder keyed on its (hashable) args.

    The per-call ``jax.jit(shard_map(...))`` pattern builds a *new* jit
    wrapper every call, so every ``solve_pool`` call re-traces and
    re-compiles — a hidden hot-path cost once the solver pool serves
    repeated partitions. Builders decorated with this return the same
    compiled callable for the same static configuration; jit's own cache
    then handles shape/dtype polymorphism.

    Bounded (not maxsize=None): cache keys include the Mesh, and an
    elastic job that re-meshes after failures would otherwise pin every
    historical mesh + compiled executable forever. LRU eviction drops the
    oldest program (and its jit wrapper) once more than
    ``_PROGRAM_CACHE_SIZE`` static configurations have been seen.

    Every cache miss records a ``build`` event in the compile ledger
    (`repro.obs.ledger`), and the returned program records a ``compile``
    event on its first call at each novel shape signature — so a warm
    re-run provably records nothing (DESIGN.md §8). Identity semantics
    are unchanged: same key → the same wrapper object.
    """
    @functools.wraps(builder)
    def build(*key):
        from repro.obs.clock import default_clock
        from repro.obs.ledger import get_ledger

        t0 = default_clock()
        program = builder(*key)
        get_ledger().note_build(builder.__name__, repr(key),
                                default_clock() - t0)
        return _LedgerProgram(program, builder.__name__, repr(key))

    return functools.lru_cache(maxsize=_PROGRAM_CACHE_SIZE)(build)

"""Batched serving engine: continuous greedy/temperature decoding over the
prefill/decode substrate, with per-request completion tracking.

This is the serve-side end-to-end driver. On a pod the same engine runs
under pjit with the decode-state shardings from launch/sharding.py
(batch-sharded for throughput shapes, sequence-sharded KV for the 500k
single-stream shapes — proven by the decode_* dry-run cells).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 64
    temperature: float = 0.0  # 0 → greedy
    eos_id: Optional[int] = None
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, serve_cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = serve_cfg
        self._prefill = jax.jit(
            lambda p, b, s_max: model.prefill(p, b, s_max=s_max),
            static_argnums=(2,),
        )
        self._decode = jax.jit(model.decode_step)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.cfg.temperature, axis=-1)

    def generate(self, batch) -> np.ndarray:
        """batch: {"tokens": (B, S_prompt), ...family extras}. Returns the
        generated token matrix (B, max_new_tokens)."""
        mcfg = self.model.cfg
        bsz, prompt_len = batch["tokens"].shape
        extra = mcfg.frontend_seq if mcfg.family == "vlm" else 0
        s_max = prompt_len + extra + self.cfg.max_new_tokens + 1

        logits, state = self._prefill(self.params, batch, s_max)
        key = jax.random.PRNGKey(self.cfg.seed)
        key, k0 = jax.random.split(key)
        tok = self._sample(logits[:, 0], k0)

        out = [tok]
        done = jnp.zeros((bsz,), bool)
        for _ in range(self.cfg.max_new_tokens - 1):
            logits, state = self._decode(self.params, tok, state)
            key, kt = jax.random.split(key)
            tok = self._sample(logits, kt)
            if self.cfg.eos_id is not None:
                done = done | (tok == self.cfg.eos_id)
                tok = jnp.where(done, self.cfg.eos_id, tok)
                if bool(jnp.all(done)):
                    out.append(tok)
                    break
            out.append(tok)
        return np.asarray(jnp.stack(out, axis=1))

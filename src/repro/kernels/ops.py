"""Dispatch layer: every hot op has a Pallas TPU kernel and a pure-jnp path.

``implementation``:
  - "auto":   Pallas on TPU, XLA (jnp reference) elsewhere.
  - "xla":    always the jnp reference path (fast on CPU).
  - "pallas": compiled Pallas kernels (TPU).
  - "pallas_interpret": Pallas kernels in interpret mode (CPU correctness
    validation; slow — used by tests).

The jnp reference path *is* `kernels.ref` — there is exactly one source of
truth for each op's semantics.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.obs.ledger import get_ledger

_IMPL = "auto"


def set_implementation(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "xla", "pallas", "pallas_interpret"), impl
    _IMPL = impl


@contextlib.contextmanager
def using_implementation(impl: str):
    """Scoped implementation override: restores the previous selection on
    exit (even on error). Dispatch happens at *trace* time, so programs
    cached outside the context keep whatever implementation they were
    traced under — cached-program builders that must honor the override
    include `get_implementation()` in their cache key."""
    global _IMPL
    prev = _IMPL
    set_implementation(impl)
    try:
        yield
    finally:
        _IMPL = prev


def get_implementation() -> str:
    if _IMPL != "auto":
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pallas(interpret_ok: bool = True):
    impl = get_implementation()
    if impl == "pallas":
        return dict(use=True, interpret=False)
    if impl == "pallas_interpret":
        return dict(use=True, interpret=True)
    return dict(use=False, interpret=False)


def _note(op: str, x) -> None:
    """Compile-ledger op event: dispatch happens at *trace* time, so an
    op entered with tracer-typed arguments fires exactly once per
    (re)trace of the enclosing program — retrace storms show up as op
    counts in the ledger (DESIGN.md §8). Concrete-argument (eager) calls
    record nothing."""
    if isinstance(x, jax.core.Tracer):
        get_ledger().note_op(op, get_implementation())


def cutvals(n: int, edges, weights):
    _note("cutvals", edges)
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutvals as k

        return k.cutvals(n, edges, weights, interpret=p["interpret"])
    return ref.cutvals(n, edges, weights)


def cutvals_at(idx, edges, weights):
    _note("cutvals_at", idx)
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutvals as k

        return k.cutvals_at(idx, edges, weights, interpret=p["interpret"])
    return ref.cutvals_at(idx, edges, weights)


def apply_phase(re, im, cutv, gamma):
    _note("apply_phase", re)
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.apply_phase(re, im, cutv, gamma, interpret=p["interpret"])
    return ref.apply_phase(re, im, cutv, gamma)


def apply_mixer(re, im, n: int, beta, group: int = 7):
    _note("apply_mixer", re)
    p = _pallas()
    if p["use"]:
        from repro.kernels import mixer as k

        return k.apply_mixer(re, im, n, beta, group=group, interpret=p["interpret"])
    return ref.apply_mixer(re, im, n, beta, group=group)


def apply_mixer_bits(re, im, n: int, lo_bit: int, nbits: int, beta):
    _note("apply_mixer_bits", re)
    p = _pallas()
    if p["use"]:
        from repro.kernels import mixer as k

        return k.apply_mixer_bits(
            re, im, n, lo_bit, nbits, beta, interpret=p["interpret"]
        )
    return ref.apply_mixer_bits(re, im, n, lo_bit, nbits, beta)


def apply_layer(re, im, cutv, gamma, beta, n: int, group: int = 7):
    """One full intra-shard QAOA layer: cost phase, then the n-qubit mixer.

    This is the op the statevector engine (core/engine.py, DESIGN.md §2.6)
    runs per layer on every path — flat or per-shard. On the Pallas path
    the phase and the *first* mixer group go through the fused
    `kernels/fused_layer.py` kernel (one VMEM round-trip, §Perf C3) and
    the remaining groups through the mixer kernel; the XLA path is the
    exact phase-then-mixer reference decomposition.
    """
    _note("apply_layer", re)
    p = _pallas()
    if p["use"]:
        from repro.kernels import fused_layer as fl
        from repro.kernels import mixer as mk

        k = min(group, n)
        dk = 2**k
        re_m, im_m = fl.fused_phase_mixer_group(
            re.reshape(-1, dk),
            im.reshape(-1, dk),
            cutv.reshape(-1, dk),
            gamma,
            beta,
            k,
            interpret=p["interpret"],
        )
        re, im = re_m.reshape(-1), im_m.reshape(-1)
        for g0 in range(k, n, group):
            re, im = mk.apply_mixer_bits(
                re, im, n, g0, min(group, n - g0), beta,
                interpret=p["interpret"],
            )
        return re, im
    re, im = ref.apply_phase(re, im, cutv, gamma)
    return ref.apply_mixer(re, im, n, beta, group=group)


def expectation(re, im, cutv):
    _note("expectation", re)
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.expectation(re, im, cutv, interpret=p["interpret"])
    return ref.expectation(re, im, cutv)


def cut_batch_dense(spins, adjacency, total_weight):
    _note("cut_batch_dense", spins)
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutbatch as k

        return k.cut_batch_dense(spins, adjacency, total_weight, interpret=p["interpret"])
    return ref.cut_batch_dense(spins, adjacency, total_weight)

"""Dispatch layer: every hot op has a Pallas TPU kernel and a pure-jnp path.

``implementation``:
  - "auto":   Pallas on TPU, XLA (jnp reference) elsewhere.
  - "xla":    always the jnp reference path (fast on CPU).
  - "pallas": compiled Pallas kernels (TPU).
  - "pallas_interpret": Pallas kernels in interpret mode (CPU correctness
    validation; slow — used by tests).

The jnp reference path *is* `kernels.ref` — there is exactly one source of
truth for each op's semantics.

Differentiability (DESIGN.md §2.7): the state-evolution entry points —
`apply_phase`, `apply_mixer_bits`, `apply_layer`, `expectation` — carry
analytic `jax.custom_vjp` rules registered here, *above* the dispatch.
The QAOA layer unitaries are their own adjoints up to angle sign (the
phase is a rotation by γ·c; the mixer-group generator is even in β on its
real part and odd on its imaginary part), so every backward pass re-enters
the same dispatch with negated angles — the gradient trace runs whatever
implementation the forward ran, and the ascent loops in core/engine.py and
core/qaoa.py need no `using_implementation("xla")` pin. The residual
angle/cut-value gradients are cheap elementwise reductions left to XLA.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.obs.ledger import get_ledger

_IMPL = "auto"


def set_implementation(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "xla", "pallas", "pallas_interpret"), impl
    _IMPL = impl


@contextlib.contextmanager
def using_implementation(impl: str):
    """Scoped implementation override: restores the previous selection on
    exit (even on error). Dispatch happens at *trace* time, so programs
    cached outside the context keep whatever implementation they were
    traced under — cached-program builders that must honor the override
    include `get_implementation()` in their cache key."""
    global _IMPL
    prev = _IMPL
    set_implementation(impl)
    try:
        yield
    finally:
        _IMPL = prev


def get_implementation() -> str:
    if _IMPL != "auto":
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pallas(interpret_ok: bool = True):
    impl = get_implementation()
    if impl == "pallas":
        return dict(use=True, interpret=False)
    if impl == "pallas_interpret":
        return dict(use=True, interpret=True)
    return dict(use=False, interpret=False)


def _note(op: str, x) -> None:
    """Compile-ledger op event: dispatch happens at *trace* time, so an
    op entered with tracer-typed arguments fires exactly once per
    (re)trace of the enclosing program — retrace storms show up as op
    counts in the ledger (DESIGN.md §8). Concrete-argument (eager) calls
    record nothing."""
    if isinstance(x, jax.core.Tracer):
        get_ledger().note_op(op, get_implementation())


def _f32(x):
    """Canonicalize an angle before it crosses the custom_vjp boundary:
    python floats are weakly typed and would make the cotangent aval
    mismatch the primal's inside `defvjp`."""
    return jnp.asarray(x, jnp.float32)


# ---------------------------------------------------------------------------
# cutvals / cutvals_at — diagonal objective oracle, closed-form VJP over
# (weights, linear). The diagonal is linear in both coefficient arrays, so
# the cotangents are plain masked reductions of the output cotangent:
#   d_w[e]   = Σ_b g[b] · xor_e(b)
#   d_lin[v] = Σ_b g[b] · bit_v(b)
# — cheap elementwise reductions left to XLA, per the PR 9 convention.
# Integer primals (edges, idx) get float0 symbolic-zero cotangents.
# ---------------------------------------------------------------------------

def _int_zero(x):
    """Symbolic-zero cotangent for an integer-dtype primal."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _cutvals_dispatch(n, edges, weights, linear):
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutvals as k

        return k.cutvals(n, edges, weights, linear, interpret=p["interpret"])
    return ref.cutvals(n, edges, weights, linear)


def _cutvals_at_dispatch(idx, edges, weights, linear):
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutvals as k

        return k.cutvals_at(idx, edges, weights, linear, interpret=p["interpret"])
    return ref.cutvals_at(idx, edges, weights, linear)


def _cutvals_grads(n_lin: int, edges, idx, g):
    """Shared (d_weights, d_linear) reductions for the cutvals VJPs."""

    def edge_body(_, e):
        i, j = e
        crossed = (((idx >> i) ^ (idx >> j)) & 1).astype(jnp.float32)
        return None, jnp.sum(g * crossed)

    _, d_w = jax.lax.scan(edge_body, None, (edges[:, 0], edges[:, 1]))

    def bit_body(_, v):
        bit = ((idx >> v) & 1).astype(jnp.float32)
        return None, jnp.sum(g * bit)

    _, d_lin = jax.lax.scan(bit_body, None, jnp.arange(n_lin, dtype=jnp.int32))
    return d_w, d_lin


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cutvals_vjp(n, edges, weights, linear):
    return _cutvals_dispatch(n, edges, weights, linear)


def _cutvals_fwd(n, edges, weights, linear):
    return _cutvals_dispatch(n, edges, weights, linear), edges


def _cutvals_bwd(n, edges, g):
    idx = jnp.arange(2**n, dtype=jnp.int32)
    d_w, d_lin = _cutvals_grads(n, edges, idx, g)
    return _int_zero(edges), d_w, d_lin


_cutvals_vjp.defvjp(_cutvals_fwd, _cutvals_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cutvals_vjp_nolin(n, edges, weights):
    return _cutvals_dispatch(n, edges, weights, None)


def _cutvals_nolin_fwd(n, edges, weights):
    return _cutvals_dispatch(n, edges, weights, None), edges


def _cutvals_nolin_bwd(n, edges, g):
    idx = jnp.arange(2**n, dtype=jnp.int32)
    d_w, _ = _cutvals_grads(0, edges, idx, g)
    return _int_zero(edges), d_w


_cutvals_vjp_nolin.defvjp(_cutvals_nolin_fwd, _cutvals_nolin_bwd)


@jax.custom_vjp
def _cutvals_at_vjp(idx, edges, weights, linear):
    return _cutvals_at_dispatch(idx, edges, weights, linear)


def _cutvals_at_fwd(idx, edges, weights, linear):
    return _cutvals_at_dispatch(idx, edges, weights, linear), (idx, edges, linear)


def _cutvals_at_bwd(res, g):
    idx, edges, linear = res
    d_w, d_lin = _cutvals_grads(linear.shape[0], edges, idx, g)
    return _int_zero(idx), _int_zero(edges), d_w, d_lin


_cutvals_at_vjp.defvjp(_cutvals_at_fwd, _cutvals_at_bwd)


@jax.custom_vjp
def _cutvals_at_vjp_nolin(idx, edges, weights):
    return _cutvals_at_dispatch(idx, edges, weights, None)


def _cutvals_at_nolin_fwd(idx, edges, weights):
    return _cutvals_at_dispatch(idx, edges, weights, None), (idx, edges)


def _cutvals_at_nolin_bwd(res, g):
    idx, edges = res
    d_w, _ = _cutvals_grads(0, edges, idx, g)
    return _int_zero(idx), _int_zero(edges), d_w


_cutvals_at_vjp_nolin.defvjp(_cutvals_at_nolin_fwd, _cutvals_at_nolin_bwd)


def cutvals(n: int, edges, weights, linear=None):
    """Objective value of every basis state. ``linear`` (n,) f32, optional,
    adds per-vertex diagonal terms (QUBO/MIS); ``None`` keeps the Max-Cut
    trace byte-identical to the linear-free op."""
    _note("cutvals", edges)
    if linear is None:
        return _cutvals_vjp_nolin(n, edges, weights)
    return _cutvals_vjp(n, edges, weights, jnp.asarray(linear, jnp.float32))


def cutvals_at(idx, edges, weights, linear=None):
    _note("cutvals_at", idx)
    if linear is None:
        return _cutvals_at_vjp_nolin(idx, edges, weights)
    return _cutvals_at_vjp(idx, edges, weights, jnp.asarray(linear, jnp.float32))


# ---------------------------------------------------------------------------
# apply_phase — diagonal cost rotation, VJP = same rotation at −γ
# ---------------------------------------------------------------------------

def _phase_dispatch(re, im, cutv, gamma):
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.apply_phase(re, im, cutv, gamma, interpret=p["interpret"])
    return ref.apply_phase(re, im, cutv, gamma)


@jax.custom_vjp
def _phase_vjp(re, im, cutv, gamma):
    return _phase_dispatch(re, im, cutv, gamma)


def _phase_fwd(re, im, cutv, gamma):
    out = _phase_dispatch(re, im, cutv, gamma)
    return out, (re, im, cutv, gamma)


def _phase_bwd(res, cot):
    re, im, cutv, gamma = res
    d_ore, d_oim = cot
    # the rotation's transpose is the rotation at −γ: same dispatched kernel
    g_re, g_im = _phase_dispatch(d_ore, d_oim, cutv, -gamma)
    t = im * g_re - re * g_im
    d_gamma = jnp.sum(cutv * t)
    d_cutv = gamma * t
    return g_re, g_im, d_cutv, d_gamma


_phase_vjp.defvjp(_phase_fwd, _phase_bwd)


def apply_phase(re, im, cutv, gamma):
    _note("apply_phase", re)
    return _phase_vjp(re, im, cutv, _f32(gamma))


# ---------------------------------------------------------------------------
# apply_mixer_bits — RX group, VJP = same group at −β
# ---------------------------------------------------------------------------

def _mixer_bits_dispatch(n, lo_bit, nbits, re, im, beta):
    p = _pallas()
    if p["use"]:
        from repro.kernels import mixer as k

        return k.apply_mixer_bits(
            re, im, n, lo_bit, nbits, beta, interpret=p["interpret"]
        )
    return ref.apply_mixer_bits(re, im, n, lo_bit, nbits, beta)


def _neighbor_sum_bits(v, lo_bit: int, nbits: int):
    """Σ over the group's qubits of v with that qubit flipped — the
    ∂β generator contraction (each RX factor differentiates into −i·X on
    its qubit). The reshape puts bit q on the middle axis; reversing it is
    the flip. Metadata-only reshapes, one add per qubit."""
    out = jnp.zeros_like(v)
    for q in range(lo_bit, lo_bit + nbits):
        out = out + v.reshape(-1, 2, 2**q)[:, ::-1, :].reshape(v.shape)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _mixer_bits_vjp(n, lo_bit, nbits, re, im, beta):
    return _mixer_bits_dispatch(n, lo_bit, nbits, re, im, beta)


def _mixer_bits_fwd(n, lo_bit, nbits, re, im, beta):
    ore, oim = _mixer_bits_dispatch(n, lo_bit, nbits, re, im, beta)
    return (ore, oim), (ore, oim, beta)


def _mixer_bits_bwd(n, lo_bit, nbits, res, cot):
    ore, oim, beta = res
    d_ore, d_oim = cot
    # the group unitary's transpose is the group at −β: same kernel
    g_re, g_im = _mixer_bits_dispatch(n, lo_bit, nbits, d_ore, d_oim, -beta)
    # ∂out/∂β = neighbor-sum of the *output* planes rotated by i, so
    # d_beta = Σ d_ore·N(oim) − d_oim·N(ore)
    fr = _neighbor_sum_bits(ore, lo_bit, nbits)
    fi = _neighbor_sum_bits(oim, lo_bit, nbits)
    d_beta = jnp.sum(d_ore * fi) - jnp.sum(d_oim * fr)
    return g_re, g_im, d_beta


_mixer_bits_vjp.defvjp(_mixer_bits_fwd, _mixer_bits_bwd)


def apply_mixer_bits(re, im, n: int, lo_bit: int, nbits: int, beta):
    _note("apply_mixer_bits", re)
    return _mixer_bits_vjp(n, lo_bit, nbits, re, im, _f32(beta))


def apply_mixer(re, im, n: int, beta, group: int = 7):
    """Full mixer as a chain of differentiable `apply_mixer_bits` groups —
    the identical kernels fire, and the chain rule over the groups gives
    the full-mixer gradient for free."""
    _note("apply_mixer", re)
    for g0 in range(0, n, group):
        re, im = apply_mixer_bits(re, im, n, g0, min(group, n - g0), beta)
    return re, im


# ---------------------------------------------------------------------------
# apply_layer — fused phase + full mixer, VJP = reversed layer at (−γ, −β)
# ---------------------------------------------------------------------------

def _layer_dispatch(n, group, re, im, cutv, gamma, beta):
    p = _pallas()
    if p["use"]:
        from repro.kernels import fused_layer as fl
        from repro.kernels import mixer as mk

        k = min(group, n)
        dk = 2**k
        re_m, im_m = fl.fused_phase_mixer_group(
            re.reshape(-1, dk),
            im.reshape(-1, dk),
            cutv.reshape(-1, dk),
            gamma,
            beta,
            k,
            interpret=p["interpret"],
        )
        re, im = re_m.reshape(-1), im_m.reshape(-1)
        for g0 in range(k, n, group):
            re, im = mk.apply_mixer_bits(
                re, im, n, g0, min(group, n - g0), beta,
                interpret=p["interpret"],
            )
        return re, im
    re, im = ref.apply_phase(re, im, cutv, gamma)
    return ref.apply_mixer(re, im, n, beta, group=group)


def _layer_adjoint_dispatch(n, group, re, im, cutv, gamma, beta):
    """Transpose of `_layer_dispatch` applied to a cotangent: the trailing
    mixer groups at −β in reverse order, then the fused kernel in
    ``reverse`` mode (mixer group 0 before the phase) at (−γ, −β). Same
    kernel shapes as the forward — the bwd trace compiles the same ops."""
    p = _pallas()
    if p["use"]:
        from repro.kernels import fused_layer as fl
        from repro.kernels import mixer as mk

        k = min(group, n)
        dk = 2**k
        for g0 in reversed(range(k, n, group)):
            re, im = mk.apply_mixer_bits(
                re, im, n, g0, min(group, n - g0), -beta,
                interpret=p["interpret"],
            )
        re_m, im_m = fl.fused_phase_mixer_group(
            re.reshape(-1, dk),
            im.reshape(-1, dk),
            cutv.reshape(-1, dk),
            -gamma,
            -beta,
            k,
            reverse=True,
            interpret=p["interpret"],
        )
        return re_m.reshape(-1), im_m.reshape(-1)
    re, im = ref.apply_mixer(re, im, n, -beta, group=group)
    return ref.apply_phase(re, im, cutv, -gamma)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _layer_vjp(n, group, re, im, cutv, gamma, beta):
    return _layer_dispatch(n, group, re, im, cutv, gamma, beta)


def _layer_fwd(n, group, re, im, cutv, gamma, beta):
    ore, oim = _layer_dispatch(n, group, re, im, cutv, gamma, beta)
    return (ore, oim), (re, im, cutv, gamma, beta, ore, oim)


def _layer_bwd(n, group, res, cot):
    re, im, cutv, gamma, beta, ore, oim = res
    d_ore, d_oim = cot
    # ∂β: the full n-qubit mixer acts last, so its generator contraction
    # (neighbor-sum over *all* qubits) runs on the layer output
    fr = _neighbor_sum_bits(ore, 0, n)
    fi = _neighbor_sum_bits(oim, 0, n)
    d_beta = jnp.sum(d_ore * fi) - jnp.sum(d_oim * fr)
    # state cotangent through the whole layer: reversed layer at (−γ, −β)
    g_re, g_im = _layer_adjoint_dispatch(n, group, d_ore, d_oim, cutv,
                                         gamma, beta)
    # ∂γ and ∂cutv fall out of the phase rule with (re, im) the layer
    # *input* (the phase's input) and g the fully back-propagated cotangent
    t = im * g_re - re * g_im
    d_gamma = jnp.sum(cutv * t)
    d_cutv = gamma * t
    return g_re, g_im, d_cutv, d_gamma, d_beta


_layer_vjp.defvjp(_layer_fwd, _layer_bwd)


def apply_layer(re, im, cutv, gamma, beta, n: int, group: int = 7):
    """One full intra-shard QAOA layer: cost phase, then the n-qubit mixer.

    This is the op the statevector engine (core/engine.py, DESIGN.md §2.6)
    runs per layer on every path — flat or per-shard. On the Pallas path
    the phase and the *first* mixer group go through the fused
    `kernels/fused_layer.py` kernel (one VMEM round-trip, §Perf C3) and
    the remaining groups through the mixer kernel; the XLA path is the
    exact phase-then-mixer reference decomposition. Differentiable under
    every implementation via the analytic layer VJP (module docstring).
    """
    _note("apply_layer", re)
    return _layer_vjp(n, group, re, im, cutv, _f32(gamma), _f32(beta))


# ---------------------------------------------------------------------------
# expectation — Σ|ψ|²·c, closed-form VJP
# ---------------------------------------------------------------------------

def _expectation_dispatch(re, im, cutv):
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.expectation(re, im, cutv, interpret=p["interpret"])
    return ref.expectation(re, im, cutv)


@jax.custom_vjp
def _expectation_vjp(re, im, cutv):
    return _expectation_dispatch(re, im, cutv)


def _expectation_fwd(re, im, cutv):
    return _expectation_dispatch(re, im, cutv), (re, im, cutv)


def _expectation_bwd(res, g):
    re, im, cutv = res
    return 2.0 * g * re * cutv, 2.0 * g * im * cutv, g * (re * re + im * im)


_expectation_vjp.defvjp(_expectation_fwd, _expectation_bwd)


def expectation(re, im, cutv):
    _note("expectation", re)
    return _expectation_vjp(re, im, cutv)


def cut_batch_dense(spins, adjacency, total_weight):
    _note("cut_batch_dense", spins)
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutbatch as k

        return k.cut_batch_dense(spins, adjacency, total_weight, interpret=p["interpret"])
    return ref.cut_batch_dense(spins, adjacency, total_weight)

"""Dispatch layer: every hot op has a Pallas TPU kernel and a pure-jnp path.

``implementation``:
  - "auto":   Pallas on TPU, XLA (jnp reference) elsewhere.
  - "xla":    always the jnp reference path (fast on CPU).
  - "pallas": compiled Pallas kernels (TPU).
  - "pallas_interpret": Pallas kernels in interpret mode (CPU correctness
    validation; slow — used by tests).

The jnp reference path *is* `kernels.ref` — there is exactly one source of
truth for each op's semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref

_IMPL = "auto"


def set_implementation(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "xla", "pallas", "pallas_interpret"), impl
    _IMPL = impl


def get_implementation() -> str:
    if _IMPL != "auto":
        return _IMPL
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pallas(interpret_ok: bool = True):
    impl = get_implementation()
    if impl == "pallas":
        return dict(use=True, interpret=False)
    if impl == "pallas_interpret":
        return dict(use=True, interpret=True)
    return dict(use=False, interpret=False)


def cutvals(n: int, edges, weights):
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutvals as k

        return k.cutvals(n, edges, weights, interpret=p["interpret"])
    return ref.cutvals(n, edges, weights)


def apply_phase(re, im, cutv, gamma):
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.apply_phase(re, im, cutv, gamma, interpret=p["interpret"])
    return ref.apply_phase(re, im, cutv, gamma)


def apply_mixer(re, im, n: int, beta, group: int = 7):
    p = _pallas()
    if p["use"]:
        from repro.kernels import mixer as k

        return k.apply_mixer(re, im, n, beta, group=group, interpret=p["interpret"])
    return ref.apply_mixer(re, im, n, beta, group=group)


def expectation(re, im, cutv):
    p = _pallas()
    if p["use"]:
        from repro.kernels import phase as k

        return k.expectation(re, im, cutv, interpret=p["interpret"])
    return ref.expectation(re, im, cutv)


def cut_batch_dense(spins, adjacency, total_weight):
    p = _pallas()
    if p["use"]:
        from repro.kernels import cutbatch as k

        return k.cut_batch_dense(spins, adjacency, total_weight, interpret=p["interpret"])
    return ref.cut_batch_dense(spins, adjacency, total_weight)

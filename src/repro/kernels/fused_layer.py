"""Pallas TPU kernel: fused QAOA cost-phase + first mixer group.

One VMEM round-trip applies the whole start of a QAOA layer:

    (re, im) --e^{-iγ·c}--> phase --RX(β)^{⊗k} (right-multiply)--> out

The unfused XLA path reads/writes the statevector twice (phase pass, then
mixer pass); fusing halves the HBM traffic of that段 — exactly §Perf C3.
The U matrix is generated in-registers from β (popcount(a⊕b)), as in
mixer.py; the cut-value block rides along the same row tiles.

Layout contract: state viewed as (R, 2^k) where the trailing axis is the
first mixer group (qubits 0..k-1) — the natural layout-A view, so no extra
relayout versus the unfused path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import popcount

ROW_TILE = 512


def _kernel(k: int, g_ref, b_ref, c_ref, re_ref, im_ref, ore_ref, oim_ref):
    dk = 2**k
    gamma = g_ref[0, 0]
    beta = b_ref[0, 0]

    # ---- phase: psi *= e^{-i γ c} ----------------------------------------
    cv = c_ref[...]
    cs = jnp.cos(gamma * cv)
    sn = jnp.sin(gamma * cv)
    re = re_ref[...]
    im = im_ref[...]
    pre = re * cs + im * sn
    pim = im * cs - re * sn

    # ---- fused mixer group: right-multiply by symmetric C + iD ----------
    a = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 0)
    b = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 1)
    d = popcount(a ^ b).astype(jnp.float32)
    kk = jnp.float32(k)
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    mag = (
        jnp.power(jnp.abs(cb), kk - d)
        * jnp.power(jnp.abs(sb), d)
        * jnp.where(cb < 0, (-1.0) ** (kk - d), 1.0)
        * jnp.where(sb < 0, (-1.0) ** d, 1.0)
    )
    m4 = popcount(a ^ b) % 4
    cmat = mag * jnp.where(m4 == 0, 1.0, jnp.where(m4 == 2, -1.0, 0.0))
    dmat = mag * jnp.where(m4 == 1, -1.0, jnp.where(m4 == 3, 1.0, 0.0))

    f32 = jnp.float32
    ore_ref[...] = jnp.dot(pre, cmat, preferred_element_type=f32) - jnp.dot(
        pim, dmat, preferred_element_type=f32
    )
    oim_ref[...] = jnp.dot(pim, cmat, preferred_element_type=f32) + jnp.dot(
        pre, dmat, preferred_element_type=f32
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def fused_phase_mixer_group(re_mat, im_mat, cutv_mat, gamma, beta, k: int,
                            *, interpret: bool = False):
    """(R, 2^k) state planes + matching cut values → one fused pass."""
    r, dk = re_mat.shape
    assert dk == 2**k and cutv_mat.shape == (r, dk)
    tile = min(ROW_TILE, r)
    assert r % tile == 0, (r, tile)
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    b = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    spec = pl.BlockSpec((tile, dk), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    ore, oim = pl.pallas_call(
        functools.partial(_kernel, k),
        grid=(r // tile,),
        in_specs=[scal, scal, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
        ],
        interpret=interpret,
    )(g, b, cutv_mat, re_mat, im_mat)
    return ore, oim

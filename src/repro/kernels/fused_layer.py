"""Pallas TPU kernel: fused QAOA cost-phase + first mixer group.

One VMEM round-trip applies the whole start of a QAOA layer:

    (re, im) --e^{-iγ·c}--> phase --RX(β)^{⊗k} (right-multiply)--> out

The unfused XLA path reads/writes the statevector twice (phase pass, then
mixer pass); fusing halves the HBM traffic of that段 — exactly §Perf C3.
The U matrix is generated in-registers from β (`mixer.rx_group_mats`); the
cut-value block rides along the same row tiles.

Layout contract: state viewed as (R, 2^k) where the trailing axis is the
first mixer group (qubits 0..k-1) — the natural layout-A view, so no extra
relayout versus the unfused path.

``reverse=True`` swaps the in-kernel order to mixer-group *then* phase:
called with (−γ, −β) that is exactly the adjoint of the forward kernel,
which is how the `kernels.ops` layer custom-vjp backward runs this same
kernel for the gradient trace (DESIGN.md §2.7).

Oracle contract: ``c`` is *any* diagonal objective, not specifically a cut
value — per-vertex linear terms (QUBO/MIS, DESIGN.md §9) are folded into
``c`` upstream by ``cutvals(..., linear=...)`` via virtual-bit edge rows,
so this kernel serves all three problem families without modification.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning
from repro.kernels.mixer import rx_group_mats

ROW_TILE = 512


def _kernel(k: int, reverse: bool, g_ref, b_ref, c_ref, re_ref, im_ref,
            ore_ref, oim_ref):
    gamma = g_ref[0, 0]
    cv = c_ref[...]
    cs = jnp.cos(gamma * cv)
    sn = jnp.sin(gamma * cv)
    cmat, dmat = rx_group_mats(b_ref[0, 0], k)
    f32 = jnp.float32

    re = re_ref[...]
    im = im_ref[...]

    def phase(pr, pi):
        return pr * cs + pi * sn, pi * cs - pr * sn

    def mixer(pr, pi):
        return (
            jnp.dot(pr, cmat, preferred_element_type=f32)
            - jnp.dot(pi, dmat, preferred_element_type=f32),
            jnp.dot(pi, cmat, preferred_element_type=f32)
            + jnp.dot(pr, dmat, preferred_element_type=f32),
        )

    if reverse:
        re, im = mixer(re, im)
        re, im = phase(re, im)
    else:
        re, im = phase(re, im)
        re, im = mixer(re, im)
    ore_ref[...] = re
    oim_ref[...] = im


@functools.partial(
    jax.jit, static_argnames=("k", "reverse", "tile", "interpret"))
def _fused_phase_mixer_group(re_mat, im_mat, cutv_mat, gamma, beta, k: int,
                             *, reverse: bool, tile: int, interpret: bool):
    r, dk = re_mat.shape
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    b = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    spec = pl.BlockSpec((tile, dk), lambda i: (i, 0))
    scal = pl.BlockSpec((1, 1), lambda i: (0, 0))
    ore, oim = pl.pallas_call(
        functools.partial(_kernel, k, reverse),
        grid=(r // tile,),
        in_specs=[scal, scal, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
        ],
        interpret=interpret,
    )(g, b, cutv_mat, re_mat, im_mat)
    return ore, oim


def fused_phase_mixer_group(re_mat, im_mat, cutv_mat, gamma, beta, k: int,
                            *, reverse: bool = False, interpret: bool = False):
    """(R, 2^k) state planes + matching cut values → one fused pass."""
    r, dk = re_mat.shape
    assert dk == 2**k and cutv_mat.shape == (r, dk)
    tile = tuning.clamp_tile(
        r, tuning.param("fused_layer", r, "row_tile", ROW_TILE))
    return _fused_phase_mixer_group(
        re_mat, im_mat, cutv_mat, gamma, beta, k,
        reverse=reverse, tile=tile, interpret=interpret,
    )

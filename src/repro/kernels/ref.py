"""Pure-jnp reference oracles for every Pallas kernel.

These are the semantic ground truth: each kernel in this package is tested
(`tests/test_kernels.py`) with ``assert_allclose`` against the function of
the same name here, across shape/dtype sweeps.

Complex statevectors are carried as (re, im) float pairs throughout —
TPU Pallas has no complex register type, and splitting the planes lets the
mixer run as real matmuls on the MXU.

Bit convention: basis index ``b`` assigns vertex/qubit ``q`` the bit
``(b >> q) & 1`` (low bits = low vertex ids).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# Linear (per-vertex) terms fold into the XOR edge form through a virtual
# bit: h_v * bit_v(b) == h_v * (bit_v(b) XOR bit_30(b)) because bit 30 of
# any basis index is 0 (indices are int32 and n <= 29 everywhere). One
# appended row (v, 30, h_v) per vertex therefore makes the *unchanged* XOR
# kernels score quadratic + linear in a single pass.
VIRTUAL_BIT = 30


def append_linear_rows(edges: jnp.ndarray, weights: jnp.ndarray, linear: jnp.ndarray):
    """Append one (v, VIRTUAL_BIT, h_v) row per vertex to the edge arrays."""
    n = linear.shape[0]
    v = jnp.arange(n, dtype=jnp.int32)
    extra = jnp.stack([v, jnp.full((n,), VIRTUAL_BIT, dtype=jnp.int32)], axis=1)
    return (
        jnp.concatenate([edges, extra], axis=0),
        jnp.concatenate([weights, linear.astype(weights.dtype)], axis=0),
    )


def popcount(x: jnp.ndarray) -> jnp.ndarray:
    """Population count for non-negative int32 arrays (SWAR, no wraparound)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    x = x + (x >> 8)
    x = x + (x >> 16)
    return x & 0x3F


def cutvals(
    n: int, edges: jnp.ndarray, weights: jnp.ndarray, linear: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Objective value of every basis state: (2^n,) float32.

    ``edges`` (E, 2) int32, ``weights`` (E,) float32; padding rows must be
    (0, 0) with weight 0. ``linear`` (n,) float32, when given, adds
    ``sum_v h_v * bit_v(b)`` via virtual-bit rows.
    """
    if linear is not None:
        edges, weights = append_linear_rows(edges, weights, linear)
    idx = jnp.arange(2**n, dtype=jnp.int32)

    def body(acc, ew):
        i, j, w = ew
        crossed = ((idx >> i) ^ (idx >> j)) & 1
        return acc + w * crossed.astype(jnp.float32), None

    init = jnp.zeros((2**n,), dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, init, (edges[:, 0], edges[:, 1], weights))
    return acc


def cutvals_at(
    idx: jnp.ndarray,
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    linear: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Objective values at arbitrary basis indices (for sharded statevectors,
    where each device owns a slice/permutation of the amplitude space)."""
    if linear is not None:
        edges, weights = append_linear_rows(edges, weights, linear)

    def body(acc, ew):
        i, j, w = ew
        crossed = ((idx >> i) ^ (idx >> j)) & 1
        return acc + w * crossed.astype(jnp.float32), None

    init = jnp.zeros(idx.shape, dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, init, (edges[:, 0], edges[:, 1], weights))
    return acc


def apply_phase(re, im, cutv, gamma):
    """Diagonal cost layer: psi <- exp(-i * gamma * c) * psi, planewise."""
    c = jnp.cos(gamma * cutv)
    s = jnp.sin(gamma * cutv)
    return re * c + im * s, im * c - re * s


def rx_kron_parts(beta, k: int):
    """(C, D) with C + iD = RX(2*beta)^{⊗k} = (e^{-i beta X})^{⊗k}.

    Entry [a, b] = cos(beta)^(k-d) * (-i sin(beta))^d with d = popcount(a^b).
    """
    a = jnp.arange(2**k, dtype=jnp.int32)
    d = popcount(a[:, None] ^ a[None, :])
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    # integer powers via cumprod tables (negative bases stay exact)
    cpow = jnp.cumprod(jnp.concatenate([jnp.ones((1,), cb.dtype), jnp.full((k,), cb)]))
    spow = jnp.cumprod(jnp.concatenate([jnp.ones((1,), sb.dtype), jnp.full((k,), sb)]))
    mag = cpow[k - d] * spow[d]
    rfac = jnp.asarray([1.0, 0.0, -1.0, 0.0])[d % 4]
    ifac = jnp.asarray([0.0, -1.0, 0.0, 1.0])[d % 4]
    return mag * rfac, mag * ifac


def apply_mixer_bits(re, im, n: int, lo_bit: int, nbits: int, beta):
    """RX(2β)^{⊗nbits} on qubits [lo_bit, lo_bit+nbits) of a flat 2^n state.

    One grouped unitary: a (2^nbits, 2^nbits) real-pair contraction over a
    reshaped view that exposes the target qubits on the contracted axis.
    The building block of both the full mixer below and the sharded
    engine's post-all_to_all global-qubit mix (DESIGN.md §2.6).
    """
    C, D = rx_kron_parts(beta, nbits)
    shape = (2 ** (n - lo_bit - nbits), 2**nbits, 2**lo_bit)
    re3, im3 = re.reshape(shape), im.reshape(shape)
    re_new = jnp.einsum("ab,xby->xay", C, re3) - jnp.einsum("ab,xby->xay", D, im3)
    im_new = jnp.einsum("ab,xby->xay", C, im3) + jnp.einsum("ab,xby->xay", D, re3)
    return re_new.reshape(-1), im_new.reshape(-1)


def apply_mixer(re, im, n: int, beta, group: int = 7):
    """Full transverse-field mixer U_M(beta) = prod_q e^{-i beta X_q}.

    Applied as ceil(n/group) grouped unitaries via `apply_mixer_bits`.
    """
    for g0 in range(0, n, group):
        re, im = apply_mixer_bits(re, im, n, g0, min(group, n - g0), beta)
    return re, im


def expectation(re, im, cutv):
    """<psi| diag(c) |psi> = sum_b |psi_b|^2 c_b."""
    return jnp.sum((re * re + im * im) * cutv)


def cut_batch_dense(spins: jnp.ndarray, adjacency: jnp.ndarray, total_weight):
    """Cut values for ±1 spin assignments via dense matmul (MXU form).

    spins: (B, V) float32 in {-1, +1}; adjacency: (V, V) float32 symmetric.
    cut = (W_total - 0.5 * s^T A s) / 2   [0.5 because A double-counts edges]
    """
    quad = jnp.einsum("bi,ij,bj->b", spins, adjacency, spins)
    return (total_weight - 0.5 * quad) / 2.0


# ---------------------------------------------------------------------------
# Dense-unitary oracle for the whole QAOA layer (test-only, n <= 8):
# builds the exact 2^n x 2^n unitary and applies it to a complex vector.
# ---------------------------------------------------------------------------
def dense_qaoa_layer(psi: jnp.ndarray, cutv: jnp.ndarray, gamma, beta, n: int):
    psi = jnp.exp(-1j * gamma * cutv.astype(jnp.complex64)) * psi
    c, s = np.cos(float(beta)), np.sin(float(beta))
    rx = np.array([[c, -1j * s], [-1j * s, c]], dtype=np.complex64)
    u = np.array([[1.0]], dtype=np.complex64)
    for _ in range(n):
        u = np.kron(rx, u)  # qubit q is bit q: later kron factors are higher bits
    return jnp.asarray(u) @ psi

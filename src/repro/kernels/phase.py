"""Pallas TPU kernels for the diagonal cost layer.

`apply_phase`: psi ← e^{-iγc}·psi on (re, im) planes — pure VPU elementwise,
tiled so each block streams HBM→VMEM once (memory-bound by design; the win
over XLA is fusing the sin/cos with both plane updates in one pass).

`expectation`: Σ|psi|²·c — a tiled reduction using the sequential-grid
accumulation idiom (out block revisited by every grid step).

Block sizes resolve through `kernels.tuning` at trace time (autotuned per
shape bucket when tuning is enabled; the hard defaults otherwise) and are
threaded into the jitted launchers as static arguments, so a tuning-state
change can never stale-hit a kernel-level jit cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

TILE = 8 * 1024  # elements per block (64 sublanes × 128 lanes)


def _phase_kernel(g_ref, re_ref, im_ref, c_ref, ore_ref, oim_ref):
    g = g_ref[0, 0]
    c = jnp.cos(g * c_ref[...])
    s = jnp.sin(g * c_ref[...])
    re = re_ref[...]
    im = im_ref[...]
    ore_ref[...] = re * c + im * s
    oim_ref[...] = im * c - re * s


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _apply_phase(re, im, cutv, gamma, *, tile: int, interpret: bool):
    dim = re.shape[0]
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (dim // tile,)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    ore, oim = pl.pallas_call(
        _phase_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            spec,
            spec,
            spec,
        ],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((dim,), jnp.float32),
            jax.ShapeDtypeStruct((dim,), jnp.float32),
        ],
        interpret=interpret,
    )(g, re, im, cutv)
    return ore, oim


def apply_phase(re, im, cutv, gamma, *, interpret: bool = False):
    dim = re.shape[0]
    tile = tuning.clamp_tile(dim, tuning.param("apply_phase", dim, "tile", TILE))
    return _apply_phase(re, im, cutv, gamma, tile=tile, interpret=interpret)


def _exp_kernel(re_ref, im_ref, c_ref, out_ref):
    i = pl.program_id(0)
    re = re_ref[...]
    im = im_ref[...]
    p = (re * re + im * im) * c_ref[...]
    partial = jnp.sum(p)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = partial

    @pl.when(i != 0)
    def _acc():
        out_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _expectation(re, im, cutv, *, tile: int, interpret: bool):
    dim = re.shape[0]
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    out = pl.pallas_call(
        _exp_kernel,
        grid=(dim // tile,),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(re, im, cutv)
    return out[0, 0]


def expectation(re, im, cutv, *, interpret: bool = False):
    dim = re.shape[0]
    tile = tuning.clamp_tile(dim, tuning.param("expectation", dim, "tile", TILE))
    return _expectation(re, im, cutv, tile=tile, interpret=interpret)

"""Pallas TPU kernel: cut values of all 2^n basis states.

This feeds the QAOA diagonal cost layer. The computation is recast as a
matmul so it runs on the MXU instead of a per-edge scalar sweep:

    bits[b, e] = ((b >> i_e) ^ (b >> j_e)) & 1        (VPU, int ops)
    cutv[b]    = bits[b, :] @ w                        (MXU)

Grid: (basis tiles × edge chunks); the edge chunk axis accumulates into the
output block (TPU grids iterate sequentially, so revisiting the same output
block across the inner axis is the canonical accumulation pattern).

VMEM budget per step: TILE_B×EDGE_CHUNK int32 bits plane (1024×256×4 = 1 MiB)
plus the (TILE_B, 1) accumulator — comfortably under a v5e core's ~16 MiB.

Pad/tile arithmetic lives in `kernels.tuning` (`pad_chunks`, `pad_and_tile`)
— one seam shared with cutbatch.py — and the block constants resolve
through the same module's per-shape-bucket tuning table.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as ref_mod
from repro.kernels import tuning

TILE_B = 1024  # basis states per block (8 sublanes × 128 lanes)
EDGE_CHUNK = 256  # edges per accumulation step


def _pad_edges(edges, weights, chunk: int):
    """Edge arrays padded to a chunk multiple; padding rows (0,0,w=0)
    contribute zero. Shared by `cutvals` and `cutvals_at`."""
    e = edges.shape[0]
    e_pad = tuning.pad_chunks(e, chunk)
    ei = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 0])
    ej = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 1])
    w = jnp.zeros((e_pad,), jnp.float32).at[:e].set(weights)
    return ei, ej, w, e_pad


def _kernel(tile: int, ei_ref, ej_ref, w_ref, out_ref):
    kb = pl.program_id(0)
    ke = pl.program_id(1)

    # basis indices covered by this block: kb*tile + [0, tile)
    row = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    idx = kb * tile + row  # (tile, 1)

    ei = ei_ref[...].reshape(1, -1)  # (1, E)
    ej = ej_ref[...].reshape(1, -1)
    w = w_ref[...].reshape(-1, 1)  # (E, 1)

    crossed = ((idx >> ei) ^ (idx >> ej)) & 1  # (tile, E)
    partial = jnp.dot(
        crossed.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )  # (tile, 1)

    @pl.when(ke == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ke != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("tile", "chunk", "interpret"))
def _cutvals(n: int, edges, weights, *, tile: int, chunk: int, interpret: bool):
    dim = 2**n
    ei, ej, w, e_pad = _pad_edges(edges, weights, chunk)
    out = pl.pallas_call(
        functools.partial(_kernel, tile),
        grid=(dim // tile, e_pad // chunk),
        in_specs=[
            pl.BlockSpec((chunk,), lambda kb, ke: (ke,)),
            pl.BlockSpec((chunk,), lambda kb, ke: (ke,)),
            pl.BlockSpec((chunk,), lambda kb, ke: (ke,)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        out_shape=jax.ShapeDtypeStruct((dim, 1), jnp.float32),
        interpret=interpret,
    )(ei, ej, w)
    return out.reshape(dim)


def cutvals(n: int, edges, weights, linear=None, *, interpret: bool = False):
    """(2^n,) float32 objective values. edges (E,2) int32, weights (E,) f32.

    ``linear`` (n,) f32, when given, folds per-vertex terms in as virtual-bit
    rows (`ref.append_linear_rows`) — the kernel body is untouched.
    """
    if linear is not None:
        edges, weights = ref_mod.append_linear_rows(edges, weights, linear)
    dim = 2**n
    tile = tuning.clamp_tile(dim, tuning.param("cutvals", dim, "tile_b", TILE_B))
    chunk = tuning.param("cutvals", dim, "edge_chunk", EDGE_CHUNK)
    return _cutvals(n, edges, weights, tile=tile, chunk=chunk,
                    interpret=interpret)


def _at_kernel(ei_ref, ej_ref, w_ref, idx_ref, out_ref):
    """Like `_kernel` but the basis indices come from an input block
    instead of the grid position — the sharded-statevector case, where
    each device owns an arbitrary slice/permutation of the amplitude
    space (DESIGN.md §2.6)."""
    ke = pl.program_id(1)
    idx = idx_ref[...].reshape(-1, 1)  # (tile, 1)
    ei = ei_ref[...].reshape(1, -1)
    ej = ej_ref[...].reshape(1, -1)
    w = w_ref[...].reshape(-1, 1)
    crossed = ((idx >> ei) ^ (idx >> ej)) & 1
    partial = jnp.dot(
        crossed.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(ke == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ke != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("tile", "chunk", "interpret"))
def _cutvals_at(idx, edges, weights, *, tile: int, chunk: int, interpret: bool):
    m = idx.shape[0]
    ei, ej, w, e_pad = _pad_edges(edges, weights, chunk)
    m_pad = tuning.round_up(m, tile)
    idx_p = jnp.zeros((m_pad, 1), jnp.int32).at[:m, 0].set(idx)

    chunk_spec = pl.BlockSpec((chunk,), lambda kb, ke: (ke,))
    out = pl.pallas_call(
        _at_kernel,
        grid=(m_pad // tile, e_pad // chunk),
        in_specs=[
            chunk_spec,
            chunk_spec,
            chunk_spec,
            pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(ei, ej, w, idx_p)
    return out.reshape(m_pad)[:m]


def cutvals_at(idx, edges, weights, linear=None, *, interpret: bool = False):
    """Objective values at arbitrary basis indices: (M,) f32 for (M,) int32 idx."""
    if linear is not None:
        edges, weights = ref_mod.append_linear_rows(edges, weights, linear)
    m = idx.shape[0]
    _, tile = tuning.pad_and_tile(
        m, tuning.param("cutvals_at", m, "tile_b", TILE_B))
    chunk = tuning.param("cutvals_at", m, "edge_chunk", EDGE_CHUNK)
    return _cutvals_at(idx, edges, weights, tile=tile, chunk=chunk,
                       interpret=interpret)

"""Pallas TPU kernel: cut values of all 2^n basis states.

This feeds the QAOA diagonal cost layer. The computation is recast as a
matmul so it runs on the MXU instead of a per-edge scalar sweep:

    bits[b, e] = ((b >> i_e) ^ (b >> j_e)) & 1        (VPU, int ops)
    cutv[b]    = bits[b, :] @ w                        (MXU)

Grid: (basis tiles × edge chunks); the edge chunk axis accumulates into the
output block (TPU grids iterate sequentially, so revisiting the same output
block across the inner axis is the canonical accumulation pattern).

VMEM budget per step: TILE_B×EDGE_CHUNK int32 bits plane (1024×256×4 = 1 MiB)
plus the (TILE_B, 1) accumulator — comfortably under a v5e core's ~16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 1024  # basis states per block (8 sublanes × 128 lanes)
EDGE_CHUNK = 256  # edges per accumulation step


def _kernel(ei_ref, ej_ref, w_ref, out_ref):
    kb = pl.program_id(0)
    ke = pl.program_id(1)

    # basis indices covered by this block: kb*TILE_B + [0, TILE_B)
    row = jax.lax.broadcasted_iota(jnp.int32, (TILE_B, 1), 0)
    idx = kb * TILE_B + row  # (TILE_B, 1)

    ei = ei_ref[...].reshape(1, EDGE_CHUNK)  # (1, E)
    ej = ej_ref[...].reshape(1, EDGE_CHUNK)
    w = w_ref[...].reshape(EDGE_CHUNK, 1)  # (E, 1)

    crossed = ((idx >> ei) ^ (idx >> ej)) & 1  # (TILE_B, E)
    partial = jnp.dot(
        crossed.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )  # (TILE_B, 1)

    @pl.when(ke == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ke != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("interpret",))
def cutvals(n: int, edges, weights, *, interpret: bool = False):
    """(2^n,) float32 cut values. edges (E,2) int32, weights (E,) f32."""
    dim = 2**n
    e = edges.shape[0]
    # pad edges to a chunk multiple (padding rows (0,0,w=0) contribute zero)
    e_pad = max(EDGE_CHUNK, ((e + EDGE_CHUNK - 1) // EDGE_CHUNK) * EDGE_CHUNK)
    ei = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 0])
    ej = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 1])
    w = jnp.zeros((e_pad,), jnp.float32).at[:e].set(weights)

    if dim < TILE_B:
        # small instances: single unblocked call
        tile = dim
        grid = (1, e_pad // EDGE_CHUNK)
    else:
        tile = TILE_B
        grid = (dim // tile, e_pad // EDGE_CHUNK)

    kernel = _kernel if tile == TILE_B else functools.partial(_small_kernel, tile)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EDGE_CHUNK,), lambda kb, ke: (ke,)),
            pl.BlockSpec((EDGE_CHUNK,), lambda kb, ke: (ke,)),
            pl.BlockSpec((EDGE_CHUNK,), lambda kb, ke: (ke,)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        out_shape=jax.ShapeDtypeStruct((dim, 1), jnp.float32),
        interpret=interpret,
    )(ei, ej, w)
    return out.reshape(dim)


def _at_kernel(ei_ref, ej_ref, w_ref, idx_ref, out_ref):
    """Like `_kernel`/`_small_kernel` but the basis indices come from an
    input block instead of the grid position — the sharded-statevector
    case, where each device owns an arbitrary slice/permutation of the
    amplitude space (DESIGN.md §2.6)."""
    ke = pl.program_id(1)
    idx = idx_ref[...].reshape(-1, 1)  # (tile, 1)
    ei = ei_ref[...].reshape(1, -1)
    ej = ej_ref[...].reshape(1, -1)
    w = w_ref[...].reshape(-1, 1)
    crossed = ((idx >> ei) ^ (idx >> ej)) & 1
    partial = jnp.dot(
        crossed.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(ke == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ke != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def cutvals_at(idx, edges, weights, *, interpret: bool = False):
    """Cut values at arbitrary basis indices: (M,) f32 for (M,) int32 idx."""
    m = idx.shape[0]
    e = edges.shape[0]
    e_pad = max(EDGE_CHUNK, ((e + EDGE_CHUNK - 1) // EDGE_CHUNK) * EDGE_CHUNK)
    ei = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 0])
    ej = jnp.zeros((e_pad,), jnp.int32).at[:e].set(edges[:, 1])
    w = jnp.zeros((e_pad,), jnp.float32).at[:e].set(weights)

    tile = min(TILE_B, m)
    m_pad = ((m + tile - 1) // tile) * tile
    idx_p = jnp.zeros((m_pad, 1), jnp.int32).at[:m, 0].set(idx)

    chunk_spec = pl.BlockSpec((EDGE_CHUNK,), lambda kb, ke: (ke,))
    out = pl.pallas_call(
        _at_kernel,
        grid=(m_pad // tile, e_pad // EDGE_CHUNK),
        in_specs=[
            chunk_spec,
            chunk_spec,
            chunk_spec,
            pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda kb, ke: (kb, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(ei, ej, w, idx_p)
    return out.reshape(m_pad)[:m]


def _small_kernel(tile, ei_ref, ej_ref, w_ref, out_ref):
    ke = pl.program_id(1)
    row = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    ei = ei_ref[...].reshape(1, -1)
    ej = ej_ref[...].reshape(1, -1)
    w = w_ref[...].reshape(-1, 1)
    crossed = ((row >> ei) ^ (row >> ej)) & 1
    partial = jnp.dot(
        crossed.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(ke == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(ke != 0)
    def _acc():
        out_ref[...] += partial

"""Pallas TPU kernel: fused transverse-field mixer RX(2β)^{⊗k}.

The full n-qubit mixer factorizes into ⌈n/7⌉ grouped unitaries of size
2^7 = 128 — exactly one MXU tile. The group matrix is *generated inside the
kernel* from β and popcount(a⊕b) (zero HBM traffic for the operator):

    U[a,b] = cos(β)^(k−d)·(−i sin β)^d,  d = popcount(a⊕b)
    C = Re U (d even), D = Im U (d odd) — both symmetric, so the state can
    be right-multiplied:  out = S·C ± (i) S·D  on (re, im) planes.

Grid: row tiles of the (R, 2^k) state view; per step two MXU matmuls
(4 dots across the two planes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import popcount

ROW_TILE = 512


def _mixer_kernel(k: int, b_ref, re_ref, im_ref, ore_ref, oim_ref):
    dk = 2**k
    beta = b_ref[0, 0]
    a = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 0)
    b = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 1)
    d = popcount(a ^ b)
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    # integer powers by cumprod-free exponent trick: build per-entry products
    # via d as exponent on a (k+1)-entry lookup generated with lax.pow on
    # non-negative magnitudes + sign bookkeeping (exact for negative bases).
    dd = d.astype(jnp.float32)
    kk = jnp.float32(k)
    mag = (
        jnp.power(jnp.abs(cb), kk - dd)
        * jnp.power(jnp.abs(sb), dd)
        * jnp.where(cb < 0, (-1.0) ** (kk - dd), 1.0)
        * jnp.where(sb < 0, (-1.0) ** dd, 1.0)
    )
    m4 = d % 4
    cmat = mag * jnp.where(m4 == 0, 1.0, jnp.where(m4 == 2, -1.0, 0.0))
    dmat = mag * jnp.where(m4 == 1, -1.0, jnp.where(m4 == 3, 1.0, 0.0))

    re = re_ref[...]
    im = im_ref[...]
    f32 = jnp.float32
    ore_ref[...] = jnp.dot(re, cmat, preferred_element_type=f32) - jnp.dot(
        im, dmat, preferred_element_type=f32
    )
    oim_ref[...] = jnp.dot(im, cmat, preferred_element_type=f32) + jnp.dot(
        re, dmat, preferred_element_type=f32
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def mixer_group_matmul(re_mat, im_mat, beta, k: int, *, interpret: bool = False):
    """Apply RX^{⊗k} to the trailing axis of (R, 2^k) state views."""
    r, dk = re_mat.shape
    assert dk == 2**k, (dk, k)
    tile = min(ROW_TILE, r)
    assert r % tile == 0, (r, tile)
    b = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    spec = pl.BlockSpec((tile, dk), lambda i: (i, 0))
    ore, oim = pl.pallas_call(
        functools.partial(_mixer_kernel, k),
        grid=(r // tile,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
        ],
        interpret=interpret,
    )(b, re_mat, im_mat)
    return ore, oim


def apply_mixer_bits(re, im, n: int, lo_bit: int, nbits: int, beta, *,
                     interpret: bool = False):
    """RX(2β)^{⊗nbits} on qubits [lo_bit, lo_bit+nbits) of a flat 2^n state.

    The wrapper owns the (X, 2^k, Y) → (X·Y, 2^k) relayout around the
    kernel call; XLA lowers it to on-chip relayout copies. Fusing the
    transpose into the kernel is tracked as a §Perf candidate.
    """
    k = nbits
    x = 2 ** (n - lo_bit - k)
    y = 2**lo_bit
    re3 = re.reshape(x, 2**k, y)
    im3 = im.reshape(x, 2**k, y)
    if y == 1:
        re_m, im_m = re3.reshape(x, 2**k), im3.reshape(x, 2**k)
        re_m, im_m = mixer_group_matmul(re_m, im_m, beta, k, interpret=interpret)
        return re_m.reshape(-1), im_m.reshape(-1)
    re_m = jnp.moveaxis(re3, 1, 2).reshape(x * y, 2**k)
    im_m = jnp.moveaxis(im3, 1, 2).reshape(x * y, 2**k)
    re_m, im_m = mixer_group_matmul(re_m, im_m, beta, k, interpret=interpret)
    re = jnp.moveaxis(re_m.reshape(x, y, 2**k), 2, 1).reshape(-1)
    im = jnp.moveaxis(im_m.reshape(x, y, 2**k), 2, 1).reshape(-1)
    return re, im


def apply_mixer(re, im, n: int, beta, group: int = 7, *, interpret: bool = False):
    """Full mixer via grouped `apply_mixer_bits` kernel calls."""
    for g0 in range(0, n, group):
        re, im = apply_mixer_bits(
            re, im, n, g0, min(group, n - g0), beta, interpret=interpret
        )
    return re, im

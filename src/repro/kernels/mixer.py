"""Pallas TPU kernel: fused transverse-field mixer RX(2β)^{⊗k}.

The full n-qubit mixer factorizes into ⌈n/7⌉ grouped unitaries of size
2^7 = 128 — exactly one MXU tile. The group matrix is *generated inside the
kernel* from β and popcount(a⊕b) (zero HBM traffic for the operator):

    U[a,b] = cos(β)^(k−d)·(−i sin β)^d,  d = popcount(a⊕b)
    C = Re U (d even), D = Im U (d odd) — both symmetric, so the state can
    be right-multiplied:  out = S·C ± (i) S·D  on (re, im) planes.

Two launchers cover the two layouts a group call sees:

  - `mixer_group_matmul`: the group occupies the trailing axis of a
    (R, 2^k) view — row tiles, two MXU matmuls per step.
  - `mixer_group_strided`: the group sits mid-state, i.e. the flat state
    factors as (X, 2^k, Y) with Y > 1. The strided BlockSpec index map
    carves (tx, 2^k, ty) blocks straight out of that view and contracts
    the middle axis in-kernel, so the old (X, 2^k, Y) → (X·Y, 2^k)
    moveaxis relayout (and its XLA copies on both sides of every group
    call) is gone — measured in `results/BENCH_kernel_autotune.json`
    (§Perf C11).

Block sizes resolve through `kernels.tuning` (autotuned per shape bucket
when enabled, hard defaults otherwise) as static jit arguments.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning
from repro.kernels.ref import popcount

ROW_TILE = 512
X_TILE = 8  # strided launcher: rows of the (X, 2^k, Y) view per block
Y_TILE = 128  # strided launcher: trailing-stride lanes per block


def rx_group_mats(beta, k: int):
    """(C, D) = (Re, Im) of the 2^k RX-group unitary, generated in-registers.

    Shared by every mixer-bearing kernel (grouped, strided, fused layer).
    Integer powers via the exponent trick: lax.pow on non-negative
    magnitudes + sign bookkeeping (exact for negative bases). Both C and D
    are symmetric; C is even in β and D odd, so the adjoint of the group
    unitary is the same generator evaluated at −β — the identity the
    `kernels.ops` custom-vjp rules run on.
    """
    dk = 2**k
    a = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 0)
    b = jax.lax.broadcasted_iota(jnp.int32, (dk, dk), 1)
    d = popcount(a ^ b)
    dd = d.astype(jnp.float32)
    kk = jnp.float32(k)
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    mag = (
        jnp.power(jnp.abs(cb), kk - dd)
        * jnp.power(jnp.abs(sb), dd)
        * jnp.where(cb < 0, (-1.0) ** (kk - dd), 1.0)
        * jnp.where(sb < 0, (-1.0) ** dd, 1.0)
    )
    m4 = d % 4
    cmat = mag * jnp.where(m4 == 0, 1.0, jnp.where(m4 == 2, -1.0, 0.0))
    dmat = mag * jnp.where(m4 == 1, -1.0, jnp.where(m4 == 3, 1.0, 0.0))
    return cmat, dmat


def _mixer_kernel(k: int, b_ref, re_ref, im_ref, ore_ref, oim_ref):
    cmat, dmat = rx_group_mats(b_ref[0, 0], k)
    re = re_ref[...]
    im = im_ref[...]
    f32 = jnp.float32
    ore_ref[...] = jnp.dot(re, cmat, preferred_element_type=f32) - jnp.dot(
        im, dmat, preferred_element_type=f32
    )
    oim_ref[...] = jnp.dot(im, cmat, preferred_element_type=f32) + jnp.dot(
        re, dmat, preferred_element_type=f32
    )


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def _mixer_group_matmul(re_mat, im_mat, beta, k: int, *, tile: int,
                        interpret: bool):
    r, dk = re_mat.shape
    b = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    spec = pl.BlockSpec((tile, dk), lambda i: (i, 0))
    ore, oim = pl.pallas_call(
        functools.partial(_mixer_kernel, k),
        grid=(r // tile,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)), spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
            jax.ShapeDtypeStruct((r, dk), jnp.float32),
        ],
        interpret=interpret,
    )(b, re_mat, im_mat)
    return ore, oim


def mixer_group_matmul(re_mat, im_mat, beta, k: int, *, interpret: bool = False):
    """Apply RX^{⊗k} to the trailing axis of (R, 2^k) state views."""
    r, dk = re_mat.shape
    assert dk == 2**k, (dk, k)
    tile = tuning.clamp_tile(r, tuning.param("mixer_matmul", r, "row_tile",
                                             ROW_TILE))
    return _mixer_group_matmul(re_mat, im_mat, beta, k, tile=tile,
                               interpret=interpret)


def _mixer_strided_kernel(k: int, b_ref, re_ref, im_ref, ore_ref, oim_ref):
    cmat, dmat = rx_group_mats(b_ref[0, 0], k)
    re = re_ref[...]  # (tx, 2^k, ty): group axis is the middle stride
    im = im_ref[...]
    f32 = jnp.float32
    ore_ref[...] = jnp.einsum(
        "xby,ba->xay", re, cmat, preferred_element_type=f32
    ) - jnp.einsum("xby,ba->xay", im, dmat, preferred_element_type=f32)
    oim_ref[...] = jnp.einsum(
        "xby,ba->xay", im, cmat, preferred_element_type=f32
    ) + jnp.einsum("xby,ba->xay", re, dmat, preferred_element_type=f32)


@functools.partial(jax.jit,
                   static_argnames=("k", "tile_x", "tile_y", "interpret"))
def _mixer_group_strided(re3, im3, beta, k: int, *, tile_x: int, tile_y: int,
                         interpret: bool):
    x, dk, y = re3.shape
    b = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    spec = pl.BlockSpec((tile_x, dk, tile_y), lambda i, j: (i, 0, j))
    ore, oim = pl.pallas_call(
        functools.partial(_mixer_strided_kernel, k),
        grid=(x // tile_x, y // tile_y),
        in_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)), spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((x, dk, y), jnp.float32),
            jax.ShapeDtypeStruct((x, dk, y), jnp.float32),
        ],
        interpret=interpret,
    )(b, re3, im3)
    return ore, oim


def mixer_group_strided(re3, im3, beta, k: int, *, interpret: bool = False):
    """Apply RX^{⊗k} to the *middle* axis of (X, 2^k, Y) state views —
    the relayout-free path for groups above the low bits."""
    x, dk, y = re3.shape
    assert dk == 2**k, (dk, k)
    rows = x * y
    tile_x = tuning.clamp_tile(
        x, tuning.param("mixer_strided", rows, "tile_x", X_TILE))
    tile_y = tuning.clamp_tile(
        y, tuning.param("mixer_strided", rows, "tile_y", Y_TILE))
    return _mixer_group_strided(re3, im3, beta, k, tile_x=tile_x,
                                tile_y=tile_y, interpret=interpret)


def apply_mixer_bits(re, im, n: int, lo_bit: int, nbits: int, beta, *,
                     interpret: bool = False):
    """RX(2β)^{⊗nbits} on qubits [lo_bit, lo_bit+nbits) of a flat 2^n state.

    lo_bit == 0 is the layout-A fast path (group on the trailing axis,
    plain row-tiled matmul). For lo_bit > 0 the strided kernel contracts
    the middle axis of the (X, 2^nbits, Y) view in place — the reshapes
    here are metadata-only, so no relayout copies are issued.
    """
    k = nbits
    x = 2 ** (n - lo_bit - k)
    y = 2**lo_bit
    re3 = re.reshape(x, 2**k, y)
    im3 = im.reshape(x, 2**k, y)
    if y == 1:
        re_m, im_m = re3.reshape(x, 2**k), im3.reshape(x, 2**k)
        re_m, im_m = mixer_group_matmul(re_m, im_m, beta, k, interpret=interpret)
        return re_m.reshape(-1), im_m.reshape(-1)
    re_m, im_m = mixer_group_strided(re3, im3, beta, k, interpret=interpret)
    return re_m.reshape(-1), im_m.reshape(-1)


def apply_mixer_bits_relayout(re, im, n: int, lo_bit: int, nbits: int, beta, *,
                              interpret: bool = False):
    """Pre-§Perf-C11 path: moveaxis the group to the trailing axis, run the
    row-tiled matmul, moveaxis back. Kept as the measured baseline for the
    autotune harness's relayout comparison (and as a parity oracle)."""
    k = nbits
    x = 2 ** (n - lo_bit - k)
    y = 2**lo_bit
    re3 = re.reshape(x, 2**k, y)
    im3 = im.reshape(x, 2**k, y)
    if y == 1:
        re_m, im_m = re3.reshape(x, 2**k), im3.reshape(x, 2**k)
        re_m, im_m = mixer_group_matmul(re_m, im_m, beta, k, interpret=interpret)
        return re_m.reshape(-1), im_m.reshape(-1)
    re_m = jnp.moveaxis(re3, 1, 2).reshape(x * y, 2**k)
    im_m = jnp.moveaxis(im3, 1, 2).reshape(x * y, 2**k)
    re_m, im_m = mixer_group_matmul(re_m, im_m, beta, k, interpret=interpret)
    re = jnp.moveaxis(re_m.reshape(x, y, 2**k), 2, 1).reshape(-1)
    im = jnp.moveaxis(im_m.reshape(x, y, 2**k), 2, 1).reshape(-1)
    return re, im


def apply_mixer(re, im, n: int, beta, group: int = 7, *, interpret: bool = False):
    """Full mixer via grouped `apply_mixer_bits` kernel calls."""
    for g0 in range(0, n, group):
        re, im = apply_mixer_bits(
            re, im, n, g0, min(group, n - g0), beta, interpret=interpret
        )
    return re, im

"""Pallas TPU kernel: batched Max-Cut evaluation of candidate assignments.

The merge phase scores huge frontiers of candidate assignments; on dense
graphs the MXU form wins:   cut_b = (W_tot − ½ s_b^T A s_b) / 2.

Grid: (batch tiles × K-dim chunks). Per step the kernel multiplies the
(BB, KV) spin slice into the (KV, V) adjacency slab, accumulating the
(BB, V) product in a VMEM scratch accumulator; the final chunk contracts
the accumulator against the full (BB, V) spin rows to the (BB, 1) output —
the classic matmul+epilogue fusion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning

BATCH_TILE = 128
K_CHUNK = 512


def _kernel(nk: int, wtot_ref, s_chunk_ref, a_ref, s_full_ref, out_ref, acc_ref):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        s_chunk_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == nk - 1)
    def _epilogue():
        quad = jnp.sum(acc_ref[...] * s_full_ref[...], axis=1, keepdims=True)
        out_ref[...] = (wtot_ref[0, 0] - 0.5 * quad) * 0.5


@functools.partial(jax.jit, static_argnames=("bt", "kc", "interpret"))
def _cut_batch_dense(spins, adjacency, total_weight, *, bt: int, kc: int,
                     interpret: bool):
    b, v = spins.shape
    # pad batch and V to tile multiples; padded spins=+1 rows are discarded,
    # padded adjacency rows/cols are zero so they never contribute.
    bp = tuning.round_up(b, bt)
    vp = tuning.round_up(v, kc)
    sp = jnp.ones((bp, vp), jnp.float32).at[:b, :v].set(spins)
    ap = jnp.zeros((vp, vp), jnp.float32).at[:v, :v].set(adjacency)
    wtot = jnp.asarray(total_weight, jnp.float32).reshape(1, 1)
    nk = vp // kc

    out = pl.pallas_call(
        functools.partial(_kernel, nk),
        grid=(bp // bt, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ik: (0, 0)),
            pl.BlockSpec((bt, kc), lambda ib, ik: (ib, ik)),  # spin K-slice
            pl.BlockSpec((kc, vp), lambda ib, ik: (ik, 0)),  # adjacency slab
            pl.BlockSpec((bt, vp), lambda ib, ik: (ib, 0)),  # full spin rows
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda ib, ik: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, vp), jnp.float32)],
        interpret=interpret,
    )(wtot, sp, ap, sp)
    return out[:b, 0]


def cut_batch_dense(spins, adjacency, total_weight, *, interpret: bool = False):
    """spins (B, V) ±1 float32; adjacency (V, V) float32 → (B,) cut values."""
    b, v = spins.shape
    _, bt = tuning.pad_and_tile(
        b, tuning.param("cut_batch_dense", v, "batch_tile", BATCH_TILE))
    _, kc = tuning.pad_and_tile(
        v, tuning.param("cut_batch_dense", v, "k_chunk", K_CHUNK))
    return _cut_batch_dense(spins, adjacency, total_weight, bt=bt, kc=kc,
                            interpret=interpret)

"""The observability layer's single sanctioned time source (DESIGN.md §8).

Every timestamp in `repro.obs` — span begin/end stamps, ledger
durations — flows from a clock *callable* injected at construction
time, defaulting to ``default_clock`` below. No other `repro.obs`
module may read `time` / `datetime` directly: reprolint's
`hot-nondeterminism` rule flags any clock read outside this module, so
a `workload.VirtualClock` injected into a `SolveService` (and from
there into its `Tracer`) provably reaches every stamp — which is what
makes a traced 2,000-request soak bit-deterministic
(tests/test_obs.py).

``default_clock`` is a bare alias, not a wrapper: call sites pay one
indirection, and identity comparisons against `time.perf_counter`
still hold.
"""

from __future__ import annotations

import time

# monotonic, high-resolution, never used for decisions — the same clock
# the scheduler defaults to (repro.service.scheduler)
default_clock = time.perf_counter

"""Metrics registry: counters, gauges, and fixed-bucket latency
histograms with exact percentiles (DESIGN.md §8).

One percentile implementation for the whole repo. `service_bench.py`,
`workload`'s soak summaries, and `serve_maxcut` each used to hand-roll
``sorted(lat)[...]`` index math; they now all route through
`percentile` / `Histogram` here, and `ServiceStats` / `TenantStats`
carry `Histogram` fields directly (the latent pre-§8 gap: the service
exposed no latency distribution at all and benches reconstructed it
externally).

`Histogram` keeps two views of the same stream:

  - fixed cumulative buckets (Prometheus ``le`` semantics) for the text
    exposition / cross-process aggregation, and
  - the raw samples, so ``percentile(q)`` is the *exact* nearest-rank
    order statistic, not a bucket interpolation — the repo's perf
    claims are measured numbers, and a claim gate on an interpolated
    p99 would move with the bucket layout.

Samples are floats (8 bytes each under ``array``-free simplicity): a
2,000-request soak retains 2,000 of them, which is noise next to the
solver arrays. Snapshots round-trip the samples (`snapshot` /
`restore`), so checkpointed per-tenant stats restore with exact
percentiles (tests/test_obs.py).

No clock reads here — durations are observed by callers against their
own injected clocks (the `repro.obs.clock` contract).
"""

from __future__ import annotations

import json
import math

# Prometheus-style latency buckets (seconds): sub-ms to minute-scale —
# the service's span from cache hits to 16k-vertex merges
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, float("inf"),
)


def percentile(samples, q: float) -> float:
    """Exact nearest-rank percentile: the smallest sample with at least
    ``ceil(q·n)`` samples ≤ it. Empty input → 0.0 (the benches' "no
    completed requests" convention). ``q`` in [0, 1]."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q out of [0, 1]: {q}")
    xs = sorted(samples)
    if not xs:
        return 0.0
    rank = max(math.ceil(q * len(xs)), 1)
    return float(xs[min(rank, len(xs)) - 1])


class Counter:
    """Monotone event count."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter decrement: {amount}")
        self.value += amount


class Gauge:
    """Last-written instantaneous value."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = float(value)

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Fixed-bucket cumulative histogram plus retained raw samples."""

    __slots__ = ("buckets", "bucket_counts", "count", "sum", "samples")

    def __init__(self, buckets=DEFAULT_BUCKETS):
        buckets = tuple(float(b) for b in buckets)
        if list(buckets) != sorted(buckets) or not buckets:
            raise ValueError(f"buckets must be sorted, non-empty: {buckets}")
        if buckets[-1] != float("inf"):
            buckets = buckets + (float("inf"),)
        self.buckets = buckets
        self.bucket_counts = [0] * len(buckets)
        self.count = 0
        self.sum = 0.0
        self.samples: list[float] = []

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.samples.append(value)
        for i, le in enumerate(self.buckets):
            if value <= le:
                self.bucket_counts[i] += 1
                break

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def cumulative_counts(self) -> list[int]:
        """Prometheus ``le`` semantics: count of samples ≤ each bound."""
        out, running = [], 0
        for c in self.bucket_counts:
            running += c
            out.append(running)
        return out

    def summary(self) -> dict:
        """The compact JSON shape stats/bench rows embed: exact p50/p99
        plus count/sum — no raw samples (those belong to `snapshot`)."""
        return {
            "count": self.count,
            "sum": round(self.sum, 6),
            "p50": round(self.percentile(0.5), 6),
            "p99": round(self.percentile(0.99), 6),
        }

    # ------------------------------------------------- checkpoint round-trip --
    def snapshot(self) -> dict:
        """Full JSON-able state; `restore` reproduces exact percentiles."""
        return {
            "buckets": ["inf" if math.isinf(b) else b for b in self.buckets],
            "samples": list(self.samples),
        }

    @classmethod
    def restore(cls, state: dict) -> "Histogram":
        h = cls(tuple(
            float("inf") if b == "inf" else float(b)
            for b in state["buckets"]
        ))
        for v in state["samples"]:
            h.observe(v)
        return h

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, Histogram)
            and self.buckets == other.buckets
            and self.samples == other.samples
        )


class MetricsRegistry:
    """Named metrics with one JSON snapshot and one Prometheus text
    exposition. Names are dotted internally; the Prometheus view maps
    dots to underscores (its identifier grammar)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(buckets)
        return self._histograms[name]

    def attach_histogram(self, name: str, hist: Histogram) -> Histogram:
        """Register an externally owned histogram (e.g. the one living
        inside `ServiceStats`) so snapshots see the live object."""
        self._histograms[name] = hist
        return hist

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
        }

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=1)

    @staticmethod
    def _prom_name(name: str) -> str:
        return name.replace(".", "_").replace("-", "_")

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines = []
        for k, c in sorted(self._counters.items()):
            n = self._prom_name(k)
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {c.value:g}")
        for k, g in sorted(self._gauges.items()):
            n = self._prom_name(k)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {g.value:g}")
        for k, h in sorted(self._histograms.items()):
            n = self._prom_name(k)
            lines.append(f"# TYPE {n} histogram")
            for le, cum in zip(h.buckets, h.cumulative_counts()):
                bound = "+Inf" if math.isinf(le) else f"{le:g}"
                lines.append(f'{n}_bucket{{le="{bound}"}} {cum}')
            lines.append(f"{n}_sum {h.sum:g}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"

"""Schema + invariant validation for exported traces and metrics
snapshots (DESIGN.md §8); the CI `obs` job's gate.

  PYTHONPATH=src python -m repro.obs.validate \\
      --trace /tmp/obs_trace.jsonl --metrics /tmp/obs_metrics.json

Trace validation checks structure *and* the span-tree invariants the
tests rely on: every record is a complete span with ``t1 >= t0``, every
``parent_id`` resolves to a span whose interval contains the child's,
span ids are unique, and every ``request`` root carries a terminal
``status`` attribute in {completed, shed, expired}. Metrics validation
checks the `MetricsRegistry.snapshot()` shape (counters/gauges are
name→number maps; histograms carry count/sum/p50/p99). Both return a
list of violation strings — empty means valid — and the CLI exits
nonzero on any violation.

Stdlib-only (no jax import) so the gate runs anywhere.
"""

from __future__ import annotations

import argparse
import json
import sys

TERMINAL_STATUSES = ("completed", "shed", "expired")

_SPAN_KEYS = {"span_id", "parent_id", "name", "t0", "t1", "attrs"}


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_trace_records(records) -> list[str]:
    """Violations in a parsed span list (dicts in `Span.as_dict` shape)."""
    errors: list[str] = []
    by_id: dict = {}
    for i, rec in enumerate(records):
        where = f"span[{i}]"
        if not isinstance(rec, dict):
            errors.append(f"{where}: not an object")
            continue
        missing = _SPAN_KEYS - set(rec)
        if missing:
            errors.append(f"{where}: missing keys {sorted(missing)}")
            continue
        if not isinstance(rec["name"], str) or not rec["name"]:
            errors.append(f"{where}: bad name {rec['name']!r}")
        if not isinstance(rec["span_id"], int):
            errors.append(f"{where}: non-int span_id")
            continue
        if rec["span_id"] in by_id:
            errors.append(f"{where}: duplicate span_id {rec['span_id']}")
        if not (_is_num(rec["t0"]) and _is_num(rec["t1"])):
            errors.append(f"{where}: non-numeric t0/t1")
            continue
        if rec["t1"] < rec["t0"]:
            errors.append(
                f"{where} ({rec['name']}): t1 {rec['t1']} < t0 {rec['t0']}"
            )
        if not isinstance(rec["attrs"], dict):
            errors.append(f"{where}: attrs not an object")
            continue
        by_id[rec["span_id"]] = rec
        if rec["name"] == "request":
            status = rec["attrs"].get("status")
            if status not in TERMINAL_STATUSES:
                errors.append(
                    f"{where}: request span without terminal status "
                    f"(got {status!r})"
                )
    # parent resolution + interval nesting
    for rec in records:
        if not isinstance(rec, dict) or rec.get("parent_id") is None:
            continue
        parent = by_id.get(rec.get("parent_id"))
        name = rec.get("name")
        if parent is None:
            errors.append(
                f"span {rec.get('span_id')} ({name}): dangling parent_id "
                f"{rec.get('parent_id')}"
            )
            continue
        if not (parent["t0"] <= rec["t0"] and rec["t1"] <= parent["t1"]):
            errors.append(
                f"span {rec['span_id']} ({name}) "
                f"[{rec['t0']}, {rec['t1']}] escapes parent "
                f"{parent['span_id']} ({parent['name']}) "
                f"[{parent['t0']}, {parent['t1']}]"
            )
    return errors


def validate_trace_jsonl(text: str) -> list[str]:
    records = []
    errors = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except ValueError as e:
            errors.append(f"line {lineno}: not JSON ({e})")
    return errors + validate_trace_records(records)


def validate_metrics(snapshot) -> list[str]:
    errors: list[str] = []
    if not isinstance(snapshot, dict):
        return ["metrics snapshot: not an object"]
    for section in ("counters", "gauges", "histograms"):
        if section not in snapshot:
            errors.append(f"metrics snapshot: missing {section!r}")
            continue
        if not isinstance(snapshot[section], dict):
            errors.append(f"{section}: not an object")
            continue
        for name, val in snapshot[section].items():
            if section == "histograms":
                if not isinstance(val, dict):
                    errors.append(f"histogram {name!r}: not an object")
                    continue
                for k in ("count", "sum", "p50", "p99"):
                    if not _is_num(val.get(k)):
                        errors.append(
                            f"histogram {name!r}: non-numeric {k!r}"
                        )
            elif not _is_num(val):
                errors.append(f"{section[:-1]} {name!r}: non-numeric value")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.validate",
        description="Validate exported JSON-lines traces and metrics "
        "snapshots against the DESIGN.md §8 schemas.",
    )
    ap.add_argument("--trace", help="JSON-lines trace file to validate")
    ap.add_argument("--metrics", help="metrics snapshot JSON to validate")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to validate: pass --trace and/or --metrics")

    failures = 0
    if args.trace:
        with open(args.trace) as f:
            errors = validate_trace_jsonl(f.read())
        for e in errors:
            print(f"[obs.validate] trace: {e}", file=sys.stderr)
        print(f"[obs.validate] {args.trace}: "
              f"{'OK' if not errors else f'{len(errors)} violation(s)'}")
        failures += len(errors)
    if args.metrics:
        with open(args.metrics) as f:
            errors = validate_metrics(json.load(f))
        for e in errors:
            print(f"[obs.validate] metrics: {e}", file=sys.stderr)
        print(f"[obs.validate] {args.metrics}: "
              f"{'OK' if not errors else f'{len(errors)} violation(s)'}")
        failures += len(errors)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Compile ledger: every cached-program build, first-call-per-shape
compile, and trace-time kernel dispatch, recorded (DESIGN.md §8).

Compile time is the dominant *hidden* cost of the pipeline — the
PR 7 SLA soak had to hand-warm every solver and merge program because a
multi-second XLA compile landing mid-soak reads as an SLA miss of the
service. The ledger makes that cost a measurable, regression-gated
quantity:

  - ``build``   — a `compat.cached_program` builder ran (lru-cache
    miss): one jit wrapper constructed for a novel static
    configuration. Key = the builder's arguments.
  - ``compile`` — a cached program's *first call at a novel shape
    signature*: the call that pays trace + XLA compile (duration
    includes that first execution — the cost the caller actually
    waits out). Subsequent same-shape calls hit jit's own cache and
    record nothing.
  - ``op``      — a `kernels.ops` entry point dispatched on tracer
    arguments: fires once per (re)trace per call site, so retrace
    storms (e.g. `merge_scan` retracing per novel graph shape) show up
    as op-event counts with the implementation that was active.

A warm system is therefore *provably* warm: re-running a workload after
`reset()` with all caches intact records zero build and zero compile
events (the acceptance gate in tests/test_obs.py and
`benchmarks/obs_bench.py` → `results/BENCH_obs.json`).

The ledger itself never reads a clock (the `repro.obs.clock` contract:
durations are stamped by `compat` against `default_clock` and passed
in), keeps bounded memory via an event cap, and is process-global —
program caches it mirrors are process-global too.
"""

from __future__ import annotations

import dataclasses

# op events dedup per (op, impl) with counts, but build/compile events
# are kept verbatim; a runaway shape storm stops recording (and starts
# counting drops) past this bound rather than growing without limit
MAX_EVENTS = 4096


@dataclasses.dataclass(frozen=True)
class LedgerEvent:
    """One recorded compile-path event."""

    kind: str  # "build" | "compile"
    name: str  # builder name (e.g. "_solve_pool_program")
    key: str  # repr of the builder's cache-key arguments
    signature: str  # arg shape/dtype signature ("" for build events)
    duration_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class CompileLedger:
    def __init__(self):
        self.events: list[LedgerEvent] = []
        self.dropped = 0
        # (op, impl) → trace-time dispatch count
        self.op_traces: dict[tuple[str, str], int] = {}

    # ------------------------------------------------------------- recording --
    def _append(self, event: LedgerEvent) -> None:
        if len(self.events) >= MAX_EVENTS:
            self.dropped += 1
            return
        self.events.append(event)

    def note_build(self, name: str, key: str, duration_s: float) -> None:
        self._append(LedgerEvent("build", name, key, "", float(duration_s)))

    def note_compile(
        self, name: str, key: str, signature: str, duration_s: float
    ) -> None:
        self._append(
            LedgerEvent("compile", name, key, signature, float(duration_s))
        )

    def note_op(self, op: str, impl: str) -> None:
        k = (op, impl)
        self.op_traces[k] = self.op_traces.get(k, 0) + 1

    # --------------------------------------------------------------- reading --
    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    @property
    def builds(self) -> list[LedgerEvent]:
        return [e for e in self.events if e.kind == "build"]

    @property
    def compiles(self) -> list[LedgerEvent]:
        return [e for e in self.events if e.kind == "compile"]

    def total_compile_s(self) -> float:
        return sum(e.duration_s for e in self.compiles)

    def snapshot(self) -> dict:
        """JSON-able view for metrics exports and the obs bench."""
        return {
            "builds": self.count("build"),
            "compiles": self.count("compile"),
            "compile_s": round(self.total_compile_s(), 6),
            "dropped": self.dropped,
            "op_traces": {
                f"{op}[{impl}]": n
                for (op, impl), n in sorted(self.op_traces.items())
            },
            "events": [e.as_dict() for e in self.events],
        }

    def reset(self) -> None:
        """Start a fresh accounting window. Does NOT clear any program
        cache — that is the point: a warm re-run after `reset()` must
        record zero build/compile events."""
        self.events.clear()
        self.op_traces.clear()
        self.dropped = 0


# process-global, mirroring the process-global program caches it audits
_LEDGER = CompileLedger()


def get_ledger() -> CompileLedger:
    return _LEDGER

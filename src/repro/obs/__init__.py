"""Request-to-kernel observability: span tracing, metrics, compile
ledger (DESIGN.md §8).

Three independent parts with one shared rule — no module here reads a
wall clock except `repro.obs.clock`:

  - `trace`   — `Tracer` / `Span`: nested spans over the request
    lifecycle and core pipeline stages, JSON-lines + Chrome trace
    export.
  - `metrics` — `MetricsRegistry`, `Counter` / `Gauge` / `Histogram`,
    exact nearest-rank `percentile`; JSON + Prometheus exposition.
  - `ledger`  — `CompileLedger`: every cached-program build, per-shape
    compile, and trace-time kernel dispatch.

`validate` holds the trace/metrics schema validators the CI obs job
runs (``python -m repro.obs.validate``).
"""

from repro.obs.clock import default_clock
from repro.obs.ledger import CompileLedger, LedgerEvent, get_ledger
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from repro.obs.trace import Span, Tracer, get_tracer, set_tracer, use_tracer

__all__ = [
    "DEFAULT_BUCKETS",
    "CompileLedger",
    "Counter",
    "Gauge",
    "Histogram",
    "LedgerEvent",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "default_clock",
    "get_ledger",
    "get_tracer",
    "percentile",
    "set_tracer",
    "use_tracer",
]

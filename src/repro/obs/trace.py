"""Structured span tracer for the request-to-kernel lifecycle
(DESIGN.md §8).

A `Span` is one named, timed interval with a parent pointer and a flat
attribute dict; a `Tracer` mints them against an injectable clock (the
`repro.obs.clock` contract — a `workload.VirtualClock` makes whole
traced soaks bit-deterministic). Two usage shapes coexist because the
solve service interleaves many request lifecycles on one thread:

  - **explicit-parent** ``begin(name, parent=...)`` / ``end(span)`` for
    long-lived spans that outlive any call frame (a request's root span
    opens at `submit` and closes at its terminal state, with admission,
    dispatch, and merge spans from other requests in between);
  - **stack-scoped** ``with tracer.span(name):`` for synchronous stages
    (partition, merge levels) — the context manager keeps an implicit
    parent stack, and ``attach(span)`` pushes an existing span so
    nested library code (e.g. `core.merge.merge_stream`) parents its
    spans under the caller's without threading tracer arguments through
    every signature.

``record=False`` (the default everywhere) keeps no spans: `begin`/`end`
still stamp the clock — the scheduler derives its recalibration
observations and latency stamps from span durations, so the stamps must
exist unconditionally — but nothing is retained or exported, which is
what keeps tracing-off overhead at zero allocation growth. `--trace-out`
on the launch drivers constructs the tracer with ``record=True``.

Retained spans export as JSON-lines (one span object per line, sorted
by ``(t0, span_id)`` so identical runs produce byte-identical files)
and as Chrome trace-event format (``ph: "X"`` complete events,
microsecond units) loadable in Perfetto — see README "Observability".

Module-global accessors (`get_tracer` / `set_tracer` / `use_tracer`)
let the core pipeline stages emit spans without a tracer parameter:
the default global tracer records nothing, and the service/driver
swaps its own in scope-bound via `use_tracer`.
"""

from __future__ import annotations

import contextlib
import json

from repro.obs.clock import default_clock

# sentinel for `begin(parent=ROOT)`: force a parentless span even when
# the implicit stack is non-empty (e.g. a request submitted from inside
# another request's streaming callback must still root its own tree)
ROOT = object()


class Span:
    """One named, timed interval. ``t1 is None`` until ended."""

    __slots__ = ("span_id", "parent_id", "name", "t0", "t1", "attrs")

    def __init__(self, span_id, parent_id, name, t0, attrs):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1 = None
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        if self.t1 is None:
            raise ValueError(f"span {self.name!r} not ended")
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "attrs": self.attrs,
        }

    def __repr__(self) -> str:  # debugging aid, never parsed
        return (
            f"Span({self.name!r}, id={self.span_id}, "
            f"parent={self.parent_id}, t0={self.t0}, t1={self.t1})"
        )


class Tracer:
    """Mints spans against one injected clock; retains them only when
    ``record=True`` (tracing is disabled by default — DESIGN.md §8)."""

    def __init__(self, clock=default_clock, record: bool = False):
        self._clock = clock
        self.record = bool(record)
        self.spans: list[Span] = []  # ended spans, when recording
        self._stack: list[Span] = []  # implicit-parent stack
        self._next_id = 1
        self._open = 0  # begun-but-unended spans (export sanity)

    # ------------------------------------------------------------ lifecycle --
    def begin(self, name: str, parent: Span | None = None, **attrs) -> Span:
        """Open a span. ``parent=None`` adopts the top of the implicit
        stack (or roots the span if the stack is empty); ``parent=ROOT``
        forces a parentless span regardless of the stack."""
        if parent is ROOT:
            parent = None
        elif parent is None and self._stack:
            parent = self._stack[-1]
        span = Span(
            self._next_id,
            None if parent is None else parent.span_id,
            name,
            self._clock(),
            attrs,
        )
        self._next_id += 1
        self._open += 1
        return span

    def end(self, span: Span, **attrs) -> Span:
        """Close a span (exactly once), merging any final attributes."""
        if span.t1 is not None:
            raise ValueError(f"span {span.name!r} ended twice")
        if attrs:
            span.attrs.update(attrs)
        span.t1 = self._clock()
        self._open -= 1
        if self.record:
            self.spans.append(span)
        return span

    def span_at(
        self, name: str, t0: float, t1: float,
        parent: Span | None = None, **attrs,
    ) -> Span:
        """A retroactive complete span over caller-supplied stamps.

        The scheduler's solve window is reconstructed at harvest time
        (``max(issue, previous harvest)`` → land, DESIGN.md §6.5), so
        the span cannot be opened live; the stamps must come from the
        same injected clock for nesting invariants to hold.
        """
        if parent is ROOT:
            parent = None
        elif parent is None and self._stack:
            parent = self._stack[-1]
        span = Span(
            self._next_id,
            None if parent is None else parent.span_id,
            name,
            float(t0),
            attrs,
        )
        self._next_id += 1
        span.t1 = float(t1)
        if self.record:
            self.spans.append(span)
        return span

    @contextlib.contextmanager
    def span(self, name: str, parent: Span | None = None, **attrs):
        """Stack-scoped span: children begun inside the block nest
        under it implicitly."""
        s = self.begin(name, parent=parent, **attrs)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            self.end(s)

    @contextlib.contextmanager
    def attach(self, span: Span):
        """Push an *existing* (still-open) span onto the implicit stack
        without ending it — nested library spans parent under it."""
        self._stack.append(span)
        try:
            yield span
        finally:
            self._stack.pop()

    # --------------------------------------------------------------- export --
    def _sorted(self) -> list[Span]:
        return sorted(self.spans, key=lambda s: (s.t0, s.span_id))

    def to_jsonl(self) -> str:
        """One JSON object per line, byte-stable across identical runs."""
        return "\n".join(
            json.dumps(s.as_dict(), sort_keys=True) for s in self._sorted()
        )

    def export_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
            f.write("\n")
        return path

    def to_chrome(self) -> dict:
        """Chrome trace-event format: ``ph: "X"`` complete events in
        microseconds, Perfetto-loadable (README "Observability")."""
        events = []
        for s in self._sorted():
            args = dict(s.attrs)
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name,
                "ph": "X",
                "ts": s.t0 * 1e6,
                "dur": (s.t1 - s.t0) * 1e6,
                "pid": 1,
                "tid": 1,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, sort_keys=True)
        return path

    def export(self, path: str, fmt: str = "jsonl") -> str:
        if fmt == "jsonl":
            return self.export_jsonl(path)
        if fmt == "chrome":
            return self.export_chrome(path)
        raise ValueError(f"unknown trace format {fmt!r}")


# ------------------------------------------------------- global accessors --
# the ambient tracer core pipeline stages emit against; records nothing
# until a driver/service installs its own (tracing off by default)
_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = tracer
    return prev


@contextlib.contextmanager
def use_tracer(tracer: Tracer):
    """Scope-bound global-tracer override (restores on exit, even on
    error) — the service installs its own tracer around merge/solve
    stages so library spans land in the request's trace."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)

"""Architecture registry: one module per assigned architecture.

`get_config(arch_id)` returns the full published config;
`get_reduced(arch_id)` returns the same-family CPU smoke-test variant.
Shapes (assigned per-arch input-shape set) live in `shapes.py`.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

ARCH_IDS = (
    "qwen1_5_0_5b",
    "gemma3_4b",
    "internlm2_20b",
    "gemma3_27b",
    "internvl2_2b",
    "moonshot_v1_16b_a3b",
    "arctic_480b",
    "whisper_medium",
    "zamba2_2_7b",
    "mamba2_1_3b",
    "paraqaoa",  # the paper's own workload, first-class citizen
)

# dashed aliases matching the assignment table
ALIASES = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma3-4b": "gemma3_4b",
    "internlm2-20b": "internlm2_20b",
    "gemma3-27b": "gemma3_27b",
    "internvl2-2b": "internvl2_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "arctic-480b": "arctic_480b",
    "whisper-medium": "whisper_medium",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "paraqaoa": "paraqaoa",
}


def canonical(arch_id: str) -> str:
    return ALIASES.get(arch_id, arch_id)


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.CONFIG


def get_reduced(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    return reduced(mod.CONFIG)


def lm_arch_ids():
    return tuple(a for a in ARCH_IDS if a != "paraqaoa")

"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: MoE 64 experts
top-6, d_ff=1408 per expert."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    n_experts=64,
    experts_per_token=6,
    tie_embeddings=True,
    rope_theta=50_000.0,
    max_seq=32_768,
)

"""gemma3-4b [hf:google/gemma-3-*-pt]: dense, 5:1 local:global sliding
window, 128k context. head_dim=256 (decoupled from d_model/n_heads)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    tie_embeddings=True,
    sliding_window=1024,
    global_every=6,  # layers 5, 11, ... are global → 5 local : 1 global
    rope_theta=1_000_000.0,
    max_seq=131_072,
)

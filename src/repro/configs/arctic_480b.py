"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 128-expert top-2 MoE
with a dense residual FFN in parallel (dense-MoE hybrid)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    tie_embeddings=False,
    rope_theta=10_000.0,
    max_seq=4096,
)

"""whisper-medium [arXiv:2212.04356]: encoder-decoder; conv audio frontend
is a STUB (input_specs supplies precomputed frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder layers
    encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    act="gelu",
    tie_embeddings=True,
    frontend="audio_stub",
    encoder_seq=1500,
    max_seq=32_768,
)

"""The paper's own workload as a selectable config: parameter taxonomy of
§4.2 (hardware-dependent / input-dependent / tunable)."""
from repro.core.paraqaoa import ParaQAOAConfig

# production setting: 26-qubit solvers (the paper's GPU cap), pod-scale pool
CONFIG = ParaQAOAConfig(
    n_qubits=26,
    n_solvers=256,  # one per chip on a 16x16 pod
    top_k=2,
    merge_level=2,
    p_layers=3,
    opt_steps=60,
)

# CPU-runnable setting used by tests/benchmarks
REDUCED = ParaQAOAConfig(
    n_qubits=12,
    n_solvers=1,
    top_k=2,
    merge_level=1,
    p_layers=3,
    opt_steps=30,
)

"""gemma3-27b [hf:google/gemma-3-27b-pt]: dense, 5:1 local:global, 128k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    tie_embeddings=True,
    sliding_window=1024,
    global_every=6,
    rope_theta=1_000_000.0,
    max_seq=131_072,
)

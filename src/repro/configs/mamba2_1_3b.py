"""mamba2-1.3b [arXiv:2405.21060]: attention-free SSD (state-space duality).
d_inner = 2*2048, 64 heads of P=64, N=128 state."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    max_seq=524_288,
)

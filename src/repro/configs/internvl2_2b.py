"""internvl2-2b [arXiv:2404.16821]: InternViT frontend (STUB — input_specs
provides precomputed patch embeddings) + InternLM2-2b text backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    frontend_seq=256,  # patch embeddings per image (stub)
    frontend_dim=2048,
    max_seq=32_768,
)

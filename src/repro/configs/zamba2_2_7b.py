"""zamba2-2.7b [arXiv:2411.15242]: Mamba2 backbone + one shared full
transformer block applied every 6th layer (shared weights, per-application
KV caches)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    tie_embeddings=True,
    max_seq=524_288,
)

"""CLI driver: ``python -m repro.analysis [paths] [options]``.

Exit codes: 0 clean (or all findings suppressed/baselined), 1 actionable
findings, 2 usage/crash. CI runs this over src/repro with --format json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.engine import (
    collect_files,
    load_baseline,
    run_on_sources,
    write_baseline,
)
from repro.analysis.rules import rule_ids

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: AST invariant checker for the repro stack",
    )
    p.add_argument(
        "paths", nargs="*", default=None,
        help="files or directories to analyze (default: src/repro)",
    )
    p.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)",
    )
    p.add_argument(
        "--rules", default=None,
        help="comma-separated rule ids to run (default: all)",
    )
    p.add_argument(
        "--baseline", default=_DEFAULT_BASELINE,
        help="baseline file of grandfathered findings "
             "(default: the checked-in one; 'none' disables)",
    )
    p.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite the baseline to absorb all current findings, "
             "then exit 0",
    )
    p.add_argument(
        "--list-rules", action="store_true",
        help="print rule ids and exit",
    )
    return p


def main(argv: list[str] | None = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        for rid in rule_ids():
            print(rid)
        return 0

    paths = args.paths or ["src/repro"]
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]

    files = collect_files(paths)
    if not files:
        print(f"reprolint: no .py files under {paths}", file=sys.stderr)
        return 2
    sources = {}
    for path in files:
        with open(path, encoding="utf-8") as f:
            sources[path] = f.read()

    baseline_path = None if args.baseline == "none" else args.baseline
    try:
        if args.write_baseline:
            report = run_on_sources(sources, rules=rules, baseline=set())
            write_baseline(baseline_path or _DEFAULT_BASELINE, report.findings)
            print(
                f"reprolint: wrote {len(report.findings)} finding(s) to "
                f"{baseline_path or _DEFAULT_BASELINE}"
            )
            return 0
        report = run_on_sources(
            sources, rules=rules, baseline=load_baseline(baseline_path)
        )
    except KeyError as e:
        print(f"reprolint: {e.args[0]}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps(report.as_dict(), indent=2))
    else:
        for f in report.findings:
            print(f.render())
        print(
            f"reprolint: {len(report.findings)} finding(s) in "
            f"{report.files} file(s) "
            f"({report.suppressed} suppressed, {report.baselined} baselined)"
        )
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())

"""reprolint: AST invariant checker for the repro JAX/Pallas stack.

Five rules, each descended from a bug this repo actually shipped or a
contract its tests policed by hand (catalog: docs/ANALYSIS.md):

  cache-key           cached program builders key mutable dispatch state
  dispatch-purity     kernel impls reachable only through kernels.ops
  tracer-hazard       no host casts / np.* / Python control flow on tracers
  collective-axis     lax collective axis names resolve to mesh axes
  hot-nondeterminism  no clocks/stdlib RNG in traced or replayed paths

Run it:    python -m repro.analysis [paths] [--format json]
Suppress:  # reprolint: disable=<rule>         (same line)
           # reprolint: disable-file=<rule>    (whole file)
Baseline:  src/repro/analysis/baseline.json (grandfathered fingerprints)
"""

from repro.analysis.engine import (
    Finding,
    ModuleInfo,
    Project,
    Report,
    collect_files,
    load_baseline,
    run,
    run_on_sources,
    write_baseline,
)
from repro.analysis.rules import ALL_RULES, get_rules, rule_ids

__all__ = [
    "ALL_RULES",
    "Finding",
    "ModuleInfo",
    "Project",
    "Report",
    "collect_files",
    "get_rules",
    "load_baseline",
    "rule_ids",
    "run",
    "run_on_sources",
    "write_baseline",
]

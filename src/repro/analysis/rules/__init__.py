"""reprolint rule registry.

Each rule module exports a single Rule instance named ``RULE``; ids are
short kebab-case slugs used in suppression comments
(``# reprolint: disable=<id>``), ``--rules`` selection, and baseline
fingerprints. The catalog with per-rule rationale and the historical bug
each rule descends from lives in docs/ANALYSIS.md.
"""

from __future__ import annotations

from typing import Sequence

from repro.analysis.rules import (
    cache_key,
    collectives,
    determinism,
    dispatch_purity,
    tracer,
)

ALL_RULES = (
    cache_key.RULE,
    dispatch_purity.RULE,
    tracer.RULE,
    collectives.RULE,
    determinism.RULE,
)

_BY_ID = {r.id: r for r in ALL_RULES}


def rule_ids() -> list[str]:
    return [r.id for r in ALL_RULES]


def get_rules(ids: Sequence[str] | None = None):
    if ids is None:
        return ALL_RULES
    unknown = [i for i in ids if i not in _BY_ID]
    if unknown:
        raise KeyError(
            f"unknown rule(s) {unknown}; available: {sorted(_BY_ID)}"
        )
    return tuple(_BY_ID[i] for i in ids)

"""tracer-hazard: host-Python operations on traced values inside
jit/shard_map/vmap/grad/scan bodies.

Inside a traced function, parameters are (potentially) jax tracers.
`float(x)` / `int(x)` / `bool(x)` force concretization —
TracerConversionError at best, a silently baked-in constant at worst
(the class of bug behind pinning gradient traces in
`engine.sharded_ascent`); `np.*` calls on tracers either fail or fall
back to host numpy and break the trace; `if`/`while` on a traced value
is data-dependent Python control flow that jit cannot stage.

Traced functions are discovered project-wide (decorated with
jax.jit/compat.jit, passed to jit/shard_map/vmap/grad/lax.scan/..., plus
their lexically nested defs and same-module callees, transitively).

Taint = "may hold a traced array": function parameters — minus declared
statics (non-array annotations, lru_cache builder keys, jit
static_argnums/static_argnames; see `_static_params`) — propagated
through local assignments, subscripts, arithmetic, and jnp/lax calls.
Deliberately *dropped* at attribute loads (except .real/.imag/.T/.mT/.at)
— `x.shape[0]`, `cfg.opt_steps`, `layout.schedule` are static metadata —
and at `isinstance`/`len`/static-identity comparisons (`is`/`is not`),
the legal static-dispatch patterns this codebase leans on
(`engine.evolve` branching on the Layout kind). The asymmetry is
intentional: under-tainting only makes the rule quieter, never noisy.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ModuleInfo, Project

RULE_ID = "tracer-hazard"

_CASTS = {"float", "int", "bool", "complex"}
# attribute loads that still refer to the array's data
_DATA_ATTRS = {"real", "imag", "T", "mT", "at"}
# calls whose result is static regardless of argument taint
_UNTAINTING_CALLS = {
    "isinstance", "len", "type", "getattr", "hasattr", "id", "repr", "str",
    "jax.eval_shape", "jnp.shape", "jax.tree_util.tree_structure",
}
_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)

# annotations that still mean "this is (or may be) a traced array"
_ARRAYISH = ("Array", "ndarray", "Tensor", "pytree")
# builders behind these produce lru_cache keys: every param is hashable
# static config by construction
_CACHE_DECORATORS = {
    "repro.compat.cached_program", "compat.cached_program",
    "functools.lru_cache", "lru_cache", "functools.cache",
}
_JIT_DECORATORS = {"jax.jit", "repro.compat.jit", "jax.pmap"}


def _static_params(mod: ModuleInfo, fn: ast.AST) -> set[str]:
    """Params that are static configuration, never tracers.

    Three sources, all conventions this codebase actually keeps:
      1. a non-array type annotation (``n: int``, ``act: str``,
         ``mesh: Mesh``) — traced arrays travel unannotated or annotated
         ``jnp.ndarray`` / ``jax.Array``;
      2. params of ``compat.cached_program`` / ``lru_cache`` builders —
         they *are* the cache key, so they are hashable host values;
      3. ``static_argnums`` / ``static_argnames`` on a jit decorator.
    """
    a = fn.args
    positional = a.posonlyargs + a.args
    static: set[str] = set()
    for p in positional + a.kwonlyargs:
        if p.annotation is not None:
            text = ast.dump(p.annotation)
            if not any(t in text for t in _ARRAYISH):
                static.add(p.arg)
    for dec in fn.decorator_list:
        call = dec if isinstance(dec, ast.Call) else None
        target = dec.func if isinstance(dec, ast.Call) else dec
        qual = mod.qualify(target)
        if qual in ("functools.partial", "partial") and call and call.args:
            qual = mod.qualify(call.args[0])
        if qual in _CACHE_DECORATORS:
            return {p.arg for p in positional + a.kwonlyargs}
        if qual in _JIT_DECORATORS and call is not None:
            for kw in call.keywords:
                vals = []
                if isinstance(kw.value, ast.Constant):
                    vals = [kw.value.value]
                elif isinstance(kw.value, (ast.Tuple, ast.List)):
                    vals = [
                        e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                    ]
                if kw.arg == "static_argnums":
                    for v in vals:
                        if isinstance(v, int) and v < len(positional):
                            static.add(positional[v].arg)
                elif kw.arg == "static_argnames":
                    static.update(v for v in vals if isinstance(v, str))
    return static


def walk_shallow(fn: ast.AST):
    """Walk a function body without descending into nested defs — each
    nested def is a separate traced entry with its own taint set."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _FuncNode):
            stack.extend(ast.iter_child_nodes(node))


class _Taint:
    """Flow-insensitive may-be-traced analysis for one function body."""

    def __init__(self, mod: ModuleInfo, fn: ast.AST):
        self.mod = mod
        a = fn.args
        self.tainted: set[str] = {
            p.arg for p in a.posonlyargs + a.args + a.kwonlyargs
        }
        if a.vararg:
            self.tainted.add(a.vararg.arg)
        if a.kwarg:
            self.tainted.add(a.kwarg.arg)
        self.tainted -= _static_params(mod, fn)
        # fixpoint over simple assignments; bodies are small
        changed = True
        while changed:
            changed = False
            for node in walk_shallow(fn):
                targets = []
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.For):
                    targets, value = [node.target], node.iter
                elif isinstance(node, ast.withitem) and node.optional_vars:
                    targets, value = [node.optional_vars], node.context_expr
                else:
                    continue
                if not self.is_tainted(value):
                    continue
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name) and \
                                n.id not in self.tainted:
                            self.tainted.add(n.id)
                            changed = True

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            return node.attr in _DATA_ATTRS and self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # identity checks are static even on tracers
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            # `"key" in params`: pytree/dict-structure membership, static
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                    and isinstance(node.left, ast.Constant) \
                    and isinstance(node.left.value, str):
                return False
            return self.is_tainted(node.left) or any(
                self.is_tainted(c) for c in node.comparators
            )
        if isinstance(node, ast.IfExp):
            return any(
                self.is_tainted(n) for n in (node.body, node.test, node.orelse)
            )
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            qual = self.mod.qualify(node.func)
            if qual in _UNTAINTING_CALLS:
                return False
            if self._args_tainted(node):
                return True
            # method call on array data (x.at[...].set, cut.at(b), x.sum())
            if isinstance(node.func, ast.Attribute):
                return self.is_tainted(node.func)
            return False
        return False

    def _args_tainted(self, call: ast.Call) -> bool:
        return any(self.is_tainted(a) for a in call.args) or any(
            self.is_tainted(k.value) for k in call.keywords
        )


class TracerHazardRule:
    id = RULE_ID
    summary = (
        "no float/int/bool casts, np.* calls, or data-dependent Python "
        "control flow on traced values inside jitted/shard_mapped bodies"
    )

    def check(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for fn in project.functions():
            if fn.node not in project.traced:
                continue
            if not isinstance(fn.node, _FuncNode):
                continue
            findings.extend(self._check_fn(fn.module, fn.node, fn.qualname))
        return findings

    def _check_fn(
        self, mod: ModuleInfo, fn: ast.AST, qualname: str
    ) -> list[Finding]:
        taint = _Taint(mod, fn)
        symbol = qualname[len(mod.modname) + 1:] if \
            qualname.startswith(mod.modname + ".") else qualname
        out: list[Finding] = []
        for node in walk_shallow(fn):
            if isinstance(node, ast.Call):
                qual = mod.qualify(node.func) or ""
                if qual in _CASTS and taint._args_tainted(node):
                    out.append(mod.finding(
                        self.id, node,
                        f"{qual}() on a traced value concretizes the "
                        "tracer inside a traced function; use jnp casts "
                        "or hoist to the host side",
                        symbol=symbol,
                    ))
                elif (qual == "numpy" or qual.startswith("numpy.")) and \
                        taint._args_tainted(node):
                    out.append(mod.finding(
                        self.id, node,
                        f"host numpy call '{qual}' on a traced value "
                        "inside a traced function; use jnp",
                        symbol=symbol,
                    ))
            elif isinstance(node, (ast.If, ast.While)):
                if taint.is_tainted(node.test):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    out.append(mod.finding(
                        self.id, node,
                        f"data-dependent Python `{kind}` on a traced "
                        "value; jit cannot stage it — use lax.cond/"
                        "lax.while_loop or jnp.where",
                        symbol=symbol,
                    ))
        return out


RULE = TracerHazardRule()

"""collective-axis: `lax.psum`/`pmean`/`all_to_all`/... axis names must
resolve to something the surrounding mesh can bind.

A collective with an axis name that no enclosing `shard_map`/`pmap` mesh
defines fails at trace time with an unbound-axis error — but only on the
path that actually traces it, which for the service backends means "in
production, under load, on the mesh topology CI never ran". The repo's
convention (engine.py, distributed.py, merge.py) is to thread the axis
through a parameter or a layout attribute (`layout.axis`), with the mesh
axes themselves named by `compat.mesh_data_axes()` / `mesh_model_axis()`:
"data", "model", and "pod".

Accepted axis arguments, recursively through tuples:

  - a string literal naming a known mesh axis ("data"/"model"/"pod"),
  - a plain name bound in an enclosing scope (parameter or local — the
    caller owns resolvability),
  - an attribute whose terminal component mentions "axis"
    (`layout.axis`, `cfg.model_axis`).

Anything else — an unknown literal (typo'd axis name) or a computed
expression the linter cannot follow — is flagged.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ModuleInfo, Project

RULE_ID = "collective-axis"

# mesh axis names minted by compat.mesh_data_axes()/mesh_model_axis()
KNOWN_AXES = {"data", "model", "pod"}

# collective → positional index of the axis-name argument
_AXIS_ARG: dict[str, int] = {
    "jax.lax.psum": 1,
    "jax.lax.pmean": 1,
    "jax.lax.pmax": 1,
    "jax.lax.pmin": 1,
    "jax.lax.psum_scatter": 1,
    "jax.lax.all_gather": 1,
    "jax.lax.all_to_all": 1,
    "jax.lax.ppermute": 1,
    "jax.lax.pshuffle": 1,
    "jax.lax.pswapaxes": 1,
    "jax.lax.axis_index": 0,
    "jax.lax.axis_size": 0,
}
_AXIS_KWARG = "axis_name"

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def _scope_names(node: ast.AST) -> set[str]:
    """Names bound inside one scope: parameters plus anything stored by
    the body (without descending into nested defs — those are their own
    scopes, though they *read* this one, hence the scope-chain union in
    the visitor)."""
    names: set[str] = set()
    if isinstance(node, _FuncNode):
        a = node.args
        names.update(p.arg for p in a.posonlyargs + a.args + a.kwonlyargs)
        if a.vararg:
            names.add(a.vararg.arg)
        if a.kwarg:
            names.add(a.kwarg.arg)
    elif isinstance(node, ast.Lambda):
        a = node.args
        names.update(p.arg for p in a.posonlyargs + a.args + a.kwonlyargs)
    stack = list(ast.iter_child_nodes(node))
    while stack:
        sub = stack.pop()
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            names.add(sub.id)
        elif isinstance(sub, (ast.Import, ast.ImportFrom)):
            for alias in sub.names:
                names.add(alias.asname or alias.name.split(".")[0])
        if not isinstance(sub, _FuncNode):
            stack.extend(ast.iter_child_nodes(sub))
    return names


class CollectiveAxisRule:
    id = RULE_ID
    summary = (
        "lax collective axis names must be known mesh axes, in-scope "
        "names, or *.axis attributes"
    )

    def check(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for mod in project.modules:
            scope = _scope_names(mod.tree)
            self._visit(mod, mod.tree, scope, findings)
        return findings

    def _visit(
        self,
        mod: ModuleInfo,
        node: ast.AST,
        scope: set[str],
        findings: list[Finding],
    ) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (*_FuncNode, ast.Lambda)):
                self._visit(mod, child, scope | _scope_names(child), findings)
                continue
            if isinstance(child, ast.Call):
                self._check_call(mod, child, scope, findings)
            self._visit(mod, child, scope, findings)

    def _check_call(
        self,
        mod: ModuleInfo,
        call: ast.Call,
        scope: set[str],
        findings: list[Finding],
    ) -> None:
        qual = mod.qualify(call.func)
        pos = _AXIS_ARG.get(qual or "")
        if pos is None:
            return
        axis: ast.AST | None = None
        if len(call.args) > pos:
            axis = call.args[pos]
        else:
            axis = next(
                (k.value for k in call.keywords if k.arg == _AXIS_KWARG),
                None,
            )
        if axis is None:
            return
        problem = self._axis_problem(axis, scope)
        if problem:
            findings.append(mod.finding(
                self.id, call,
                f"{qual.rsplit('.', 1)[-1]} axis {problem}; thread the "
                "mesh axis name through a parameter or layout.axis "
                f"(known mesh axes: {sorted(KNOWN_AXES)})",
            ))

    def _axis_problem(self, axis: ast.AST, scope: set[str]) -> str | None:
        """None when the axis expression is acceptable, else a reason."""
        if isinstance(axis, ast.Constant):
            if isinstance(axis.value, str):
                if axis.value in KNOWN_AXES:
                    return None
                return f"names unknown mesh axis '{axis.value}'"
            return f"is a non-string literal {axis.value!r}"
        if isinstance(axis, ast.Name):
            if axis.id in scope:
                return None
            return f"name '{axis.id}' is not bound in any enclosing scope"
        if isinstance(axis, ast.Attribute):
            if "axis" in axis.attr.lower():
                return None
            return (
                f"attribute '.{axis.attr}' does not look like an axis "
                "handle (expected e.g. layout.axis)"
            )
        if isinstance(axis, (ast.Tuple, ast.List)):
            for elt in axis.elts:
                problem = self._axis_problem(elt, scope)
                if problem:
                    return problem
            return None
        return "is a computed expression the linter cannot resolve"


RULE = CollectiveAxisRule()

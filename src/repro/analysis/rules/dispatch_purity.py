"""dispatch-purity: kernel implementation modules are reachable only
through `kernels.ops`.

The repo's correctness story hangs on one dispatch chokepoint: every hot
op (phase, mixer, cutvals, fused layer, cut batch) goes through
`repro.kernels.ops`, so `pallas` / `pallas_interpret` / `xla` selection —
and any future backend — applies identically on every path (DESIGN.md
§2.6). A direct `kernels.ref` (or other impl-module) call silently pins
that call site to one backend; exactly what the two ad-hoc source-contract
tests (formerly in tests/test_engine.py, runtime half in
tests/test_distributed.py::test_engine_ops_dispatch_per_shard) policed for
five functions. This rule is that invariant over the whole tree.

Flags any import that binds a kernel implementation module — at any scope
— outside the allowed zones:

  - `repro.kernels.*` itself (the implementation layer below the
    dispatch boundary: ops.py fans out to the impl modules, and the impl
    modules share helpers like `ref.popcount`),
  - tests/ and benchmarks/ (they compare impls against `ref` on purpose).

`repro.kernels.ops` itself is importable from anywhere — it *is* the
boundary. `repro.kernels.tuning` is likewise not an implementation
module: it is the block-shape tuning state (DESIGN.md §2.7) that
cached-program builders must key on and re-assert, exactly like the ops
implementation — importing it cannot pin a call site to a backend.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ModuleInfo, Project

RULE_ID = "dispatch-purity"

_KERNELS_PKG = "repro.kernels"
_DISPATCH_OK = {"repro.kernels.ops", "repro.kernels", "repro.kernels.tuning"}


def _allowed_module(mod: ModuleInfo) -> bool:
    if mod.modname == _KERNELS_PKG or \
            mod.modname.startswith(_KERNELS_PKG + "."):
        return True
    parts = mod.path.replace("\\", "/").split("/")
    return "tests" in parts or "benchmarks" in parts


def _impl_module(dotted: str) -> bool:
    """True for repro.kernels.<impl> (not ops, not the package itself)."""
    return (
        dotted.startswith(_KERNELS_PKG + ".")
        and dotted not in _DISPATCH_OK
    )


class DispatchPurityRule:
    id = RULE_ID
    summary = (
        "no direct kernels.ref/phase/mixer/cutvals/fused_layer/cutbatch "
        "imports outside repro.kernels, tests, and benchmarks"
    )

    def check(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for mod in project.modules:
            if _allowed_module(mod):
                continue
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        if _impl_module(alias.name):
                            findings.append(self._flag(mod, node, alias.name))
                elif isinstance(node, ast.ImportFrom):
                    if node.level:  # relative: outside repro.kernels already
                        continue
                    base = node.module or ""
                    if _impl_module(base):
                        findings.append(self._flag(mod, node, base))
                    elif base == _KERNELS_PKG:
                        for alias in node.names:
                            dotted = f"{base}.{alias.name}"
                            if _impl_module(dotted):
                                findings.append(
                                    self._flag(mod, node, dotted)
                                )
        return findings

    def _flag(self, mod: ModuleInfo, node: ast.AST, dotted: str) -> Finding:
        return mod.finding(
            self.id, node,
            f"direct kernel-implementation import '{dotted}': call through "
            "repro.kernels.ops so backend dispatch (pallas/xla/interpret) "
            "reaches this site",
        )


RULE = DispatchPurityRule()

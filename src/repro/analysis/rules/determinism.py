"""hot-nondeterminism: wall-clock and stdlib RNG reads in code that must
be replayable.

Two protected regions:

  1. **Traced functions** (jit/shard_map/vmap/scan bodies): `time.*`,
     `datetime.*` clock reads, and stdlib `random.*` execute at *trace*
     time, baking one arbitrary host value into the compiled program —
     every subsequent call replays it silently. Randomness in traced code
     must come from `jax.random` with threaded keys.

  2. **The scheduler's deterministic decision path**
     (`repro.service.scheduler`): bucket choice, admission, and merge
     ordering are replayed from event logs during recalibration; a
     `random.random()` tiebreak or `time.time()`-keyed decision breaks
     replay equivalence. `time.perf_counter*` / `time.monotonic*` stay
     allowed there — the scheduler reads them for *observability*
     (latency accounting), never for decisions, and they never leave the
     metrics structs.

  3. **The observability package** (`repro.obs.*`, DESIGN.md §8):
     tracer/metrics timestamps must flow through the injectable clock
     (`repro.obs.clock.default_clock`) so virtual-clock soaks stay
     bit-deterministic with tracing on. Every `time.*` / `datetime.*`
     read is banned there — including the monotonic clocks the
     scheduler region allows — except inside `repro.obs.clock` itself,
     the one sanctioned wall-clock boundary.

  4. **Measurement paths** (`repro.kernels.tuning`): the autotune
     harness's kernel timings feed the committed tuning cache, so sweeps
     must be replayable/mockable through the injectable clock exactly
     like the obs package — every `time.*` / `datetime.*` read is banned
     (including the monotonic clocks), with `repro.obs.clock` the only
     way in.

jax.random / numpy.random are not flagged: the former is the sanctioned
mechanism, the latter is the tracer-hazard rule's jurisdiction.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ModuleInfo, Project
from repro.analysis.rules.tracer import walk_shallow

RULE_ID = "hot-nondeterminism"

# modules whose *entire* body is a deterministic replay path
DETERMINISTIC_PATHS = ("repro.service.scheduler",)

# the observability package: clock reads allowed only in the clock module
OBS_PACKAGE = "repro.obs"
OBS_CLOCK_MODULE = "repro.obs.clock"

# measurement paths outside repro.obs held to the same injectable-clock
# contract (the autotune timing helper lives here)
MEASUREMENT_PATHS = ("repro.kernels.tuning",)

# observability clocks: monotonic, never used for decisions
_ALLOWED_CLOCKS = {
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
}
_CLOCK_READS = {
    "time.time", "time.time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def _banned(qual: str, in_traced: bool) -> str | None:
    """Reason string when `qual` is a nondeterministic read, else None."""
    if qual == "random" or qual.startswith("random."):
        return f"stdlib RNG '{qual}'"
    if qual in _CLOCK_READS:
        return f"wall-clock read '{qual}'"
    if in_traced and qual.startswith("time.") and qual.count(".") == 1:
        # inside a trace even a monotonic clock is a bake-in hazard
        return f"host clock read '{qual}'"
    return None


class HotNondeterminismRule:
    id = RULE_ID
    summary = (
        "no time/datetime/stdlib-random reads in traced functions or the "
        "scheduler's deterministic pump/admission path"
    )

    def check(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        seen: set[tuple[str, int, int]] = set()

        for fn in project.functions():
            if fn.node not in project.traced:
                continue
            if not isinstance(fn.node, _FuncNode):
                continue
            mod = fn.module
            symbol = (
                fn.qualname[len(mod.modname) + 1:]
                if fn.qualname.startswith(mod.modname + ".")
                else fn.qualname
            )
            for node in walk_shallow(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                qual = mod.qualify(node.func) or ""
                reason = _banned(qual, in_traced=True)
                if reason is None:
                    continue
                key = (mod.path, node.lineno, node.col_offset)
                if key in seen:
                    continue
                seen.add(key)
                findings.append(mod.finding(
                    self.id, node,
                    f"{reason} inside a traced function: the value is "
                    "read once at trace time and baked into the compiled "
                    "program; use jax.random with threaded keys or hoist "
                    "the read to the host side",
                    symbol=symbol,
                ))

        for mod in project.modules:
            if mod.modname not in DETERMINISTIC_PATHS:
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                qual = mod.qualify(node.func) or ""
                if qual in _ALLOWED_CLOCKS:
                    continue
                reason = _banned(qual, in_traced=False)
                if reason is None:
                    continue
                key = (mod.path, node.lineno, node.col_offset)
                if key in seen:
                    continue
                seen.add(key)
                findings.append(mod.finding(
                    self.id, node,
                    f"{reason} in deterministic scheduler path "
                    f"'{mod.modname}': pump/admission decisions must "
                    "replay from event logs; use time.perf_counter for "
                    "observability or thread seeds explicitly",
                ))

        for mod in project.modules:
            in_obs = (mod.modname == OBS_PACKAGE
                      or mod.modname.startswith(OBS_PACKAGE + "."))
            in_measure = mod.modname in MEASUREMENT_PATHS
            if not (in_obs or in_measure) or mod.modname == OBS_CLOCK_MODULE:
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                qual = mod.qualify(node.func) or ""
                # in_traced=True bans even the monotonic clocks: obs
                # timestamps must come through the injectable clock
                reason = _banned(qual, in_traced=True)
                if reason is None:
                    continue
                key = (mod.path, node.lineno, node.col_offset)
                if key in seen:
                    continue
                seen.add(key)
                kind = "observability" if in_obs else "measurement-path"
                why = (
                    "so virtual-clock soaks stay bit-deterministic with "
                    "tracing on (DESIGN.md §8)" if in_obs else
                    "so autotune sweeps are replayable/mockable "
                    "(DESIGN.md §2.7)"
                )
                findings.append(mod.finding(
                    self.id, node,
                    f"{reason} in {kind} module '{mod.modname}': "
                    "timestamps must flow through the "
                    f"injectable clock ('{OBS_CLOCK_MODULE}."
                    f"default_clock') {why}",
                ))
        return findings


RULE = HotNondeterminismRule()

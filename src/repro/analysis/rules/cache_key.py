"""cache-key: cached program builders must key mutable dispatch state.

The PR 5 bug class: `solve_subgraph_batch_program` / `_solve_pool_program`
cached jitted programs keyed only on the QAOA config — but the traced body
dispatches through `kernels.ops`, which reads the *active implementation*
at trace time. Two calls under different `ops.using_implementation`
contexts silently shared one compiled program; the override never reached
the pool/service paths (fixed by hand in PR 5, CHANGES.md).

This rule makes the fix structural. For every builder decorated with
`compat.cached_program` or `functools.lru_cache`:

  1. if the builder (including its nested defs) traces through the
     `kernels.ops` dispatch — a direct `ops.<op>` / `ops.get_implementation`
     reference, or a call-graph path to one (cross-module, through
     `jax.vmap` aliases and `functools.partial`) — then some builder
     parameter must be re-asserted via ``ops.using_implementation(<param>)``
     inside the body. The parameter puts the state in the lru_cache key;
     the with-block makes the lazily-traced body agree with that key.
  2. any ``ops.using_implementation(X)`` inside a cached builder where X
     is *not* a plain builder parameter is flagged outright — e.g.
     ``ops.using_implementation(ops.get_implementation())`` re-reads the
     global at trace time and the cache key cannot see it.

Callers are expected to pass ``ops.get_implementation()`` *at the call
site* (that read happens per call, outside the cache).

The block-shape tuning state (`kernels.tuning`, DESIGN.md §2.7) is
trace-time dispatch state of exactly the same kind, so rule 2 applies to
``tuning.using_state(X)`` as well: inside a cached builder X must be a
plain builder parameter (callers pass ``tuning.state()`` at the call
site). Re-asserting tuning is not *required* — builders that never reach
a Pallas launcher are tuning-insensitive — but a non-param re-assert is
always the same cache-blindness bug.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ModuleInfo, Project

_CACHE_DECORATORS = {
    "repro.compat.cached_program",
    "compat.cached_program",  # snippet projects without repro on the path
    "functools.lru_cache",
    "lru_cache",
}
_USING_IMPL = "repro.kernels.ops.using_implementation"
_USING_TUNE = "repro.kernels.tuning.using_state"

RULE_ID = "cache-key"


def _is_cached_builder(mod: ModuleInfo, node: ast.FunctionDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        qual = mod.qualify(target)
        if qual in _CACHE_DECORATORS:
            return True
    return False


def _param_names(node: ast.FunctionDef) -> set[str]:
    a = node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


class CacheKeyRule:
    id = RULE_ID
    summary = (
        "builders behind compat.cached_program/lru_cache must thread "
        "mutable kernels.ops dispatch state through their key signature"
    )

    def check(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for fn in project.functions():
            node = fn.node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _is_cached_builder(fn.module, node):
                continue
            findings.extend(self._check_builder(project, fn.module, node))
        return findings

    def _check_builder(
        self, project: Project, mod: ModuleInfo, node: ast.FunctionDef
    ) -> list[Finding]:
        params = _param_names(node)
        keyed = False
        findings: list[Finding] = []
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            qual = mod.qualify(sub.func)
            if qual not in (_USING_IMPL, _USING_TUNE):
                continue
            arg = sub.args[0] if sub.args else None
            if isinstance(arg, ast.Name) and arg.id in params:
                if qual == _USING_IMPL:
                    keyed = True
            else:
                fn_name = ("ops.using_implementation()" if qual == _USING_IMPL
                           else "tuning.using_state()")
                findings.append(mod.finding(
                    self.id, sub,
                    f"{fn_name} inside cached builder "
                    f"'{node.name}' must take a builder parameter, not "
                    "an expression the cache key cannot see",
                    symbol=node.name,
                ))
        if not keyed and not findings and \
                project.is_impl_sensitive(mod, node):
            findings.append(mod.finding(
                self.id, node,
                f"cached builder '{node.name}' traces through the "
                "kernels.ops dispatch but does not key the active "
                "implementation: add an `impl` parameter and wrap the "
                "traced body in ops.using_implementation(impl) "
                "(the PR 5 _solve_pool_program bug class)",
                symbol=node.name,
            ))
        return findings


RULE = CacheKeyRule()

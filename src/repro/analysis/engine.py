"""reprolint core: module model, project index, and the analysis driver.

The rule modules (repro/analysis/rules/) consume three layers built here:

  ``ModuleInfo``
      One parsed source file: AST, name-binding table (import aliases and
      ``from``-names resolved to dotted paths), per-line suppression
      comments, and the ``qualify`` resolver that turns an ``ast.Name`` /
      ``ast.Attribute`` chain into a dotted name ("ops.cutvals" →
      "repro.kernels.ops.cutvals").
  ``Project``
      All modules together: a function index (top-level defs, methods and
      nested defs under their dotted path), a name-resolved call graph,
      the *impl-sensitivity* fixpoint (which functions transitively reach
      the mutable `kernels.ops` dispatch state — the cache-key rule's
      input), and the *traced-function* set (functions that run under
      `jax.jit` / `compat.shard_map` / `vmap` / `grad` / `lax.scan` — the
      tracer-hazard and nondeterminism rules' input).
  ``run`` / ``run_on_sources``
      The driver: parse, build the project, apply the requested rules,
      drop suppressed findings, split the rest against the baseline.

Static analysis over Python is necessarily approximate; every
over-approximation here errs toward *fewer* findings (attribute loads
drop taint, cross-module taint is not propagated) so the tool stays
quiet enough to run in tier-1. Escapes for deliberate exceptions:

  ``# reprolint: disable=<rule>[,<rule>...]``       (finding's own line)
  ``# reprolint: disable-file=<rule>[,<rule>...]``  (anywhere in the file)

and the checked-in baseline (``baseline.json`` next to this package) for
grandfathered findings — matched by content fingerprint (rule + path +
enclosing symbol + normalized source line), so findings survive
unrelated line churn but die with the code they point at.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import os
import re
import tokenize
from typing import Iterable, Sequence

# ---------------------------------------------------------------- findings --
_SUPPRESS_RE = re.compile(
    r"reprolint:\s*(disable(?:-file)?)\s*=\s*([\w\-, ]+)"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # as given to the analyzer (repo-relative in CI)
    line: int
    col: int
    message: str
    symbol: str = ""  # dotted enclosing-def chain, "" at module level
    line_text: str = ""  # stripped source line, for the fingerprint

    @property
    def fingerprint(self) -> str:
        """Content-based identity for baselining: stable under line moves,
        invalidated when the offending code itself changes."""
        norm_path = self.path.replace(os.sep, "/")
        # anchor on the tail of the path so absolute vs relative
        # invocations fingerprint identically
        m = re.search(r"(?:^|/)(src/.*|tests/.*|benchmarks/.*)$", norm_path)
        tail = m.group(1) if m else norm_path
        key = "|".join(
            (self.rule, tail, self.symbol, " ".join(self.line_text.split()))
        )
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def as_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "symbol": self.symbol,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}{sym}"


# ------------------------------------------------------------ module model --
def _module_name(path: str) -> str:
    """Dotted module name from a path: anchored at the last `repro` package
    component when present (src/repro/core/qaoa.py → repro.core.qaoa),
    else the path itself dotted (fixture snippets in tests)."""
    norm = path.replace(os.sep, "/")
    parts = [p for p in norm.split("/") if p]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][: -len(".py")]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return ".".join(parts)


class ModuleInfo:
    """One parsed source file with its binding table and suppressions."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.modname = _module_name(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # name → dotted target, merged over every Import/ImportFrom in the
        # file regardless of scope (good enough for a linter; later imports
        # shadow earlier ones, as at runtime)
        self.bindings: dict[str, str] = {}
        self._collect_bindings()
        self.line_suppressions: dict[int, set[str]] = {}
        self.file_suppressions: set[str] = set()
        self._collect_suppressions()

    # -- imports --
    def _collect_bindings(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name.split(".")[0]
                    target = alias.name if alias.asname else alias.name.split(".")[0]
                    self.bindings[name] = target
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative import: resolve against modname
                    base = self.modname.split(".")
                    base = base[: len(base) - node.level]
                    mod = ".".join(base + ([node.module] if node.module else []))
                else:
                    mod = node.module or ""
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    name = alias.asname or alias.name
                    self.bindings[name] = f"{mod}.{alias.name}" if mod else alias.name

    # -- suppressions --
    def _collect_suppressions(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            comments = [
                (tok.start[0], tok.string)
                for tok in tokens
                if tok.type == tokenize.COMMENT
            ]
        except (tokenize.TokenError, IndentationError):  # partial files
            comments = [
                (i + 1, line)
                for i, line in enumerate(self.lines)
                if "#" in line
            ]
        for lineno, text in comments:
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
            if m.group(1) == "disable-file":
                self.file_suppressions |= rules
            else:
                self.line_suppressions.setdefault(lineno, set()).update(rules)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_suppressions:
            return True
        return rule in self.line_suppressions.get(line, set())

    # -- name resolution --
    def qualify(self, node: ast.AST) -> str | None:
        """Dotted name of a Name/Attribute chain, import-resolved at the
        root ("qaoa_mod.solve_subgraph_batch" →
        "repro.core.qaoa.solve_subgraph_batch"). None for anything that is
        not a plain dotted chain."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.bindings.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def finding(
        self, rule: str, node: ast.AST, message: str, symbol: str = ""
    ) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        text = self.lines[line - 1].strip() if 0 < line <= len(self.lines) else ""
        return Finding(rule, self.path, line, col, message, symbol, text)


# ----------------------------------------------------------- project index --
_OPS_MODULE = "repro.kernels.ops"
# reads of the mutable dispatch state: calling any dispatched op traces
# through `get_implementation()`, and calling it directly reads the state
# outright. `using_implementation` / `set_implementation` are the keying /
# override mechanisms, not reads.
_OPS_STATE_READS = frozenset(
    {
        "cutvals", "cutvals_at", "apply_phase", "apply_mixer",
        "apply_mixer_bits", "apply_layer", "expectation", "cut_batch_dense",
        "get_implementation", "_IMPL",
    }
)

# wrapper → index/keyword of the traced-callable argument(s)
_TRACING_WRAPPERS: dict[str, tuple] = {
    "jax.jit": (0, "fun"),
    "repro.compat.jit": (0, "f"),
    "jax.vmap": (0, "fun"),
    "jax.pmap": (0, "fun"),
    "jax.grad": (0, "fun"),
    "jax.value_and_grad": (0, "fun"),
    "jax.checkpoint": (0, "fun"),
    "jax.remat": (0, "fun"),
    "repro.compat.shard_map": (0, "f"),
    "jax.shard_map": (0, "f"),
    "jax.experimental.shard_map.shard_map": (0, "f"),
    "jax.lax.scan": (0, "f"),
    "jax.lax.map": (0, "f"),
    "jax.lax.while_loop": (0, 1, "cond_fun", "body_fun"),
    "jax.lax.fori_loop": (2, "body_fun"),
    "jax.lax.cond": (1, 2, "true_fun", "false_fun"),
    "jax.lax.switch": tuple(),  # branches are positional varargs; skip
    "functools.partial": tuple(),  # unwrapped explicitly below
}

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass
class FnInfo:
    qualname: str  # module.dotted.path
    module: ModuleInfo
    node: ast.AST  # FunctionDef / Lambda
    outer: str  # qualname of the outermost enclosing def (itself if top)


class Project:
    """All analyzed modules plus the cross-module facts rules share."""

    def __init__(self, modules: Sequence[ModuleInfo]):
        self.modules = list(modules)
        self.fn_index: dict[str, FnInfo] = {}
        # per-module: bare name → [qualnames] (any scope), for same-module
        # bare-call resolution
        self._by_name: dict[str, dict[str, list[str]]] = {}
        self._fn_of_node: dict[ast.AST, FnInfo] = {}
        for mod in self.modules:
            self._index_module(mod)
        # module-level aliases (`batch = jax.vmap(solve, ...)`): alias
        # qualname → project functions its defining expression references
        self.alias_deps: dict[str, set[str]] = {}
        for mod in self.modules:
            self._index_aliases(mod)
        self.impl_sensitive: set[str] = self._impl_sensitivity_fixpoint()
        self.traced: set[ast.AST] = self._traced_closure()

    # -- indexing --
    def _index_module(self, mod: ModuleInfo) -> None:
        by_name = self._by_name.setdefault(mod.modname, {})

        def visit(node: ast.AST, prefix: str, outer: str | None):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, _FuncNode):
                    qual = f"{prefix}.{child.name}"
                    info = FnInfo(qual, mod, child, outer or qual)
                    self.fn_index[qual] = info
                    self._fn_of_node[child] = info
                    by_name.setdefault(child.name, []).append(qual)
                    visit(child, qual, outer or qual)
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}.{child.name}", outer)
                else:
                    visit(child, prefix, outer)

        visit(mod.tree, mod.modname, None)

    def _index_aliases(self, mod: ModuleInfo) -> None:
        by_name = self._by_name.setdefault(mod.modname, {})
        for stmt in mod.tree.body:
            if not isinstance(stmt, ast.Assign):
                continue
            deps = set()
            for n in ast.walk(stmt.value):
                if not isinstance(n, (ast.Name, ast.Attribute)):
                    continue
                q = mod.qualify(n)
                if q in self.fn_index:
                    deps.add(q)
                elif isinstance(n, ast.Name):
                    # same-module top-level def referenced bare
                    # (`batch = jax.vmap(solve, ...)`) — imports don't
                    # bind it, so qualify() can't
                    local = f"{mod.modname}.{n.id}"
                    if local in self.fn_index:
                        deps.add(local)
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    qual = f"{mod.modname}.{t.id}"
                    self.alias_deps[qual] = deps
                    by_name.setdefault(t.id, []).append(qual)

    def functions(self) -> Iterable[FnInfo]:
        return self.fn_index.values()

    # -- impl sensitivity (cache-key rule input) --
    def _direct_ops_read(self, mod: ModuleInfo, fn_node: ast.AST) -> bool:
        for node in ast.walk(fn_node):
            if isinstance(node, (ast.Attribute, ast.Name)):
                qual = mod.qualify(node)
                if (
                    qual
                    and qual.startswith(_OPS_MODULE + ".")
                    and qual[len(_OPS_MODULE) + 1:] in _OPS_STATE_READS
                ):
                    return True
        return False

    def _bare_name_targets(
        self, mod: ModuleInfo, name: str, outer: str | None
    ) -> list[str]:
        """Same-module functions a bare name can legally refer to from a
        scope whose outermost enclosing def is `outer`: top-level defs,
        module-level aliases, and nested defs of the *same* outer function.
        (Without the outer filter, a local variable `run` in one builder
        would alias the unrelated nested def `run` of another.)"""
        out = []
        for q in self._by_name.get(mod.modname, {}).get(name, []):
            if q == f"{mod.modname}.{name}" or q in self.alias_deps:
                out.append(q)
            else:
                info = self.fn_index.get(q)
                if info is not None and outer is not None and \
                        info.outer == outer:
                    out.append(q)
        return out

    def _call_targets(
        self, mod: ModuleInfo, fn_node: ast.AST, outer: str | None = None
    ) -> set[str]:
        """Qualified names this function's body references that resolve to
        indexed project functions (calls and bare-name mentions — a
        function passed to vmap/partial is reached as surely as one
        called)."""
        out: set[str] = set()
        if outer is None:
            info = self._fn_of_node.get(fn_node)
            outer = info.outer if info is not None else None
        for node in ast.walk(fn_node):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            qual = mod.qualify(node)
            if qual is None:
                continue
            if qual in self.fn_index or qual in self.alias_deps:
                out.add(qual)
            elif isinstance(node, ast.Name):
                out.update(self._bare_name_targets(mod, node.id, outer))
        return out

    def _impl_sensitivity_fixpoint(self) -> set[str]:
        sensitive: set[str] = set()
        deps: dict[str, set[str]] = dict(self.alias_deps)
        for fn in self.functions():
            # seed: the ops dispatch entry points themselves, when ops.py
            # is part of the analyzed tree (their bodies read the module
            # state through bare names this walker cannot see)
            name = fn.qualname.rsplit(".", 1)[-1]
            if (
                fn.qualname == f"{_OPS_MODULE}.{name}"
                and name in _OPS_STATE_READS
            ):
                sensitive.add(fn.qualname)
        for fn in self.functions():
            # nested defs are walked as part of their own entry too, so a
            # nested direct read marks both the inner fn and (via the call
            # edge below) anything that references it
            if self._direct_ops_read(fn.module, fn.node):
                sensitive.add(fn.qualname)
            deps[fn.qualname] = self._call_targets(fn.module, fn.node)
        changed = True
        while changed:
            changed = False
            for name, d in deps.items():
                if name not in sensitive and d & sensitive:
                    sensitive.add(name)
                    changed = True
        return sensitive

    def is_impl_sensitive(self, mod: ModuleInfo, fn_node: ast.AST) -> bool:
        """Does this function (including nested defs) reach the mutable
        `kernels.ops` dispatch state — directly or through project calls?"""
        if self._direct_ops_read(mod, fn_node):
            return True
        return bool(self._call_targets(mod, fn_node) & self.impl_sensitive)

    # -- traced functions (tracer-hazard / nondeterminism rules input) --
    def _resolve_fn_arg(
        self, mod: ModuleInfo, arg: ast.AST, outer: str | None
    ) -> list[ast.AST]:
        """Function node(s) an argument to a tracing wrapper refers to."""
        if isinstance(arg, ast.Lambda):
            return [arg]
        if isinstance(arg, ast.Call):  # functools.partial(f, ...) etc.
            qual = mod.qualify(arg.func)
            if qual in ("functools.partial", "partial") and arg.args:
                return self._resolve_fn_arg(mod, arg.args[0], outer)
            return []
        if isinstance(arg, (ast.Name, ast.Attribute)):
            qual = mod.qualify(arg)
            out = []
            if qual in self.fn_index:
                out.append(self.fn_index[qual].node)
            elif isinstance(arg, ast.Name):
                for q in self._bare_name_targets(mod, arg.id, outer):
                    if q in self.fn_index:
                        out.append(self.fn_index[q].node)
            return out
        return []

    def _traced_roots(self) -> set[ast.AST]:
        roots: set[ast.AST] = set()

        def scan(mod: ModuleInfo, node: ast.AST, outer: str | None):
            for child in ast.iter_child_nodes(node):
                child_outer = outer
                if isinstance(child, _FuncNode):
                    info = self._fn_of_node.get(child)
                    child_outer = info.outer if info is not None else outer
                    for dec in child.decorator_list:
                        target = dec.func if isinstance(dec, ast.Call) else dec
                        qual = mod.qualify(target)
                        if qual in ("functools.partial", "partial") and \
                                isinstance(dec, ast.Call) and dec.args:
                            qual = mod.qualify(dec.args[0])
                        if qual in _TRACING_WRAPPERS and \
                                qual != "functools.partial":
                            roots.add(child)
                elif isinstance(child, ast.Call):
                    qual = mod.qualify(child.func)
                    spec = _TRACING_WRAPPERS.get(qual or "")
                    if spec:
                        for sel in spec:
                            arg = None
                            if isinstance(sel, int) and sel < len(child.args):
                                arg = child.args[sel]
                            elif isinstance(sel, str):
                                arg = next(
                                    (k.value for k in child.keywords
                                     if k.arg == sel),
                                    None,
                                )
                            if arg is not None:
                                roots.update(
                                    self._resolve_fn_arg(mod, arg, outer)
                                )
                scan(mod, child, child_outer)

        for mod in self.modules:
            scan(mod, mod.tree, None)
        return roots

    def _traced_closure(self) -> set[ast.AST]:
        """Traced roots + lexically nested defs + same-module functions
        they reference by name (transitively)."""
        traced = self._traced_roots()
        node_to_fn = {fn.node: fn for fn in self.functions()}
        changed = True
        while changed:
            changed = False
            for node in list(traced):
                # nested defs run under the same trace
                for sub in ast.walk(node):
                    if sub is not node and isinstance(sub, _FuncNode) \
                            and sub not in traced:
                        traced.add(sub)
                        changed = True
                fn = node_to_fn.get(node)
                if fn is None:
                    continue
                for qual in self._call_targets(fn.module, fn.node):
                    # an alias reference pulls in the functions behind it
                    quals = (
                        self.alias_deps[qual]
                        if qual in self.alias_deps
                        else (qual,)
                    )
                    for q in quals:
                        tnode = self.fn_index[q].node
                        if tnode not in traced:
                            traced.add(tnode)
                            changed = True
        return traced

    def module_of(self, node: ast.AST) -> ModuleInfo | None:
        for fn in self.functions():
            if fn.node is node:
                return fn.module
        return None


# ----------------------------------------------------------------- baseline --
def load_baseline(path: str | None) -> set[str]:
    if not path or not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return {entry["fingerprint"] for entry in data.get("findings", [])}


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    data = {
        "version": 1,
        "findings": [
            {
                "fingerprint": f.fingerprint,
                "rule": f.rule,
                "path": f.path.replace(os.sep, "/"),
                "symbol": f.symbol,
                "message": f.message,
            }
            for f in sorted(findings, key=lambda f: (f.path, f.line))
        ],
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


# ------------------------------------------------------------------- driver --
@dataclasses.dataclass
class Report:
    findings: list[Finding]  # actionable: not suppressed, not baselined
    suppressed: int
    baselined: int
    files: int

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def as_dict(self) -> dict:
        return {
            "version": 1,
            "files": self.files,
            "suppressed": self.suppressed,
            "baselined": self.baselined,
            "findings": [f.as_dict() for f in self.findings],
        }


def collect_files(paths: Sequence[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs if d not in ("__pycache__", ".git")
            )
            out.extend(
                os.path.join(root, f) for f in sorted(files)
                if f.endswith(".py")
            )
    return out


def run_on_sources(
    sources: dict[str, str],
    rules: Sequence[str] | None = None,
    baseline: set[str] | None = None,
) -> Report:
    """Analyze in-memory sources ({path: source}). The unit-test entry
    point — identical semantics to `run` minus the filesystem walk."""
    from repro.analysis.rules import get_rules

    modules = []
    for path, src in sources.items():
        modules.append(ModuleInfo(path, src))
    project = Project(modules)

    raw: list[Finding] = []
    for rule in get_rules(rules):
        raw.extend(rule.check(project))

    by_mod = {m.path: m for m in modules}
    kept, suppressed, baselined = [], 0, 0
    baseline = baseline or set()
    for f in raw:
        mod = by_mod.get(f.path)
        if mod is not None and mod.suppressed(f.rule, f.line):
            suppressed += 1
        elif f.fingerprint in baseline:
            baselined += 1
        else:
            kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return Report(kept, suppressed, baselined, len(modules))


def run(
    paths: Sequence[str],
    rules: Sequence[str] | None = None,
    baseline_path: str | None = None,
) -> Report:
    files = collect_files(paths)
    sources = {}
    for path in files:
        with open(path, encoding="utf-8") as f:
            sources[path] = f.read()
    return run_on_sources(
        sources, rules=rules, baseline=load_baseline(baseline_path)
    )

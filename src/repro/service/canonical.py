"""Canonical graph hashing for the result cache (DESIGN.md §6.3).

Two `Graph` instances that differ only by edge-list padding, edge order,
duplicate/zero-weight edges, or a vertex relabeling should map to the same
cache key. The canonical form is a degree-ordered relabeling computed by
Weisfeiler-Leman color refinement over the weighted adjacency structure,
followed by bounded individualization when refinement leaves ties:

  1. normalize the edge list (strip padding rows via ``n_edges``, drop
     self-loops and zero-weight edges, orient u < v, coalesce parallel
     edges by summing weights) — this is what makes the key
     padding-invariant;
  2. refine vertex colors to a stable partition, where a vertex's
     signature is (its color, the sorted multiset of (edge weight,
     neighbor color)) — signatures are ranked by sorted order, so the
     refinement is relabeling-invariant by construction;
  3. while non-singleton color classes remain, individualize the first
     vertex of the smallest-rank class and re-refine. When the tied
     vertices are automorphic (the overwhelmingly common case on the
     random weighted instances this service sees) every choice yields the
     identical certificate; WL-equivalent non-automorphic ties (e.g.
     strongly regular graphs) can split isomorphic inputs into different
     keys — a cache *miss*, never a wrong answer, because the cache
     re-scores every hit against the querying graph (§6.3).

The certificate hashed is (n, sorted relabeled weighted edge list), via
sha256. `CanonicalForm.perm` maps original vertex → canonical index, which
is what lets the cache store assignments in canonical vertex order and
replay them onto any relabeled instance.

Above `_EXACT_THRESHOLD` vertices, steps 2-3 switch to a vectorized
64-bit multiset-hash refinement without individualization — O(|E|) numpy
work per round on the admission path instead of per-vertex Python tuple
sorting; hash collisions or residual ties only weaken the key (a miss),
never the answer.
"""

from __future__ import annotations

import hashlib
from typing import NamedTuple

import numpy as np

from repro.core.graph import Graph, Problem, as_problem


_MAX_INDIVIDUALIZE = 64


class CanonicalForm(NamedTuple):
    key: str  # sha256 hex digest of the canonical certificate
    perm: np.ndarray  # (n,) int32: original vertex -> canonical index
    n: int
    n_edges: int  # normalized (deduplicated) edge count


def normalized_edges(graph: Graph) -> tuple[np.ndarray, np.ndarray]:
    """Padding-free, order-free edge list: (E, 2) with u < v, coalesced."""
    e = np.asarray(graph.edges)[: graph.n_edges].astype(np.int64)
    w = np.asarray(graph.weights)[: graph.n_edges].astype(np.float64)
    live = (e[:, 0] != e[:, 1]) & (w != 0.0)
    e, w = e[live], w[live]
    u = np.minimum(e[:, 0], e[:, 1])
    v = np.maximum(e[:, 0], e[:, 1])
    # coalesce parallel edges: sum weights per (u, v) pair
    flat = u * graph.n + v
    order = np.argsort(flat, kind="stable")
    flat, u, v, w = flat[order], u[order], v[order], w[order]
    uniq, start = np.unique(flat, return_index=True)
    wsum = np.add.reduceat(w, start) if w.size else w
    uv = np.stack([uniq // graph.n, uniq % graph.n], axis=1)
    keep = wsum != 0.0  # coalesced ±w pairs cancel
    return uv[keep].astype(np.int64), wsum[keep].astype(np.float64)


# above this vertex count, refinement switches to the vectorized hashed
# form and skips individualization: admission-path latency stays O(|E|)
# numpy work instead of per-vertex Python tuple sorting
_EXACT_THRESHOLD = 256


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over a uint64 array (wrapping arithmetic)."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def _refine_hashed(
    n: int, uv: np.ndarray, w: np.ndarray, colors: np.ndarray
) -> np.ndarray:
    """Vectorized WL refinement for large graphs.

    A vertex's signature is a 64-bit multiset hash: the sum (wrapping,
    hence order-free) of mixed (neighbor color, edge weight) contributions,
    combined with its own color. Ranks come from `np.unique`'s sort of the
    signature *values*, so the result is relabeling-invariant up to hash
    collisions — which only coarsen the partition and weaken the cache
    key; the cache's re-score-on-hit keeps that safe.
    """
    eu, ev = uv[:, 0].astype(np.int64), uv[:, 1].astype(np.int64)
    w_q = _mix64(np.round(w * 1e6).astype(np.int64).astype(np.uint64))
    n_colors = len(np.unique(colors))
    while True:
        hc = _mix64(colors.astype(np.uint64))
        acc = np.zeros(n, dtype=np.uint64)
        np.add.at(acc, eu, _mix64(hc[ev] ^ w_q))
        np.add.at(acc, ev, _mix64(hc[eu] ^ w_q))
        _, colors = np.unique(_mix64(hc ^ acc), return_inverse=True)
        if len(np.unique(colors)) == n_colors:
            return colors
        n_colors = len(np.unique(colors))


def _refine(n: int, adj: list, colors: np.ndarray) -> np.ndarray:
    """WL color refinement to a fixed point. Signature ranks are assigned
    by sorted signature order, so the result is relabeling-invariant."""
    n_colors = len(np.unique(colors))
    while True:
        sigs = []
        for vtx in range(n):
            nbr = tuple(sorted((wt, int(colors[o])) for o, wt in adj[vtx]))
            sigs.append((int(colors[vtx]), nbr))
        ranked = {s: i for i, s in enumerate(sorted(set(sigs)))}
        colors = np.asarray([ranked[s] for s in sigs], dtype=np.int64)
        if len(ranked) == n_colors:
            return colors
        n_colors = len(ranked)


def canonical_form(graph: Graph | Problem) -> CanonicalForm:
    """Compute the canonical relabeling + cache key of a graph or problem.

    A `Problem`'s linear terms and offset fold into the key: initial WL
    colors come from the ranks of the (quantized) per-vertex linear
    coefficients — relabeling-invariant, since ranks depend only on
    values — and the certificate appends the relabeled linear vector and
    the offset. Two QUBOs sharing a quadratic but differing in linear
    terms therefore cannot collide. Both additions are gated on the terms
    being nonzero, so a plain `Graph` (and the zero-linear `Problem`)
    hashes to the byte-identical pre-QUBO key.
    """
    lin = None
    offset = 0.0
    if isinstance(graph, Problem):
        prob = graph
        graph = prob.graph
        lin_arr = np.asarray(prob.linear, dtype=np.float64)
        offset = float(prob.offset)
        if np.any(lin_arr != 0.0):
            lin = lin_arr
    n = graph.n
    uv, w = normalized_edges(graph)

    colors0 = np.zeros(n, dtype=np.int64)
    if lin is not None:
        # rank-of-value initial coloring: vertices with distinct linear
        # coefficients can never be confused, and the refinement keeps
        # its relabeling invariance (ranks are label-free)
        _, colors0 = np.unique(np.round(lin * 1e6).astype(np.int64),
                               return_inverse=True)
        colors0 = colors0.astype(np.int64)

    if n > _EXACT_THRESHOLD:
        # large graphs: vectorized hashed refinement, no individualization
        # (admission latency over key strength; misses stay correct)
        colors = _refine_hashed(n, uv, w, colors0)
    else:
        adj: list = [[] for _ in range(n)]
        for (u, v), wt in zip(uv, w.round(9)):
            adj[u].append((v, float(wt)))
            adj[v].append((u, float(wt)))

        colors = _refine(n, adj, colors0)
        # individualization: split remaining ties one vertex at a time.
        # Pick the lowest-index vertex of the smallest-rank non-singleton
        # class — deterministic, and certificate-invariant whenever the
        # tie is an automorphism (any member gives the same canonical
        # graph). Bounded: residual ties fall through to the argsort's
        # stable index tie-break — a weaker, best-effort key that can
        # only cost cache hits, not correctness (§6.3 re-scores every
        # hit).
        rounds = 0
        while len(np.unique(colors)) < n and rounds < _MAX_INDIVIDUALIZE:
            counts = np.bincount(colors)
            cls = int(np.flatnonzero(counts > 1)[0])
            pick = int(np.flatnonzero(colors == cls)[0])
            colors = colors * 2
            colors[pick] -= 1
            colors = _refine(n, adj, colors)
            rounds += 1

    # colors are now a permutation rank (up to residual ties, broken by
    # original index via the stable sort); perm[orig] = canonical index
    perm = np.empty(n, dtype=np.int32)
    perm[np.argsort(colors, kind="stable")] = np.arange(n, dtype=np.int32)

    cu = perm[uv[:, 0]]
    cv = perm[uv[:, 1]]
    lo = np.minimum(cu, cv)
    hi = np.maximum(cu, cv)
    order = np.lexsort((hi, lo))
    cert = hashlib.sha256()
    cert.update(np.int64(n).tobytes())
    cert.update(lo[order].astype(np.int64).tobytes())
    cert.update(hi[order].astype(np.int64).tobytes())
    cert.update(w[order].round(6).astype(np.float64).tobytes())
    if lin is not None:
        # linear terms in *canonical* vertex order + the constant offset;
        # appended only when nonzero so the zero path stays byte-identical
        lin_canon = np.empty(n, dtype=np.float64)
        lin_canon[perm] = lin
        cert.update(b"lin")
        cert.update(lin_canon.round(6).tobytes())
        cert.update(np.float64(offset).tobytes())
    return CanonicalForm(
        key=cert.hexdigest(), perm=perm, n=n, n_edges=int(uv.shape[0])
    )


def canonical_key(graph: Graph | Problem) -> str:
    return canonical_form(graph).key

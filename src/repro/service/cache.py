"""Canonical-graph result cache with LRU eviction (DESIGN.md §6.3).

Entries are keyed on the canonical graph hash and store the best-known
assignment in *canonical vertex order*, so a hit replays onto any
relabeled-but-isomorphic instance through the querying graph's own
canonical permutation. Every hit is re-scored against the querying
graph/problem with the *full* objective (`problem_value` — quadratic +
linear + offset, O(|E| + n)) before being served: a hash collision or a
WL-equivalent non-isomorphic twin then degrades to a miss instead of a
wrong answer.

Entries also carry the quality score of the knob plan that produced them
(planner.py): a request is only served from cache when the cached result
was computed at equal-or-better quality, so a tight-deadline/cheap-knob
result never masquerades as a high-accuracy one.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Problem, as_problem, problem_value
from repro.service.canonical import CanonicalForm, canonical_form


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    quality_misses: int = 0  # key present but cached quality too low
    verify_failures: int = 0  # key matched, replayed cut did not
    evictions: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_ratio(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "quality_misses": self.quality_misses,
            "verify_failures": self.verify_failures,
            "evictions": self.evictions,
            "hit_ratio": round(self.hit_ratio, 4),
        }


@dataclasses.dataclass
class _Entry:
    canon_assignment: np.ndarray  # (n,) int8, canonical vertex order
    cut: float
    quality: float  # planner quality score of the producing knobs


class ResultCache:
    """Bounded LRU map: canonical graph key → best-known cut."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(
        self,
        graph: Graph | Problem,
        form: CanonicalForm | None = None,
        min_quality: float = 0.0,
    ) -> tuple[np.ndarray, float] | None:
        """Return (assignment, value) replayed onto `graph`'s labels, or None.

        `min_quality` gates stale-quality hits; `form` skips recomputing
        the canonical form when the caller already has it. The hit is
        re-scored with the *full* objective of the querying problem
        (quadratic + linear + offset), not `cut_value` alone — two QUBOs
        differing only in linear terms hash differently, but the re-score
        guard must still catch any residual collision on the linear part.
        """
        prob = as_problem(graph)
        form = form or canonical_form(graph)
        entry = self._entries.get(form.key)
        if entry is None or entry.canon_assignment.shape[0] != prob.n:
            self.stats.misses += 1
            return None
        if entry.quality < min_quality:
            self.stats.misses += 1
            self.stats.quality_misses += 1
            return None
        assignment = entry.canon_assignment[form.perm]
        replayed = float(problem_value(prob, jnp.asarray(assignment)))
        if abs(replayed - entry.cut) > 1e-2 * max(1.0, abs(entry.cut)):
            # collision / WL-twin: same key, different graph — refuse
            self.stats.misses += 1
            self.stats.verify_failures += 1
            return None
        self._entries.move_to_end(form.key)
        self.stats.hits += 1
        return assignment, replayed

    def store(
        self,
        graph: Graph | Problem,
        assignment: np.ndarray,
        cut: float,
        quality: float = 0.0,
        form: CanonicalForm | None = None,
    ) -> None:
        """Insert/upgrade the entry for `graph`. ``cut`` is the full
        objective value (for a `Problem`, including linear terms and
        offset). Keeps the better value at the higher quality mark; never
        downgrades an existing entry."""
        prob = as_problem(graph)
        form = form or canonical_form(graph)
        canon = np.empty(prob.n, dtype=np.int8)
        canon[form.perm] = np.asarray(assignment, dtype=np.int8)
        prev = self._entries.get(form.key)
        if prev is not None and prev.cut >= cut and prev.quality >= quality:
            self._entries.move_to_end(form.key)
            return
        if prev is not None and prev.cut > cut:
            canon, cut = prev.canon_assignment, prev.cut
        quality = max(quality, prev.quality if prev else quality)
        self._entries[form.key] = _Entry(canon, float(cut), float(quality))
        self._entries.move_to_end(form.key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def keys(self):
        return list(self._entries.keys())

"""Seed-stable request-mix generation shared by the service drivers
(`launch/serve_maxcut.py`, `benchmarks/service_bench.py`): varied-size
Erdős-Rényi instances with a controllable fraction of vertex-relabeled
repeats, the traffic shape that exercises the canonical-graph cache
(DESIGN.md §6.3).

Production-shaped traffic for the §6.6 SLA soak lives here too: an
*open-loop* arrival process (`arrival_trace` — Poisson base rate, burst
episodes, the skewed `tenant_mix` assignment, and a per-request
deadline / accuracy-floor mix) plus the two drivers that replay it
against a `SolveService`. `run_soak_virtual` advances an injectable
`VirtualClock` a fixed virtual cost per pump tick, so a soak of
thousands of requests is bit-deterministic and replayable (tier-1:
tests/test_service_sla.py); `run_soak_wall` replays the same trace in
wall-clock time for `benchmarks/service_bench.py --sla-soak`. Both are
open-loop: arrivals are submitted when the trace says so, never gated on
the service keeping up — and a request's deadline is anchored at its
*arrival* time, so budget burned waiting to be noticed is burned."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.graph import Graph, Problem
from repro.service.planner import SLA


def relabel(graph: Graph, perm: np.ndarray) -> Graph:
    """The same instance under a vertex permutation (isomorphic copy)."""
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    return Graph.from_edges(graph.n, perm[e], w)


def relabel_problem(prob: Problem, perm: np.ndarray) -> Problem:
    """The same `Problem` under a vertex permutation: the quadratic edges
    and the per-vertex linear terms move together (vertex v → perm[v])."""
    lin = np.zeros(prob.n, dtype=np.float32)
    lin[perm] = np.asarray(prob.linear, dtype=np.float32)
    return dataclasses.replace(
        prob, graph=relabel(prob.graph, perm), linear=np.asarray(lin)
    )


def _generate(n: int, p: float, seed: int, weights: str) -> Graph:
    """One seed-stable instance; ``weights``: "unit" | "uniform" | "spin"."""
    if weights == "uniform":
        return Graph.erdos_renyi_weighted(n, p, seed=seed)
    if weights == "spin":
        return Graph.spin_glass(n, p, seed=seed)
    if weights != "unit":
        raise ValueError(f"unknown weight family: {weights!r}")
    return Graph.erdos_renyi(n, p, seed=seed)


def request_mix(
    load: int,
    n_range: tuple,
    p: float,
    repeat_frac: float,
    seed: int,
    weights: str = "unit",
) -> list:
    """Seed-stable graphs for one offered load; ~repeat_frac of them are
    vertex-relabeled copies of earlier ones (isomorphic, cache-hittable).
    ``weights`` selects the instance family: unit-weight ER (default),
    uniform-weight ER, or ±1 spin glass."""
    rng = np.random.default_rng(seed)
    fresh, graphs = [], []
    for _ in range(load):
        if fresh and rng.random() < repeat_frac:
            g0 = fresh[int(rng.integers(len(fresh)))]
            perm = rng.permutation(g0.n).astype(np.int32)
            graphs.append(relabel(g0, perm))
        else:
            n = int(rng.integers(n_range[0], n_range[1] + 1))
            g = _generate(n, p, int(rng.integers(1 << 30)), weights)
            fresh.append(g)
            graphs.append(g)
    return graphs


def problem_mix(
    load: int,
    n_range: tuple,
    p: float,
    repeat_frac: float,
    seed: int,
    problem: str = "maxcut",
    weights: str = "unit",
) -> list:
    """Seed-stable `Problem` requests for one offered load.

    ``problem``: "maxcut" returns plain graphs (exactly `request_mix`);
    "mis" wraps each topology in the penalty-QUBO MIS encoding; "qubo"
    draws a random QUBO (graph quadratic + N(0,1) linear terms). Repeats
    are vertex-relabeled copies — for problems, the linear terms permute
    with the vertices, so the canonical cache should still hit."""
    if problem == "maxcut":
        return request_mix(load, n_range, p, repeat_frac, seed, weights)
    rng = np.random.default_rng(seed)
    fresh, probs = [], []
    for _ in range(load):
        if fresh and rng.random() < repeat_frac:
            p0 = fresh[int(rng.integers(len(fresh)))]
            perm = rng.permutation(p0.n).astype(np.int32)
            probs.append(relabel_problem(p0, perm))
        else:
            n = int(rng.integers(n_range[0], n_range[1] + 1))
            g = _generate(n, p, int(rng.integers(1 << 30)), weights)
            if problem == "mis":
                pr = Problem.mis(g)
            elif problem == "qubo":
                e = np.asarray(g.edges)[: g.n_edges]
                q = np.asarray(g.weights)[: g.n_edges]
                lin = rng.normal(size=n).astype(np.float32)
                pr = Problem.qubo(n, e, q, linear=lin)
            else:
                raise ValueError(f"unknown problem family: {problem!r}")
            fresh.append(pr)
            probs.append(pr)
    return probs


def tenant_mix(load: int, tenants: int, seed: int) -> list:
    """Seed-stable tenant labels (``"t0"``…) for one offered load.

    A *skewed* assignment — tenant ``t0`` claims roughly half the
    requests, the rest split evenly — because uniform traffic never
    exercises the scheduler's fairness/quota path (DESIGN.md §6.5).
    With one tenant everything is ``"t0"``.
    """
    if tenants < 1:
        raise ValueError(f"tenants must be >= 1: {tenants}")
    rng = np.random.default_rng(seed + 0x7E7A)
    labels = []
    for _ in range(load):
        if tenants == 1 or rng.random() < 0.5:
            labels.append("t0")
        else:
            labels.append(f"t{int(rng.integers(1, tenants))}")
    return labels


# ---------------------------------------------------- §6.6 open-loop soak --
class VirtualClock:
    """A deterministic, manually advanced time source.

    Injected as ``SolveService(clock=...)`` it replaces every wall-clock
    read in the scheduler — deadline math, latency stamps, recalibration
    observations — so a whole soak replays bit-for-bit. Callable (the
    scheduler's clock contract) and monotone (``advance`` refuses to go
    backward).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backward: {dt}")
        self._now += dt

    def advance_to(self, t: float) -> None:
        self._now = max(self._now, float(t))


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: when, what, whose, and under which SLA."""

    t: float  # arrival time (virtual or wall seconds from soak start)
    graph: Graph
    tenant: str
    deadline_s: float | None  # relative to arrival, not submission
    floor_quality: float | None = None


def arrival_trace(
    load: int,
    rate_rps: float,
    n_range: tuple,
    p: float,
    seed: int,
    *,
    repeat_frac: float = 0.25,
    tenants: int = 2,
    burst_factor: float = 4.0,
    burst_every_s: float = 20.0,
    burst_len_s: float = 4.0,
    deadline_choices: tuple = (2.0, 8.0),
    floor_choices: tuple = (None,),
) -> list:
    """Seed-stable open-loop arrival process for one offered load.

    Inter-arrival gaps are unit-rate exponential draws scaled by the
    instantaneous rate: the Poisson base ``rate_rps``, multiplied by
    ``burst_factor`` during burst episodes (the first ``burst_len_s`` of
    every ``burst_every_s`` window — deterministic episodes, so two
    traces at different rates stay comparable). The graph mix and the
    skewed tenant assignment reuse `request_mix` / `tenant_mix` with the
    same seed, so **changing ``rate_rps`` rescales arrival times without
    changing which requests arrive** — that is what makes
    attainment-vs-offered-load curves (and their monotonicity test)
    apples-to-apples. Deadlines and accuracy floors are drawn per
    request from the given choice tuples (``None`` = unconstrained).
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0: {rate_rps}")
    graphs = request_mix(load, n_range, p, repeat_frac, seed)
    labels = tenant_mix(load, tenants, seed)
    rng = np.random.default_rng(seed + 0x51A)
    trace, t = [], 0.0
    for g, tenant in zip(graphs, labels):
        in_burst = burst_factor > 1.0 and (t % burst_every_s) < burst_len_s
        rate = rate_rps * (burst_factor if in_burst else 1.0)
        t += float(rng.exponential(1.0)) / rate
        deadline = deadline_choices[int(rng.integers(len(deadline_choices)))]
        floor = floor_choices[int(rng.integers(len(floor_choices)))]
        trace.append(Arrival(t, g, tenant, deadline, floor))
    return trace


def _submit_arrival(svc, a: Arrival, now: float) -> int:
    """Open-loop submission: the deadline budget is residual from the
    *arrival* stamp — time spent unnoticed in the arrival queue counts."""
    deadline = None
    if a.deadline_s is not None:
        deadline = a.t + a.deadline_s - now
    return svc.submit(
        a.graph,
        SLA(deadline_s=deadline, floor_quality=a.floor_quality),
        tenant=a.tenant,
        defer=True,
    )


def run_soak_virtual(svc, clock: VirtualClock, trace, tick_s: float = 0.01):
    """Replay an arrival trace under a virtual clock; returns the rids
    aligned with the trace.

    Each `pump` tick costs exactly ``tick_s`` virtual seconds — the
    calibration knob relating offered load to service capacity — and
    idle gaps fast-forward to the next arrival. Everything downstream
    (deadline verdicts, latencies, stats) is a pure function of
    (trace, service config, tick_s), which is what the bit-determinism
    property in tests/test_service_sla.py asserts.
    """
    rids = []
    i = 0
    while True:
        now = clock.now()
        while i < len(trace) and trace[i].t <= now:
            rids.append(_submit_arrival(svc, trace[i], now))
            i += 1
        busy = svc.pump()
        if busy:
            clock.advance(tick_s)
        elif i < len(trace):
            clock.advance_to(max(trace[i].t, now + tick_s))
        else:
            break
    return rids


def run_soak_wall(svc, trace, *, max_idle_sleep_s: float = 0.002):
    """Replay an arrival trace in wall-clock time (the bench mode);
    returns (rids, wall_seconds). Open-loop: if the service falls
    behind, due arrivals flood in unthrottled."""
    rids = []
    i = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i].t <= now:
            rids.append(_submit_arrival(svc, trace[i], now))
            i += 1
        busy = svc.pump()
        if not busy:
            if i >= len(trace):
                break
            gap = trace[i].t - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(min(gap, max_idle_sleep_s))
    return rids, time.perf_counter() - t0


def latency_summary(svc) -> dict:
    """Completed-request latency summary for a drained soak, straight
    from the service's shared obs histogram (count/sum/p50/p99) — the
    single percentile implementation the benches consume (DESIGN.md §8)
    instead of hand-rolling sorted-list math per call site."""
    return svc.stats.latency.summary()

"""Seed-stable request-mix generation shared by the service drivers
(`launch/serve_maxcut.py`, `benchmarks/service_bench.py`): varied-size
Erdős-Rényi instances with a controllable fraction of vertex-relabeled
repeats, the traffic shape that exercises the canonical-graph cache
(DESIGN.md §6.3)."""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def relabel(graph: Graph, perm: np.ndarray) -> Graph:
    """The same instance under a vertex permutation (isomorphic copy)."""
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    return Graph.from_edges(graph.n, perm[e], w)


def request_mix(
    load: int,
    n_range: tuple,
    p: float,
    repeat_frac: float,
    seed: int,
) -> list:
    """Seed-stable graphs for one offered load; ~repeat_frac of them are
    vertex-relabeled copies of earlier ones (isomorphic, cache-hittable)."""
    rng = np.random.default_rng(seed)
    fresh, graphs = [], []
    for _ in range(load):
        if fresh and rng.random() < repeat_frac:
            g0 = fresh[int(rng.integers(len(fresh)))]
            perm = rng.permutation(g0.n).astype(np.int32)
            graphs.append(relabel(g0, perm))
        else:
            n = int(rng.integers(n_range[0], n_range[1] + 1))
            g = Graph.erdos_renyi(n, p, seed=int(rng.integers(1 << 30)))
            fresh.append(g)
            graphs.append(g)
    return graphs


def tenant_mix(load: int, tenants: int, seed: int) -> list:
    """Seed-stable tenant labels (``"t0"``…) for one offered load.

    A *skewed* assignment — tenant ``t0`` claims roughly half the
    requests, the rest split evenly — because uniform traffic never
    exercises the scheduler's fairness/quota path (DESIGN.md §6.5).
    With one tenant everything is ``"t0"``.
    """
    if tenants < 1:
        raise ValueError(f"tenants must be >= 1: {tenants}")
    rng = np.random.default_rng(seed + 0x7E7A)
    labels = []
    for _ in range(load):
        if tenants == 1 or rng.random() < 0.5:
            labels.append("t0")
        else:
            labels.append(f"t{int(rng.integers(1, tenants))}")
    return labels

"""SLA-driven knob selection (DESIGN.md §6.2).

The paper's §4.2 parameter taxonomy exposes (K, L, opt_steps, N) as
per-invocation CLI flags; the service chooses them *per request* from a
deadline / accuracy target. A small calibrated cost model — per-stage
coefficients fitted from `results/BENCH_distributed.json`-style stage
timings — predicts (partition_s, solve_s, merge_s) for every knob tuple in
a candidate grid; the planner then picks, among the tuples predicted to
meet the deadline, the cheapest that reaches the accuracy target, else the
highest-quality one. Because the feasible set only shrinks as the deadline
tightens and selection maximizes quality within it, a tighter deadline can
never select a slower-predicted tuple (proved by `tests/test_service.py`).

Quality is a monotone proxy score over the knobs (the paper's Figs. 9-10
trends: cut quality rises with K, beam/L, N, and optimizer steps), shared
with the result cache's equal-or-better-quality gate (§6.3).

The committed `BENCH_distributed.json` fit is only the *prior*: the
scheduler streams served-request stage timings back through
`observe_partition` / `observe_solve` / `observe_merge`, each an
exponentially weighted blend of the implied per-work-unit coefficient
into the live `CostModel` (DESIGN.md §6.5). Selection monotonicity is
structural — it holds for any non-negative coefficient values, so it
survives every refit — and a planner that never observes keeps its
fitted model bit-for-bit (both proved in tests/test_service.py).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import NamedTuple, Sequence

import numpy as np


class KnobTuple(NamedTuple):
    """One candidate setting of the paper's §4.2 tunable knobs."""

    n_qubits: int  # N — per-solver qubit budget
    top_k: int  # K — candidates kept per subgraph
    opt_steps: int  # Adam steps on <cut>
    beam_width: int  # merge frontier width (the L knob's work volume)
    p_layers: int = 2


class StageCost(NamedTuple):
    partition_s: float
    solve_s: float
    merge_s: float

    @property
    def total_s(self) -> float:
        return self.partition_s + self.solve_s + self.merge_s


class KnobPlan(NamedTuple):
    """Planner output: the chosen knobs plus their predictions."""

    knobs: KnobTuple
    merge_level: int  # L, clamped to the predicted partition depth
    predicted: StageCost
    quality: float
    meets_deadline: bool
    meets_quality: bool

    def to_config(self):
        """`ParaQAOAConfig` for this plan — the single knob→config
        mapping shared by the scheduler, the benches, and every
        service-vs-solo parity check (so a new knob field cannot be
        silently dropped from one of them)."""
        from repro.core import paraqaoa  # service→core only, no cycle

        kn = self.knobs
        return paraqaoa.ParaQAOAConfig(
            n_qubits=kn.n_qubits,
            top_k=kn.top_k,
            merge_level=self.merge_level,
            p_layers=kn.p_layers,
            opt_steps=kn.opt_steps,
            beam_width=kn.beam_width,
        )


@dataclasses.dataclass(frozen=True)
class SLA:
    """Per-request service-level objective. `None` means unconstrained.

    ``floor_quality`` is the *hard* accuracy floor of the deadline
    enforcement path (DESIGN.md §6.6): a downgrade re-plan may walk the
    knob lattice down only to tuples whose `quality_score` still meets
    it, and a request whose floor plan is predicted to miss the residual
    deadline is shed rather than served below the floor.
    ``target_quality`` remains the *soft* target `plan` optimizes for.
    """

    deadline_s: float | None = None
    target_quality: float | None = None
    floor_quality: float | None = None


class ReplanDecision(NamedTuple):
    """Outcome of a deadline re-score (DESIGN.md §6.6).

    ``verdict`` is one of:
      - ``"keep"``      — the current plan is still predicted to meet the
                          residual budget; ``plan`` is the current plan;
      - ``"downgrade"`` — the current plan is predicted late but a
                          floor-meeting tuple fits; ``plan`` is the new
                          (cheaper) plan;
      - ``"shed"``      — even the floor plan is predicted late (or the
                          declared floor is unreachable in the grid);
                          ``plan`` is None.
    """

    verdict: str
    plan: "KnobPlan | None"
    floor_predicted_s: float  # the floor plan's predicted total (inf if
    #                           the floor is unreachable in the grid)


def quality_score(knobs: KnobTuple) -> float:
    """Monotone accuracy proxy over the knob tuple; higher is better.

    Calibrated ordering, not an AR prediction: each term follows the
    paper's measured trend direction (K: Fig. 9, beam/L: Fig. 10,
    N: §4.2, opt_steps: the ansatz optimizer), with diminishing returns
    via log/ratio shaping.
    """
    return (
        float(knobs.n_qubits)
        + 2.0 * math.log2(knobs.top_k)
        + 0.5 * math.log2(knobs.beam_width)
        + 3.0 * knobs.opt_steps / (knobs.opt_steps + 10.0)
    )


def _subgraph_count(n_vertices: int, n_qubits: int) -> int:
    if n_vertices <= n_qubits:
        return 1
    return math.ceil(n_vertices / (n_qubits - 1))


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-stage linear coefficients over closed-form work terms.

    partition ~ c_partition · (|E| + |V|)           (host preprocessing)
    solve     ~ c_solve · M·(T+1)·p·2^N + c_dispatch·ceil(M/B)
    merge     ~ c_merge · W·K·|E| + c_merge_base·M  (frontier × extensions
                                                     × edges scored once)
    """

    c_partition: float = 2.5e-8
    c_solve: float = 6.0e-8
    c_dispatch: float = 2.0e-2
    c_merge: float = 1.2e-8
    c_merge_base: float = 1.0e-3
    batch_slots: int = 16

    def predict(
        self, n_vertices: int, n_edges: int, knobs: KnobTuple
    ) -> StageCost:
        m = _subgraph_count(n_vertices, knobs.n_qubits)
        e = max(n_edges, 1)
        part = self.c_partition * (e + n_vertices)
        amp_steps = m * (knobs.opt_steps + 1) * knobs.p_layers * 2**knobs.n_qubits
        solve = self.c_solve * amp_steps + self.c_dispatch * math.ceil(
            m / self.batch_slots
        )
        merge = self.c_merge * knobs.beam_width * knobs.top_k * e + (
            self.c_merge_base * m
        )
        return StageCost(part, solve, merge)

    @classmethod
    def fit(
        cls,
        rows: Sequence[dict],
        knobs: KnobTuple,
        edge_prob: float = 0.02,
        **overrides,
    ) -> "CostModel":
        """Fit coefficients from benchmark stage-timing rows.

        Rows follow the `BENCH_distributed.json` single-device schema:
        each carries `n`, `partition_s`, `solve_s`, `merge_s` (and `m` when
        recorded); `knobs` are the settings the suite ran with and
        `edge_prob` recovers |E| for rows that predate an explicit edge
        count. Coefficients are the median observed time-per-work-unit, so
        one outlier row cannot skew the model.
        """
        base = cls(**overrides)
        c_part, c_solve, c_merge = [], [], []
        for row in rows:
            if "partition_s" not in row or "n" not in row:
                continue
            n = int(row["n"])
            e = int(row.get("edges") or edge_prob * n * (n - 1) / 2)
            m = int(row.get("m") or _subgraph_count(n, knobs.n_qubits))
            c_part.append(row["partition_s"] / max(e + n, 1))
            amp = m * (knobs.opt_steps + 1) * knobs.p_layers * 2**knobs.n_qubits
            c_solve.append(
                max(row["solve_s"] - base.c_dispatch * math.ceil(m / base.batch_slots), 0.0)
                / max(amp, 1)
            )
            c_merge.append(
                max(row["merge_s"] - base.c_merge_base * m, 0.0)
                / max(knobs.beam_width * knobs.top_k * e, 1)
            )
        if not c_part:
            return base
        return dataclasses.replace(
            base,
            c_partition=float(np.median(c_part)),
            c_solve=float(np.median(c_solve)),
            c_merge=float(np.median(c_merge)),
        )

    @classmethod
    def from_bench_file(
        cls, path: str, knobs: KnobTuple | None = None, **kwargs
    ) -> "CostModel":
        """Calibrate from a committed BENCH_*.json; defaults on any miss.

        The shipped calibration source is `results/BENCH_distributed.json`
        (written by `benchmarks/large_scale.py --distributed` with the
        knob settings below).
        """
        knobs = knobs or KnobTuple(
            n_qubits=10, top_k=1, opt_steps=12, beam_width=64, p_layers=2
        )
        try:
            with open(path) as f:
                payload = json.load(f)
            rows = [
                r for r in payload.get("rows", []) if r.get("mode") == "single"
            ]
            return cls.fit(rows, knobs, **kwargs)
        except (OSError, ValueError, KeyError):
            return cls(**kwargs)


DEFAULT_BENCH_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results",
    "BENCH_distributed.json",
)

# the candidate grid: small enough to scan per request, wide enough to
# span ~3 orders of magnitude in predicted cost
DEFAULT_GRID: tuple = tuple(
    KnobTuple(n_qubits=nq, top_k=k, opt_steps=t, beam_width=w)
    for nq in (6, 8, 10, 12)
    for k in (1, 2, 4)
    for t in (4, 12, 30)
    for w in (32, 128, 512)
)


@dataclasses.dataclass
class CalibrationStats:
    """Streaming-refit bookkeeping: how many served-request observations
    have been blended into each stage coefficient."""

    partition_obs: int = 0
    solve_obs: int = 0
    merge_obs: int = 0

    @property
    def total(self) -> int:
        return self.partition_obs + self.solve_obs + self.merge_obs

    def as_dict(self) -> dict:
        return {
            "partition_obs": self.partition_obs,
            "solve_obs": self.solve_obs,
            "merge_obs": self.merge_obs,
        }


class Planner:
    """Maps (graph size, SLA) → the knob tuple the scheduler should run.

    ``recalibrate_alpha`` is the exponential weight of the streaming
    refit: each `observe_*` call blends the observed per-work-unit
    coefficient as ``c ← (1-α)·c + α·obs``. With zero observations the
    cost model stays bit-for-bit the fitted prior.
    """

    def __init__(
        self,
        cost_model: CostModel | None = None,
        grid: Sequence[KnobTuple] = DEFAULT_GRID,
        max_qubits: int | None = None,
        default_merge_level: int = 2,
        batch_slots: int | None = None,
        recalibrate_alpha: float = 0.25,
    ):
        self.cost_model = cost_model or CostModel.from_bench_file(
            DEFAULT_BENCH_PATH
        )
        if batch_slots is not None:
            # predict dispatch counts for the batch size the scheduler
            # actually runs, not the model's default
            self.cost_model = dataclasses.replace(
                self.cost_model, batch_slots=batch_slots
            )
        if max_qubits is not None:
            grid = [kn for kn in grid if kn.n_qubits <= max_qubits]
        if not grid:
            raise ValueError("empty knob grid")
        self.grid = list(grid)
        self.default_merge_level = default_merge_level
        if not 0.0 < recalibrate_alpha <= 1.0:
            raise ValueError(f"recalibrate_alpha out of (0, 1]: {recalibrate_alpha}")
        self.recalibrate_alpha = recalibrate_alpha
        self.base_model = self.cost_model  # the pre-refit fitted prior
        self.calibration = CalibrationStats()

    # ------------------------------------------------- streaming refit --
    def _blend(self, field: str, observed: float) -> None:
        """One EW refit step of a single coefficient; clamps at >= 0 so
        selection monotonicity (structural over non-negative coefficients)
        survives arbitrary observation streams."""
        obs = max(float(observed), 0.0)
        a = self.recalibrate_alpha
        cur = getattr(self.cost_model, field)
        self.cost_model = dataclasses.replace(
            self.cost_model, **{field: (1.0 - a) * cur + a * obs}
        )

    def observe_partition(
        self, n_vertices: int, n_edges: int, seconds: float
    ) -> None:
        """Blend one measured host-partition time into `c_partition`."""
        self.calibration.partition_obs += 1
        self._blend("c_partition", seconds / max(n_edges + n_vertices, 1))

    def observe_solve(
        self,
        n_qubits: int,
        p_layers: int,
        opt_steps: int,
        slots: int,
        seconds: float,
    ) -> None:
        """Blend one measured batch-dispatch time into `c_solve`.

        ``slots`` is the dispatched row count (padding rows run the full
        computation, so they count as work); the model's per-dispatch
        overhead term is subtracted before normalizing.
        """
        work = slots * (opt_steps + 1) * p_layers * 2**n_qubits
        self.calibration.solve_obs += 1
        self._blend(
            "c_solve",
            max(seconds - self.cost_model.c_dispatch, 0.0) / max(work, 1),
        )

    def observe_merge(
        self, knobs: KnobTuple, m: int, n_edges: int, seconds: float
    ) -> None:
        """Blend one measured per-request merge time into `c_merge`."""
        work = knobs.beam_width * knobs.top_k * max(n_edges, 1)
        self.calibration.merge_obs += 1
        self._blend(
            "c_merge",
            max(seconds - self.cost_model.c_merge_base * m, 0.0)
            / max(work, 1),
        )

    def observe_span(self, span) -> None:
        """§8: recalibration from the span stream. The scheduler hands
        every closed stage span here; spans carry their observation
        payload in their attrs, and this dispatches on the span name to
        the per-stage observers above. Unknown span names are ignored,
        so the scheduler can stream its whole trace without filtering.
        """
        a = span.attrs
        if span.name == "partition":
            self.observe_partition(a["n"], a["n_edges"], span.duration_s)
        elif span.name == "solve":
            self.observe_solve(a["n_qubits"], a["p_layers"], a["opt_steps"],
                               a["slots"], span.duration_s)
        elif span.name == "merge":
            self.observe_merge(a["knobs"], a["m"], a["n_edges"],
                               span.duration_s)

    def _lattice(self, floor_quality: float | None) -> list[KnobTuple]:
        """The knob lattice a request may occupy: grid tuples meeting the
        declared hard accuracy floor. An unreachable floor returns [] —
        the caller decides between shed (deadline enforcement) and
        best-effort (no deadline)."""
        if floor_quality is None:
            return self.grid
        return [
            kn for kn in self.grid
            if quality_score(kn) >= floor_quality - 1e-12
        ]

    def floor_predicted(
        self, n_vertices: int, n_edges: int, floor_quality: float | None
    ) -> tuple[KnobTuple, StageCost] | None:
        """The *floor plan*: the cheapest-predicted tuple still meeting
        the declared accuracy floor — the last stop on the downgrade
        lattice before shedding. None when the floor is unreachable in
        the grid (no tuple scores high enough)."""
        lattice = self._lattice(floor_quality)
        if not lattice:
            return None
        return min(
            ((kn, self.cost_model.predict(n_vertices, n_edges, kn))
             for kn in lattice),
            key=lambda s: (s[1].total_s, s[0]),
        )

    def replan(
        self,
        n_vertices: int,
        n_edges: int,
        budget_s: float,
        current: KnobPlan,
        floor_quality: float | None = None,
    ) -> ReplanDecision:
        """Re-score one queued request against its residual wall-clock
        budget (DESIGN.md §6.6).

        Keep the current plan while it is still predicted to fit the
        budget. Otherwise walk the knob lattice to the cheapest-predicted
        floor-meeting tuple that fits — the cost model has already been
        wrong once for this request (its original prediction no longer
        holds), so a downgrade maximizes safety margin instead of
        squeezing quality; ties break toward higher quality, then the
        tuple. When even the floor plan is predicted late, the verdict is
        shed. Monotone in the budget by construction: the kept plan's
        predicted time is fixed, the downgrade target is the lattice-wide
        minimum, and a shrinking budget can only move keep → downgrade →
        shed, never backward in predicted time.
        """
        floor = self.floor_predicted(n_vertices, n_edges, floor_quality)
        if floor is None:  # declared floor unreachable in the grid
            return ReplanDecision("shed", None, float("inf"))
        floor_s = floor[1].total_s
        cur_pred = self.cost_model.predict(n_vertices, n_edges, current.knobs)
        if cur_pred.total_s <= budget_s:
            return ReplanDecision("keep", current, floor_s)
        if floor_s > budget_s:
            return ReplanDecision("shed", None, floor_s)
        scored = [
            (kn, self.cost_model.predict(n_vertices, n_edges, kn),
             quality_score(kn))
            for kn in self._lattice(floor_quality)
        ]
        feasible = [s for s in scored if s[1].total_s <= budget_s]
        choice = min(feasible, key=lambda s: (s[1].total_s, -s[2], s[0]))
        plan = self._finish(
            choice, n_vertices, True,
            choice[2] >= (floor_quality or -math.inf), SLA(),
        )
        return ReplanDecision("downgrade", plan, floor_s)

    def plan(self, n_vertices: int, n_edges: int, sla: SLA = SLA()) -> KnobPlan:
        """Pick knobs for one request.

        Selection: among tuples predicted to meet the deadline, the
        cheapest that reaches the accuracy target; if none reaches it,
        the highest-quality feasible tuple; if nothing fits the deadline
        at all, the fastest tuple (best effort). Ties break toward lower
        predicted time, then the knob tuple itself, so planning is
        deterministic — and tightening the deadline can only move the
        choice to an equal-or-faster-predicted tuple. A declared
        ``sla.floor_quality`` restricts the candidate lattice to
        floor-meeting tuples (an unreachable floor falls back to the full
        grid — the shed decision belongs to the scheduler's enforcement
        path, not to planning).
        """
        lattice = self._lattice(sla.floor_quality) or self.grid
        scored = []
        for kn in lattice:
            pred = self.cost_model.predict(n_vertices, n_edges, kn)
            scored.append((kn, pred, quality_score(kn)))

        deadline = sla.deadline_s
        feasible = [
            s for s in scored if deadline is None or s[1].total_s <= deadline
        ]
        meets_deadline = bool(feasible)
        if not feasible:  # best effort: fastest tuple in the grid
            choice = min(scored, key=lambda s: (s[1].total_s, s[0]))
            return self._finish(choice, n_vertices, False, False, sla)

        target = sla.target_quality
        if target is not None:
            reaching = [s for s in feasible if s[2] >= target]
            if reaching:
                # meet the accuracy target at minimum predicted cost
                choice = min(reaching, key=lambda s: (s[1].total_s, s[0]))
                return self._finish(choice, n_vertices, True, True, sla)
        # no (reachable) target: maximize quality within the deadline
        choice = max(
            feasible, key=lambda s: (s[2], -s[1].total_s, s[0])
        )
        return self._finish(choice, n_vertices, True, target is None, sla)

    def _finish(self, choice, n_vertices, meets_deadline, meets_quality, sla):
        kn, pred, qual = choice
        m = _subgraph_count(n_vertices, kn.n_qubits)
        return KnobPlan(
            knobs=kn,
            merge_level=min(self.default_merge_level, max(m - 1, 0)),
            predicted=pred,
            quality=qual,
            meets_deadline=meets_deadline,
            meets_quality=meets_quality if sla.target_quality is not None else True,
        )

"""Pluggable solver backends for the solve service (DESIGN.md §6.5).

The scheduler's packing logic is backend-agnostic: it builds fixed-shape
`batch_slots`-row buckets and hands them to a backend's `solve_batch`.

  - `LocalBackend` runs the single-device cached jitted
    `qaoa.solve_subgraph_batch_program` — PR 3's original path.
  - `MeshBackend` routes the *same* padded batch through
    `core.distributed.solve_pool` over a device mesh's `data`/`pod` axes
    — the paper's N_s-solver pool as the service's execution engine.
    Because `solve_pool` wraps the identical jitted computation in
    `shard_map` (and both program caches key on the active `kernels.ops`
    implementation), the per-row candidates — and therefore every
    request's cut — are bit-identical across backends
    (`core._dist_checks check_service_mesh`, `cut_equal` in
    `results/BENCH_service_mesh.json`).

Backends return *unmaterialized* device results: jax dispatch is
asynchronous, so the scheduler can keep admitting and dispatching while
earlier batches are still in flight and only blocks when it harvests
(`np.asarray`) the oldest one (DESIGN.md §6.5).
"""

from __future__ import annotations

from repro import compat
from repro.core import qaoa as qaoa_mod


class LocalBackend:
    """Single-device batched solver: the cached jitted batch program."""

    name = "local"

    def solve_batch(self, qcfg: qaoa_mod.QAOAConfig, edges, weights, masks,
                    linears=None):
        if linears is not None:
            return qaoa_mod.solve_subgraph_batch_program(qcfg, has_linear=True)(
                edges, weights, masks, linears
            )
        return qaoa_mod.solve_subgraph_batch_program(qcfg)(
            edges, weights, masks
        )

    def describe(self) -> dict:
        return {"backend": self.name, "devices": 1}


class MeshBackend:
    """Batches routed through `solve_pool` over a `data` mesh.

    ``mesh_spec`` is anything `core.distributed.as_mesh` resolves: a
    `jax.sharding.Mesh`, a parsed ``{"data": 4}`` dict, or a
    ``"data=4"`` CLI string. The mesh must expose at least one
    batch-shardable (`data`/`pod`) axis; on a single-CPU host arrange
    device emulation (`compat.ensure_host_device_count`) *before* jax
    initializes, exactly as `launch/serve_maxcut.py --mesh` does.
    """

    name = "mesh"

    def __init__(self, mesh_spec):
        from repro.core import distributed as dist

        self._dist = dist
        self.mesh = dist.as_mesh(mesh_spec)
        if self.mesh is None or not self.mesh.shape:
            raise ValueError(f"MeshBackend needs a non-empty mesh: {mesh_spec!r}")
        self.axes = compat.mesh_data_axes(self.mesh)
        if not self.axes:
            raise ValueError(
                f"mesh {dict(self.mesh.shape)} has no data/pod axis to "
                "shard the solver pool over"
            )

    @property
    def n_devices(self) -> int:
        total = 1
        for a in self.axes:
            total *= int(self.mesh.shape[a])
        return total

    def solve_batch(self, qcfg: qaoa_mod.QAOAConfig, edges, weights, masks,
                    linears=None):
        return self._dist.solve_pool(
            edges, weights, masks, qcfg, self.mesh, axes=self.axes,
            linears=linears,
        )

    def describe(self) -> dict:
        return {
            "backend": self.name,
            "mesh": dict(self.mesh.shape),
            "axes": list(self.axes),
            "devices": self.n_devices,
        }


def make_backend(mesh_spec=None):
    """`ServiceConfig.mesh` → backend: None keeps the local program."""
    if mesh_spec is None:
        return LocalBackend()
    return MeshBackend(mesh_spec)

"""Cross-request batching Max-Cut solve service (DESIGN.md §6.1, §6.5).

The paper's pipeline solves one problem per invocation; the ROADMAP north
star is a service under concurrent load. The scheduler closes that gap by
amortizing solver capacity *across* requests:

  1. `submit` places a request on the admission queue. Admission consults
     the result cache (§6.3) on the canonical graph hash, and — on a miss
     — asks the SLA planner (§6.2) for a knob tuple, partitions via
     `core.partition.partition_for_solver` at the chosen qubit budget, and
     enqueues one work item per subgraph;
  2. the dispatcher packs pending subgraphs from *any* request (and any
     tenant) into fixed-shape batches for the configured solver backend
     (§6.5): the single-device cached `solve_subgraph_batch_program`, or
     `core.distributed.solve_pool` over a `data` mesh. Batches are
     shape-bucketed by the QAOA config: every dispatch in a bucket uses
     exactly ``batch_slots`` rows padded to the qubit budget's edge
     capacity N·(N−1)/2 — the maximum a ≤N-vertex subgraph can carry —
     so a bucket compiles exactly once no matter how request sizes mix.
     Dispatch is *asynchronous*: jax returns unmaterialized device
     results, so up to ``max_inflight`` batches overlap with admission
     and with each other; the loop only blocks when it harvests the
     oldest in-flight batch. Everything stays a deterministic
     single-thread event loop — "concurrent" means many admitted
     requests and in-flight batches, never racing threads;
  3. per-request completion tracking (mirroring `serving/engine.py`'s done
     mask, here a remaining-subgraph count) fires the merge stage the
     moment a request's last candidate lands: the default path runs
     `core.paraqaoa.merge_candidates` — the *same* merge `core.solve`
     runs, which together with the per-row bit-stability of the batched
     solver makes service cuts bit-identical to solo `solve` runs on the
     same knobs — while streaming requests run the anytime
     `core.merge.merge_stream` and surface the best-known cut after every
     merge level (§6.4).

Multi-tenant fairness (§6.5): when a bucket holds more waiting subgraphs
than one dispatch can take, slots are filled round-robin across tenants
(optionally capped per tenant under contention), and any bucket whose
oldest item has waited ``max_wait_dispatches`` dispatches pre-empts the
fullest-bucket heuristic — so no request starves behind a heavier
tenant's traffic (bounded-delay property, tests/test_service_stress.py).

Served-request stage timings stream back into the planner's cost model
(`Planner.observe_*`, §6.5) so knob selection tracks the hardware the
service actually runs on, not the shipped benchmark fit.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import paraqaoa as para_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import partition_for_solver
from repro.service.backend import make_backend
from repro.service.cache import ResultCache
from repro.service.canonical import canonical_form
from repro.service.planner import SLA, KnobPlan, Planner


def edge_capacity(n_qubits: int) -> int:
    """Max simple-edge count of a subgraph that fits an N-qubit solver."""
    return max(n_qubits * (n_qubits - 1) // 2, 1)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    batch_slots: int = 16  # fixed rows per solver dispatch (one shape/bucket)
    cache_capacity: int = 256
    enable_cache: bool = True
    max_qubits: int = 12  # hardware budget cap handed to the planner
    anytime_min_levels: int = 2  # stream only when the merge has >1 level
    # §6.5 backend: None → single-device program; a mesh spec (string /
    # dict / Mesh) routes batches through solve_pool over its data axes
    mesh: object = None
    # §6.5 async admission loop
    max_inflight: int = 2  # dispatched-but-unharvested batches
    max_wait_dispatches: int = 4  # anti-starvation pre-emption bound
    tenant_max_slots: int | None = None  # per-tenant slot cap under contention
    # §6.5 online recalibration: stream stage timings into the planner
    recalibrate: bool = True


@dataclasses.dataclass
class RequestResult:
    request_id: int
    assignment: np.ndarray
    cut_value: float
    cached: bool
    plan: KnobPlan
    latency_s: float
    timings: dict
    anytime: list  # [(level, n_levels, best_known_cut)] for streamed requests
    tenant: str = "default"
    dispatches_waited: int = 0  # dispatches between admission and completion


class _Request:
    def __init__(self, rid, graph, sla, plan, cfg, stream, on_update, form,
                 tenant):
        self.id = rid
        self.graph = graph
        self.sla = sla
        self.plan = plan
        self.cfg = cfg  # ParaQAOAConfig derived from plan.knobs
        self.stream = stream
        self.on_update = on_update
        self.form = form  # canonical form, when the cache is enabled
        self.tenant = tenant
        self.submit_t = time.perf_counter()
        self.part = None
        self.bit_indices = None  # (M, K) int64
        self.remaining = 0
        self.solve_done_t = None
        self.admit_dispatch = 0  # stats.dispatches at admission


class _Item:
    """One queued subgraph: request, its subgraph index, enqueue stamp."""

    __slots__ = ("req", "idx", "enq_dispatch")

    def __init__(self, req, idx, enq_dispatch):
        self.req = req
        self.idx = idx
        self.enq_dispatch = enq_dispatch


class _Batch:
    """One dispatched (possibly still in-flight) solver batch."""

    __slots__ = ("qcfg", "items", "result", "t_issue")

    def __init__(self, qcfg, items, result, t_issue):
        self.qcfg = qcfg
        self.items = items
        self.result = result  # unmaterialized device arrays
        self.t_issue = t_issue


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    completed: int = 0
    cache_served: int = 0
    slots: int = 0  # solver slots this tenant's subgraphs occupied

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServiceStats:
    dispatches: int = 0
    slots_total: int = 0
    slots_filled: int = 0
    completed: int = 0
    cache_served: int = 0
    admitted: int = 0
    preemptions: int = 0  # anti-starvation bucket picks
    max_inflight_seen: int = 0
    tenants: dict = dataclasses.field(default_factory=dict)

    def tenant(self, name: str) -> TenantStats:
        if name not in self.tenants:
            self.tenants[name] = TenantStats()
        return self.tenants[name]

    @property
    def fill_ratio(self) -> float:
        return self.slots_filled / self.slots_total if self.slots_total else 0.0

    def as_dict(self) -> dict:
        return {
            "dispatches": self.dispatches,
            "slots_total": self.slots_total,
            "slots_filled": self.slots_filled,
            "fill_ratio": round(self.fill_ratio, 4),
            "completed": self.completed,
            "cache_served": self.cache_served,
            "admitted": self.admitted,
            "preemptions": self.preemptions,
            "max_inflight_seen": self.max_inflight_seen,
            "tenants": {t: s.as_dict() for t, s in self.tenants.items()},
        }


class SolveService:
    """Batched Max-Cut solve service over the ParaQAOA pipeline."""

    def __init__(
        self,
        config: ServiceConfig = ServiceConfig(),
        planner: Planner | None = None,
        cache: ResultCache | None = None,
        backend=None,
    ):
        self.config = config
        self.planner = planner or Planner(
            max_qubits=config.max_qubits, batch_slots=config.batch_slots
        )
        self.cache = cache or ResultCache(config.cache_capacity)
        self.backend = backend or make_backend(config.mesh)
        self.stats = ServiceStats()
        self.results: "OrderedDict[int, RequestResult]" = OrderedDict()
        self._next_id = 0
        self._active: dict[int, _Request] = {}
        # admission queue: submitted-but-not-admitted requests, drained by
        # `submit` (eager default) or at the top of every `pump` tick
        self._admission: deque = deque()
        # bucket key: the (frozen, hashable) QAOAConfig — one compiled
        # program and one queue per static solver configuration
        self._buckets: "OrderedDict[qaoa_mod.QAOAConfig, deque]" = OrderedDict()
        # dispatched batches whose device results have not landed yet
        self._inflight: "deque[_Batch]" = deque()
        self._last_harvest_t = 0.0  # de-queues solve-time observations
        # in-flight dedup: canonical key → (primary request id, its quality);
        # isomorphic requests admitted while their twin is still solving
        # coalesce onto it and are served from cache when it completes
        self._inflight_forms: dict[str, tuple[int, float]] = {}
        self._followers: dict[str, list] = {}

    # ------------------------------------------------------------- admit --
    def submit(
        self,
        graph: Graph,
        sla: SLA = SLA(),
        stream: bool = False,
        on_update: Optional[Callable] = None,
        tenant: str = "default",
        defer: bool = False,
    ) -> int:
        """Place one solve request on the admission queue; returns its id.

        With ``defer=False`` (default) admission happens before `submit`
        returns: cache hits complete immediately (the result is visible
        in `results` on return); misses enqueue the request's subgraphs
        into the shared batch queues. ``defer=True`` guarantees only
        that *this call* does no admission work — the request waits on
        the admission queue until the next `pump` tick or the next eager
        `submit`, whichever drains the (strictly FIFO) queue first; the
        interleaved-arrival shape of a live frontend, where requests
        land while earlier batches are still in flight. Either way, call
        `pump`/`drain` to make progress.
        """
        rid = self._next_id
        self._next_id += 1
        self.stats.tenant(tenant).submitted += 1
        self._admission.append(
            (rid, graph, sla, stream, on_update, tenant, time.perf_counter())
        )
        if not defer:
            self._process_admissions()
        return rid

    def _process_admissions(self) -> None:
        while self._admission:
            rid, graph, sla, stream, on_update, tenant, t0 = (
                self._admission.popleft()
            )
            self.stats.admitted += 1
            plan = self.planner.plan(graph.n, graph.n_edges, sla)
            form = None
            if self.config.enable_cache:
                form = canonical_form(graph)
                hit = self.cache.lookup(
                    graph, form=form, min_quality=plan.quality
                )
                if hit is not None:
                    assignment, cut = hit
                    self._record_cached(
                        rid, graph, plan, assignment, cut, t0,
                        stream=stream, on_update=on_update, tenant=tenant,
                    )
                    continue
                # coalesce onto an in-flight isomorphic twin of sufficient
                # quality: no work enqueued; served from cache at its merge.
                # Streaming requests bypass dedup — they want per-level
                # updates.
                primary = self._inflight_forms.get(form.key)
                if primary is not None and primary[1] >= plan.quality and not stream:
                    self._followers.setdefault(form.key, []).append(
                        (rid, graph, sla, plan, form, t0, tenant)
                    )
                    continue

            self._admit(rid, graph, sla, plan, form, stream, on_update, tenant)

    def _admit(self, rid, graph, sla, plan, form, stream, on_update,
               tenant="default") -> None:
        """Enqueue a request's subgraphs into its shape bucket."""
        kn = plan.knobs
        cfg = plan.to_config()
        req = _Request(rid, graph, sla, plan, cfg, stream, on_update, form,
                       tenant)
        t_part0 = time.perf_counter()
        req.part = partition_for_solver(graph, kn.n_qubits)
        if self.config.recalibrate:
            observe = getattr(self.planner, "observe_partition", None)
            if observe is not None:
                observe(graph.n, graph.n_edges,
                        time.perf_counter() - t_part0)
        req.bit_indices = np.zeros((req.part.m, kn.top_k), dtype=np.int64)
        req.remaining = req.part.m
        req.admit_dispatch = self.stats.dispatches
        self._active[rid] = req
        if form is not None and form.key not in self._inflight_forms:
            self._inflight_forms[form.key] = (rid, plan.quality)

        qcfg = cfg.qaoa_config()
        queue = self._buckets.setdefault(qcfg, deque())
        for idx in range(req.part.m):
            queue.append(_Item(req, idx, self.stats.dispatches))

    def _record_cached(
        self, rid, graph, plan, assignment, cut, t0,
        stream=False, on_update=None, tenant="default",
    ) -> None:
        # a streamed request served from cache still gets its anytime
        # contract: one final update (the answer is complete immediately)
        anytime = [(1, 1, cut)] if stream else []
        if stream and on_update is not None:
            on_update(rid, 1, 1, cut)
        now = time.perf_counter()
        self.results[rid] = RequestResult(
            request_id=rid,
            assignment=assignment,
            cut_value=cut,
            cached=True,
            plan=plan,
            latency_s=now - t0,
            timings={"cache_s": now - t0},
            anytime=anytime,
            tenant=tenant,
        )
        self.stats.completed += 1
        self.stats.cache_served += 1
        ts = self.stats.tenant(tenant)
        ts.completed += 1
        ts.cache_served += 1

    # --------------------------------------------------------- dispatch --
    def _pick_bucket(self):
        """The bucket to dispatch next: the fullest — unless some queue's
        head item has waited ``max_wait_dispatches`` dispatches, in which
        case the queue with the oldest head pre-empts (the bounded-delay
        guarantee of DESIGN.md §6.5)."""
        live = [(qcfg, q) for qcfg, q in self._buckets.items() if q]
        if not live:
            return None
        fullest = max(live, key=lambda b: len(b[1]))
        bound = self.config.max_wait_dispatches
        overdue = [
            (qcfg, q) for qcfg, q in live
            if self.stats.dispatches - q[0].enq_dispatch >= bound
        ]
        if overdue:
            choice = min(overdue, key=lambda b: b[1][0].enq_dispatch)
            if choice[0] is not fullest[0]:  # an actual pre-emption, not
                self.stats.preemptions += 1  # the pick it would get anyway
            return choice
        return fullest

    def _take_items(self, queue: deque) -> list:
        """Pop up to ``batch_slots`` items, round-robin across tenants.

        With a single tenant (or a queue that fits one dispatch) this is
        plain FIFO. Under contention, slots interleave tenants in
        arrival order of each tenant's oldest item, optionally capped at
        ``tenant_max_slots`` per tenant so one heavy tenant cannot fill
        the whole dispatch while others wait. The quota is
        work-conserving: once every tenant with queued items has had its
        capped share, leftover slots fill round-robin anyway — padding
        rows cost the same as filled ones, so idling capacity would only
        delay the capped tenant without helping anyone.
        """
        slots = self.config.batch_slots
        if len(queue) <= slots:
            items = list(queue)
            queue.clear()
            return items
        by_tenant: "OrderedDict[str, deque]" = OrderedDict()
        for it in queue:
            by_tenant.setdefault(it.req.tenant, deque()).append(it)
        cap = self.config.tenant_max_slots
        if cap is None or len(by_tenant) <= 1:
            cap = slots
        cap = max(cap, 1)  # a 0/negative quota must still make progress
        picked, taken = [], {t: 0 for t in by_tenant}
        while len(picked) < slots and by_tenant:
            progressed = False
            for t in list(by_tenant):
                if len(picked) == slots:
                    break
                if taken[t] >= cap:
                    continue
                picked.append(by_tenant[t].popleft())
                taken[t] += 1
                progressed = True
                if not by_tenant[t]:
                    del by_tenant[t]
            if not progressed:
                # every waiting tenant got its capped share: fill the
                # leftover slots rather than dispatch empty rows
                cap = slots
        chosen = set(map(id, picked))
        remaining = [it for it in queue if id(it) not in chosen]
        queue.clear()
        queue.extend(remaining)
        return picked

    def _dispatch_one(self) -> bool:
        """Issue one cross-request batch to the backend (non-blocking)."""
        bucket = self._pick_bucket()
        if bucket is None:
            return False
        qcfg, queue = bucket
        slots = self.config.batch_slots
        items = self._take_items(queue)

        edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
            [it.req.part.subgraphs[it.idx] for it in items],
            qcfg.n_qubits,
            e_pad=edge_capacity(qcfg.n_qubits),
            n_rows=slots,
        )
        res = self.backend.solve_batch(qcfg, edges, weights, masks)
        self._inflight.append(_Batch(qcfg, items, res, time.perf_counter()))

        self.stats.dispatches += 1
        self.stats.slots_total += slots
        self.stats.slots_filled += len(items)
        self.stats.max_inflight_seen = max(
            self.stats.max_inflight_seen, len(self._inflight)
        )
        for it in items:
            self.stats.tenant(it.req.tenant).slots += 1
        return True

    def _harvest_one(self) -> None:
        """Land the oldest in-flight batch (blocks) and run any merges it
        unblocks."""
        batch = self._inflight.popleft()
        bitstrings = np.asarray(batch.result.bitstrings)  # blocks here
        t_land = time.perf_counter()
        if self.config.recalibrate:
            observe = getattr(self.planner, "observe_solve", None)
            if observe is not None:
                # the device runs batches serially, so this batch's compute
                # window starts when the previous harvest ended — not at
                # issue time, which would bill it for the whole in-flight
                # queue ahead of it and inflate c_solve ~max_inflight-fold
                t_start = max(batch.t_issue, self._last_harvest_t)
                observe(
                    batch.qcfg.n_qubits, batch.qcfg.p_layers,
                    batch.qcfg.opt_steps, self.config.batch_slots,
                    t_land - t_start,
                )
        self._last_harvest_t = t_land

        done_requests = []
        for slot, it in enumerate(batch.items):
            it.req.bit_indices[it.idx] = bitstrings[slot]
            it.req.remaining -= 1
            if it.req.remaining == 0:
                done_requests.append(it.req)
        for req in done_requests:
            req.solve_done_t = time.perf_counter()
            self._merge(req)

    # ------------------------------------------------------------- solve --
    def pump(self) -> bool:
        """One deterministic event-loop tick: drain the admission queue,
        fill the dispatch window (up to ``max_inflight`` batches issued
        without blocking), then harvest the oldest in-flight batch and
        run any merges it unblocks. Returns True while work remains."""
        self._process_admissions()
        window = max(self.config.max_inflight, 1)  # 0 would never dispatch
        while len(self._inflight) < window:
            if not self._dispatch_one():
                break
        if self._inflight:
            self._harvest_one()
        return bool(
            self._inflight
            or self._admission
            or any(self._buckets.values())
        )

    def drain(self) -> "OrderedDict[int, RequestResult]":
        """Run the scheduler until every admitted request has a result."""
        while self.pump():
            pass
        return self.results

    # ------------------------------------------------------------- merge --
    def _merge(self, req: _Request) -> None:
        anytime: list = []
        if req.stream and req.part.m >= self.config.anytime_min_levels:
            plan, bw = para_mod.merge_inputs(
                req.part, req.bit_indices, req.cfg
            )
            best_cut, best_assign = -np.inf, None
            for snap in merge_mod.merge_stream(plan, bw):
                if snap.cut_value > best_cut:
                    best_cut, best_assign = snap.cut_value, snap.assignment
                anytime.append((snap.level, snap.n_levels, best_cut))
                if req.on_update is not None:
                    req.on_update(req.id, snap.level, snap.n_levels, best_cut)
            assignment = best_assign
        else:
            assignment, _, _ = para_mod.merge_candidates(
                req.part, req.bit_indices, req.cfg
            )
        # final re-score from scratch, exactly as core.solve reconciles
        cut = float(cut_value(req.graph, jnp.asarray(assignment)))
        if req.stream and not anytime:
            # single-level merges skip the stream; still honor the anytime
            # contract with one final update
            anytime.append((1, 1, cut))
            if req.on_update is not None:
                req.on_update(req.id, 1, 1, cut)

        now = time.perf_counter()
        if self.config.recalibrate:
            observe = getattr(self.planner, "observe_merge", None)
            if observe is not None:
                observe(req.plan.knobs, req.part.m, req.graph.n_edges,
                        now - req.solve_done_t)
        if self.config.enable_cache:
            self.cache.store(
                req.graph,
                assignment,
                cut,
                quality=req.plan.quality,
                form=req.form,
            )
        self.results[req.id] = RequestResult(
            request_id=req.id,
            assignment=np.asarray(assignment),
            cut_value=cut,
            cached=False,
            plan=req.plan,
            latency_s=now - req.submit_t,
            timings={
                "solve_s": req.solve_done_t - req.submit_t,
                "merge_s": now - req.solve_done_t,
                "total_s": now - req.submit_t,
            },
            anytime=anytime,
            tenant=req.tenant,
            dispatches_waited=self.stats.dispatches - req.admit_dispatch,
        )
        self.stats.completed += 1
        self.stats.tenant(req.tenant).completed += 1
        del self._active[req.id]

        # serve coalesced isomorphic followers from the just-stored entry
        if req.form is not None:
            self._inflight_forms.pop(req.form.key, None)
            for frid, g, sla, plan, form, t0, tenant in self._followers.pop(
                req.form.key, []
            ):
                hit = self.cache.lookup(g, form=form, min_quality=plan.quality)
                if hit is not None:
                    self._record_cached(frid, g, plan, hit[0], hit[1], t0,
                                        tenant=tenant)
                else:
                    # canonical-key collision surfaced by the cache's
                    # re-score: solve the follower for real
                    self._admit(frid, g, sla, plan, form, False, None,
                                tenant=tenant)

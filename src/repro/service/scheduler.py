"""Cross-request batching Max-Cut solve service (DESIGN.md §6.1, §6.5).

The paper's pipeline solves one problem per invocation; the ROADMAP north
star is a service under concurrent load. The scheduler closes that gap by
amortizing solver capacity *across* requests:

  1. `submit` places a request on the admission queue. Admission consults
     the result cache (§6.3) on the canonical graph hash, and — on a miss
     — asks the SLA planner (§6.2) for a knob tuple, partitions via
     `core.partition.partition_for_solver` at the chosen qubit budget, and
     enqueues one work item per subgraph;
  2. the dispatcher packs pending subgraphs from *any* request (and any
     tenant) into fixed-shape batches for the configured solver backend
     (§6.5): the single-device cached `solve_subgraph_batch_program`, or
     `core.distributed.solve_pool` over a `data` mesh. Batches are
     shape-bucketed by the QAOA config: every dispatch in a bucket uses
     exactly ``batch_slots`` rows padded to the qubit budget's edge
     capacity N·(N−1)/2 — the maximum a ≤N-vertex subgraph can carry —
     so a bucket compiles exactly once no matter how request sizes mix.
     Dispatch is *asynchronous*: jax returns unmaterialized device
     results, so up to ``max_inflight`` batches overlap with admission
     and with each other; the loop only blocks when it harvests the
     oldest in-flight batch. Everything stays a deterministic
     single-thread event loop — "concurrent" means many admitted
     requests and in-flight batches, never racing threads;
  3. per-request completion tracking (mirroring `serving/engine.py`'s done
     mask, here a remaining-subgraph count) fires the merge stage the
     moment a request's last candidate lands: the default path runs
     `core.paraqaoa.merge_candidates` — the *same* merge `core.solve`
     runs, which together with the per-row bit-stability of the batched
     solver makes service cuts bit-identical to solo `solve` runs on the
     same knobs — while streaming requests run the anytime
     `core.merge.merge_stream` and surface the best-known cut after every
     merge level (§6.4).

Multi-tenant fairness (§6.5): when a bucket holds more waiting subgraphs
than one dispatch can take, slots are filled round-robin across tenants
(optionally capped per tenant under contention), and any bucket whose
oldest item has waited ``max_wait_dispatches`` dispatches pre-empts the
fullest-bucket heuristic — so no request starves behind a heavier
tenant's traffic (bounded-delay property, tests/test_service_stress.py).

Served-request stage timings stream back into the planner's cost model
(`Planner.observe_*`, §6.5) so knob selection tracks the hardware the
service actually runs on, not the shipped benchmark fit.

Deadline enforcement (§6.6): every clock read goes through one injected
time source (``SolveService(clock=...)``, default `time.perf_counter` —
a `workload.VirtualClock` makes whole soaks bit-deterministic). A
request's deadline becomes an absolute clock stamp at submission;
admission plans against the *residual* budget and sheds outright when
even the floor plan (`Planner.floor_predicted`) is predicted late. Each
`pump` tick then re-scores queued-but-undispatched requests against
their remaining budget with the live (recalibrated) cost model:
`Planner.replan` keeps, downgrades (re-partition at the cheaper knobs —
never below the request's declared `SLA.floor_quality`), or clamps to
the floor plan. Once admitted, a request is never shed on a prediction
alone — predictions drift with recalibration; it is dropped (terminal
state ``"expired"``) only when its deadline has actually passed before
any of its subgraphs dispatched. Every request therefore reaches exactly
one terminal state — completed / shed / expired — and `ServiceStats`
carries exact per-tenant attainment, shed, and downgrade accounting
(tests/test_service_sla.py).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import paraqaoa as para_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, Problem, as_problem, problem_value
from repro.core.partition import partition_for_solver, split_linear
from repro.obs import trace as trace_mod
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import Span, Tracer
from repro.service.backend import make_backend
from repro.service.cache import ResultCache
from repro.service.canonical import canonical_form
from repro.service.planner import SLA, KnobPlan, Planner, quality_score


def edge_capacity(n_qubits: int) -> int:
    """Max simple-edge count of a subgraph that fits an N-qubit solver."""
    return max(n_qubits * (n_qubits - 1) // 2, 1)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    batch_slots: int = 16  # fixed rows per solver dispatch (one shape/bucket)
    cache_capacity: int = 256
    enable_cache: bool = True
    max_qubits: int = 12  # hardware budget cap handed to the planner
    anytime_min_levels: int = 2  # stream only when the merge has >1 level
    # §6.5 backend: None → single-device program; a mesh spec (string /
    # dict / Mesh) routes batches through solve_pool over its data axes
    mesh: object = None
    # §6.5 async admission loop
    max_inflight: int = 2  # dispatched-but-unharvested batches
    max_wait_dispatches: int = 4  # anti-starvation pre-emption bound
    tenant_max_slots: int | None = None  # per-tenant slot cap under contention
    # §6.5 online recalibration: stream stage timings into the planner
    recalibrate: bool = True
    # §6.6 wall-clock SLA enforcement: shed predicted-late requests at
    # admission, re-score queued requests every tick (downgrade toward
    # the accuracy floor), and expire requests whose deadline passes
    # before dispatch. Off = the pre-§6.6 load-driven behavior (the
    # throughput-parity benches pin it off: a shed request has no cut to
    # compare)
    enforce_deadlines: bool = True


@dataclasses.dataclass
class RequestResult:
    request_id: int
    assignment: np.ndarray  # None for shed/expired requests
    cut_value: float  # nan for shed/expired requests
    cached: bool
    plan: KnobPlan
    latency_s: float
    timings: dict
    anytime: list  # [(level, n_levels, best_known_cut)] for streamed requests
    tenant: str = "default"
    dispatches_waited: int = 0  # dispatches between admission and completion
    # §6.6 terminal state: "completed" | "shed" | "expired" — exactly one
    # per submitted request
    status: str = "completed"
    # None for undeadlined requests; else whether the deadline was met
    # (False for shed/expired)
    deadline_met: bool | None = None
    downgrades: int = 0  # deadline re-plans applied before completion


class _Request:
    def __init__(self, rid, prob, sla, plan, cfg, stream, on_update, form,
                 tenant, submit_t, deadline_t=None):
        self.id = rid
        self.prob = prob  # the full Problem (graph + linear + offset)
        self.graph = prob.graph
        self.has_lin = prob.has_linear
        self.sub_lins = None  # per-subgraph linear terms, when has_lin
        self.sla = sla
        self.plan = plan
        self.cfg = cfg  # ParaQAOAConfig derived from plan.knobs
        self.stream = stream
        self.on_update = on_update
        self.form = form  # canonical form, when the cache is enabled
        self.tenant = tenant
        self.submit_t = submit_t
        self.deadline_t = deadline_t  # absolute clock stamp, or None
        self.part = None
        self.bit_indices = None  # (M, K) int64
        self.remaining = 0
        self.solve_done_t = None
        self.admit_dispatch = 0  # stats.dispatches at admission
        self.started = False  # any subgraph dispatched (re-plan barrier)
        self.downgrades = 0  # §6.6 deadline re-plans applied


class _Item:
    """One queued subgraph: request, its subgraph index, enqueue stamp."""

    __slots__ = ("req", "idx", "enq_dispatch")

    def __init__(self, req, idx, enq_dispatch):
        self.req = req
        self.idx = idx
        self.enq_dispatch = enq_dispatch


class _Batch:
    """One dispatched (possibly still in-flight) solver batch."""

    __slots__ = ("qcfg", "items", "result", "t_issue", "span")

    def __init__(self, qcfg, items, result, t_issue, span=None):
        self.qcfg = qcfg
        self.items = items
        self.result = result  # unmaterialized device arrays
        self.t_issue = t_issue
        self.span = span  # §8 dispatch span, open until harvest


class _SLACounters:
    """§6.6 terminal-state + attainment accounting, shared by the global
    and per-tenant stats so the two cannot drift apart structurally.

    Every submitted request lands in exactly one terminal bucket —
    ``completed`` / ``shed`` / ``expired`` — so attainment denominators
    are exact (the latent pre-§6.6 gap: stats were recorded only for
    completed requests). Among *deadlined* requests, ``sla_met`` /
    ``sla_missed`` split the completed bucket; undeadlined completions
    count in neither. Attainment is met-over-all-deadlined — shed and
    expired requests count against it.
    """

    @property
    def terminal(self) -> int:
        return self.completed + self.shed + self.expired

    @property
    def deadlined(self) -> int:
        return self.sla_met + self.sla_missed + self.shed + self.expired

    @property
    def attainment(self) -> float:
        d = self.deadlined
        return self.sla_met / d if d else 1.0


def _counter_fields(obj) -> list[str]:
    """The plain-count dataclass fields of a stats object — everything
    except the latency `Histogram` and the per-tenant sub-dict."""
    return [
        f.name for f in dataclasses.fields(obj)
        if f.name not in ("latency", "tenants")
    ]


@dataclasses.dataclass
class TenantStats(_SLACounters):
    submitted: int = 0
    completed: int = 0
    cache_served: int = 0
    slots: int = 0  # solver slots this tenant's subgraphs occupied
    shed: int = 0  # predicted-late at admission, never enqueued
    expired: int = 0  # deadline passed while queued, dropped
    downgraded: int = 0  # completed after >= 1 deadline re-plan
    sla_met: int = 0  # completed within the deadline
    sla_missed: int = 0  # completed, but late
    # §8: completed-request latency distribution (exact p50/p99) — lives
    # in the stats object itself so benches and exports stop
    # reconstructing it from the results dict
    latency: Histogram = dataclasses.field(default_factory=Histogram)

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in _counter_fields(self)}
        d["latency"] = self.latency.summary()
        d["attainment"] = round(self.attainment, 4)
        return d

    # §8: checkpoint-style round-trip — the histogram's raw samples
    # travel with the counters, so restored stats keep exact percentiles
    def snapshot(self) -> dict:
        d = {f: getattr(self, f) for f in _counter_fields(self)}
        d["latency"] = self.latency.snapshot()
        return d

    @classmethod
    def restore(cls, state: dict) -> "TenantStats":
        ts = cls(**{f: state[f] for f in state if f != "latency"})
        ts.latency = Histogram.restore(state["latency"])
        return ts


@dataclasses.dataclass
class ServiceStats(_SLACounters):
    dispatches: int = 0
    slots_total: int = 0
    slots_filled: int = 0
    completed: int = 0
    cache_served: int = 0
    admitted: int = 0
    preemptions: int = 0  # anti-starvation bucket picks
    max_inflight_seen: int = 0
    shed: int = 0
    expired: int = 0
    downgraded: int = 0  # requests completed after >= 1 downgrade
    downgrade_events: int = 0  # individual deadline re-plans applied
    sla_met: int = 0
    sla_missed: int = 0
    latency: Histogram = dataclasses.field(default_factory=Histogram)
    tenants: dict = dataclasses.field(default_factory=dict)

    def tenant(self, name: str) -> TenantStats:
        if name not in self.tenants:
            self.tenants[name] = TenantStats()
        return self.tenants[name]

    @property
    def fill_ratio(self) -> float:
        return self.slots_filled / self.slots_total if self.slots_total else 0.0

    def as_dict(self) -> dict:
        return {
            "dispatches": self.dispatches,
            "slots_total": self.slots_total,
            "slots_filled": self.slots_filled,
            "fill_ratio": round(self.fill_ratio, 4),
            "completed": self.completed,
            "cache_served": self.cache_served,
            "admitted": self.admitted,
            "preemptions": self.preemptions,
            "max_inflight_seen": self.max_inflight_seen,
            "shed": self.shed,
            "expired": self.expired,
            "downgraded": self.downgraded,
            "downgrade_events": self.downgrade_events,
            "sla_met": self.sla_met,
            "sla_missed": self.sla_missed,
            "latency": self.latency.summary(),
            "attainment": round(self.attainment, 4),
            "tenants": {t: s.as_dict() for t, s in self.tenants.items()},
        }

    def snapshot(self) -> dict:
        d = {f: getattr(self, f) for f in _counter_fields(self)}
        d["latency"] = self.latency.snapshot()
        d["tenants"] = {t: s.snapshot() for t, s in self.tenants.items()}
        return d

    @classmethod
    def restore(cls, state: dict) -> "ServiceStats":
        s = cls(**{
            f: state[f] for f in state if f not in ("latency", "tenants")
        })
        s.latency = Histogram.restore(state["latency"])
        s.tenants = {
            t: TenantStats.restore(ts) for t, ts in state["tenants"].items()
        }
        return s


class SolveService:
    """Batched Max-Cut solve service over the ParaQAOA pipeline."""

    def __init__(
        self,
        config: ServiceConfig = ServiceConfig(),
        planner: Planner | None = None,
        cache: ResultCache | None = None,
        backend=None,
        clock: Callable[[], float] | None = None,
        tracer: Tracer | None = None,
    ):
        self.config = config
        # §6.6: the single time source every deadline decision and every
        # latency/observability stamp reads. Injecting a
        # `workload.VirtualClock` makes a whole soak bit-deterministic;
        # the default is the same monotonic clock as before
        self._clock = clock if clock is not None else time.perf_counter
        # §8: the span tracer every lifecycle/stage stamp goes through.
        # The default records nothing (tracing off); a driver passing its
        # own `Tracer(record=True)` must construct it over this same
        # clock or span nesting/determinism guarantees break
        self.trace = tracer if tracer is not None else Tracer(
            clock=self._clock
        )
        # open per-request root spans: rid → Span, ended exactly once at
        # the request's terminal state (completed / shed / expired)
        self._req_spans: dict[int, Span] = {}
        self.planner = planner or Planner(
            max_qubits=config.max_qubits, batch_slots=config.batch_slots
        )
        self.cache = cache or ResultCache(config.cache_capacity)
        self.backend = backend or make_backend(config.mesh)
        self.stats = ServiceStats()
        self.results: "OrderedDict[int, RequestResult]" = OrderedDict()
        self._next_id = 0
        self._active: dict[int, _Request] = {}
        # admission queue: submitted-but-not-admitted requests, drained by
        # `submit` (eager default) or at the top of every `pump` tick
        self._admission: deque = deque()
        # bucket key: (frozen QAOAConfig, has-linear-terms) — one compiled
        # program and one queue per static solver configuration; linear
        # (QUBO/MIS) batches carry a 4th input array so they can never
        # share a compiled shape with pure Max-Cut batches
        self._buckets: "OrderedDict[tuple, deque]" = OrderedDict()
        # dispatched batches whose device results have not landed yet
        self._inflight: "deque[_Batch]" = deque()
        self._last_harvest_t = 0.0  # de-queues solve-time observations
        # in-flight dedup: canonical key → (primary request id, its quality);
        # isomorphic requests admitted while their twin is still solving
        # coalesce onto it and are served from cache when it completes
        self._inflight_forms: dict[str, tuple[int, float]] = {}
        self._followers: dict[str, list] = {}

    # ------------------------------------------------------------- admit --
    def submit(
        self,
        graph: Graph | Problem,
        sla: SLA = SLA(),
        stream: bool = False,
        on_update: Optional[Callable] = None,
        tenant: str = "default",
        defer: bool = False,
    ) -> int:
        """Place one solve request on the admission queue; returns its id.

        ``graph`` may be a plain `Graph` (Max-Cut) or a `core.graph.Problem`
        (weighted Max-Cut / QUBO / MIS): linear terms ride through the
        shape buckets (keyed on (config, has-linear) so mixed traffic never
        recompiles), the backend dispatch, and the merge; the result's
        ``cut_value`` is the full objective including the constant offset.

        With ``defer=False`` (default) admission happens before `submit`
        returns: cache hits complete immediately (the result is visible
        in `results` on return); misses enqueue the request's subgraphs
        into the shared batch queues. ``defer=True`` guarantees only
        that *this call* does no admission work — the request waits on
        the admission queue until the next `pump` tick or the next eager
        `submit`, whichever drains the (strictly FIFO) queue first; the
        interleaved-arrival shape of a live frontend, where requests
        land while earlier batches are still in flight. Either way, call
        `pump`/`drain` to make progress.
        """
        rid = self._next_id
        self._next_id += 1
        self.stats.tenant(tenant).submitted += 1
        # §8: the request's root span opens at submission and closes at
        # its terminal state — parentless even when submitted from
        # inside another request's streaming callback
        self._req_spans[rid] = self.trace.begin(
            "request", parent=trace_mod.ROOT, rid=rid, tenant=tenant
        )
        self._admission.append(
            (rid, graph, sla, stream, on_update, tenant, self._clock())
        )
        if not defer:
            self._process_admissions()
        return rid

    def _budget(self, sla: SLA, t0: float, now: float) -> float | None:
        """Residual wall-clock budget, or None for undeadlined requests."""
        if sla.deadline_s is None:
            return None
        return t0 + sla.deadline_s - now

    def _process_admissions(self) -> None:
        while self._admission:
            rid, graph, sla, stream, on_update, tenant, t0 = (
                self._admission.popleft()
            )
            prob = as_problem(graph)
            graph = prob.graph
            self.stats.admitted += 1
            # §6.6: plan against the budget *remaining now* — a deferred
            # request that waited on the admission queue plans (and is
            # shed-checked) at its shrunken residual deadline
            now = self._clock()
            budget = self._budget(sla, t0, now)
            eff_sla = sla if budget is None else dataclasses.replace(
                sla, deadline_s=max(budget, 0.0)
            )
            # §8: the admission span covers plan + cache lookup and is
            # closed *before* any terminal verdict is recorded, so a
            # cache-hit/shed root span never ends inside a still-open
            # child
            root = self._req_spans.get(rid)
            adm = self.trace.begin("admission", parent=root)
            with self.trace.attach(adm):
                with self.trace.span("plan"):
                    plan = self.planner.plan(graph.n, graph.n_edges, eff_sla)
                form = None
                hit = None
                if self.config.enable_cache:
                    form = canonical_form(prob)
                    with self.trace.span("cache_lookup"):
                        hit = self.cache.lookup(
                            prob, form=form, min_quality=plan.quality
                        )
            self.trace.end(adm, cache_hit=hit is not None)
            if hit is not None:
                assignment, cut = hit
                self._record_cached(
                    rid, prob, plan, assignment, cut, t0,
                    stream=stream, on_update=on_update, tenant=tenant,
                    deadline_t=None if sla.deadline_s is None
                    else t0 + sla.deadline_s,
                )
                continue
            # shed verdict before any work is enqueued (but after the
            # cache: a hit completes instantly, predicted-late or not)
            if self._shed_if_floor_late(rid, graph, sla, plan, budget, t0,
                                        tenant):
                continue
            if form is not None:
                # coalesce onto an in-flight isomorphic twin of sufficient
                # quality: no work enqueued; served from cache at its merge.
                # Streaming requests bypass dedup — they want per-level
                # updates.
                primary = self._inflight_forms.get(form.key)
                if primary is not None and primary[1] >= plan.quality and not stream:
                    self._followers.setdefault(form.key, []).append(
                        (rid, prob, sla, plan, form, t0, tenant)
                    )
                    continue

            self._admit(rid, prob, sla, plan, form, stream, on_update,
                        tenant, t0)

    def _shed_if_floor_late(self, rid, graph, sla, plan, budget, t0,
                            tenant) -> bool:
        """§6.6 admission verdict: True (and a recorded ``"shed"``
        terminal) when even the floor plan is predicted to miss the
        residual budget."""
        if (not self.config.enforce_deadlines) or budget is None:
            return False
        graph = as_problem(graph).graph
        floor = self.planner.floor_predicted(
            graph.n, graph.n_edges, sla.floor_quality
        )
        floor_s = floor[1].total_s if floor is not None else float("inf")
        if floor_s <= budget:
            return False
        self._record_dropped(rid, plan, t0, tenant, "shed",
                             predicted_floor_s=floor_s, budget_s=budget)
        return True

    def _admit(self, rid, graph, sla, plan, form, stream, on_update,
               tenant="default", t0=None) -> None:
        """Enqueue a request's subgraphs into its shape bucket."""
        prob = as_problem(graph)
        kn = plan.knobs
        cfg = plan.to_config()
        if t0 is None:
            t0 = self._clock()
        deadline_t = None if sla.deadline_s is None else t0 + sla.deadline_s
        req = _Request(rid, prob, sla, plan, cfg, stream, on_update, form,
                       tenant, t0, deadline_t)
        graph = req.graph
        ps = self.trace.begin(
            "partition", parent=self._req_spans.get(rid),
            n=graph.n, n_edges=graph.n_edges, n_qubits=kn.n_qubits,
        )
        req.part = partition_for_solver(graph, kn.n_qubits)
        if req.has_lin:
            req.sub_lins = split_linear(req.part, prob.linear)
        self.trace.end(ps, m=req.part.m)
        self._observe(ps)
        req.bit_indices = np.zeros((req.part.m, kn.top_k), dtype=np.int64)
        req.remaining = req.part.m
        req.admit_dispatch = self.stats.dispatches
        self._active[rid] = req
        if form is not None and form.key not in self._inflight_forms:
            self._inflight_forms[form.key] = (rid, plan.quality)

        queue = self._buckets.setdefault((cfg.qaoa_config(), req.has_lin),
                                         deque())
        for idx in range(req.part.m):
            queue.append(_Item(req, idx, self.stats.dispatches))

    def _record_cached(
        self, rid, graph, plan, assignment, cut, t0,
        stream=False, on_update=None, tenant="default", deadline_t=None,
    ) -> None:
        # a streamed request served from cache still gets its anytime
        # contract: one final update (the answer is complete immediately)
        anytime = [(1, 1, cut)] if stream else []
        if stream and on_update is not None:
            on_update(rid, 1, 1, cut)
        now = self._clock()
        met = None if deadline_t is None else bool(now <= deadline_t)
        self.results[rid] = RequestResult(
            request_id=rid,
            assignment=assignment,
            cut_value=cut,
            cached=True,
            plan=plan,
            latency_s=now - t0,
            timings={"cache_s": now - t0},
            anytime=anytime,
            tenant=tenant,
            deadline_met=met,
        )
        self.stats.completed += 1
        self.stats.cache_served += 1
        ts = self.stats.tenant(tenant)
        ts.completed += 1
        ts.cache_served += 1
        self._count_deadline(met, ts)
        self.stats.latency.observe(now - t0)
        ts.latency.observe(now - t0)
        self._end_request_span(rid, "completed", cached=True)

    def _count_deadline(self, met: bool | None, ts: TenantStats) -> None:
        if met is None:
            return
        field = "sla_met" if met else "sla_missed"
        setattr(self.stats, field, getattr(self.stats, field) + 1)
        setattr(ts, field, getattr(ts, field) + 1)

    def _record_dropped(self, rid, plan, t0, tenant, status, *,
                        predicted_floor_s=None, budget_s=None) -> None:
        """§6.6 non-served terminal states: ``"shed"`` (admission verdict
        — even the floor plan predicted late) and ``"expired"`` (deadline
        passed while queued). The recorded timings carry the verdict's
        evidence so tests can assert shed ⇒ floor-predicted-late."""
        now = self._clock()
        timings = {"verdict_s": now - t0}
        if predicted_floor_s is not None:
            timings["predicted_floor_s"] = predicted_floor_s
            timings["budget_s"] = budget_s
        self.results[rid] = RequestResult(
            request_id=rid,
            assignment=None,
            cut_value=float("nan"),
            cached=False,
            plan=plan,
            latency_s=now - t0,
            timings=timings,
            anytime=[],
            tenant=tenant,
            status=status,
            deadline_met=False,
        )
        ts = self.stats.tenant(tenant)
        setattr(self.stats, status, getattr(self.stats, status) + 1)
        setattr(ts, status, getattr(ts, status) + 1)
        self._end_request_span(rid, status)

    def _end_request_span(self, rid: int, status: str, **attrs) -> None:
        """§8: close the request's root span at its terminal state — the
        pop guarantees exactly one terminal span per submitted request
        (the reconciliation invariant in tests/test_obs.py)."""
        root = self._req_spans.pop(rid, None)
        if root is not None:
            self.trace.end(root, status=status, **attrs)

    def _observe(self, span: Span) -> None:
        """§6.5 recalibration via the §8 span stream: stage spans carry
        their observation payload in their attrs, and the planner's
        `observe_span` dispatches on the span name. Duck-typed planners
        without `observe_span` fall back to the legacy per-stage hooks."""
        if not self.config.recalibrate:
            return
        observe = getattr(self.planner, "observe_span", None)
        if observe is not None:
            observe(span)
            return
        a = span.attrs
        if span.name == "partition":
            fn = getattr(self.planner, "observe_partition", None)
            if fn is not None:
                fn(a["n"], a["n_edges"], span.duration_s)
        elif span.name == "solve":
            fn = getattr(self.planner, "observe_solve", None)
            if fn is not None:
                fn(a["n_qubits"], a["p_layers"], a["opt_steps"], a["slots"],
                   span.duration_s)
        elif span.name == "merge":
            fn = getattr(self.planner, "observe_merge", None)
            if fn is not None:
                fn(a["knobs"], a["m"], a["n_edges"], span.duration_s)

    # --------------------------------------------------------- dispatch --
    def _pick_bucket(self):
        """The bucket to dispatch next: the fullest — unless some queue's
        head item has waited ``max_wait_dispatches`` dispatches, in which
        case the queue with the oldest head pre-empts (the bounded-delay
        guarantee of DESIGN.md §6.5)."""
        live = [(key, q) for key, q in self._buckets.items() if q]
        if not live:
            return None
        fullest = max(live, key=lambda b: len(b[1]))
        bound = self.config.max_wait_dispatches
        overdue = [
            (key, q) for key, q in live
            if self.stats.dispatches - q[0].enq_dispatch >= bound
        ]
        if overdue:
            choice = min(overdue, key=lambda b: b[1][0].enq_dispatch)
            if choice[0] is not fullest[0]:  # an actual pre-emption, not
                self.stats.preemptions += 1  # the pick it would get anyway
            return choice
        return fullest

    def _take_items(self, queue: deque) -> list:
        """Pop up to ``batch_slots`` items, round-robin across tenants.

        With a single tenant (or a queue that fits one dispatch) this is
        plain FIFO. Under contention, slots interleave tenants in
        arrival order of each tenant's oldest item, optionally capped at
        ``tenant_max_slots`` per tenant so one heavy tenant cannot fill
        the whole dispatch while others wait. The quota is
        work-conserving: once every tenant with queued items has had its
        capped share, leftover slots fill round-robin anyway — padding
        rows cost the same as filled ones, so idling capacity would only
        delay the capped tenant without helping anyone.
        """
        slots = self.config.batch_slots
        if len(queue) <= slots:
            items = list(queue)
            queue.clear()
            return items
        by_tenant: "OrderedDict[str, deque]" = OrderedDict()
        for it in queue:
            by_tenant.setdefault(it.req.tenant, deque()).append(it)
        cap = self.config.tenant_max_slots
        if cap is None or len(by_tenant) <= 1:
            cap = slots
        cap = max(cap, 1)  # a 0/negative quota must still make progress
        picked, taken = [], {t: 0 for t in by_tenant}
        while len(picked) < slots and by_tenant:
            progressed = False
            for t in list(by_tenant):
                if len(picked) == slots:
                    break
                if taken[t] >= cap:
                    continue
                picked.append(by_tenant[t].popleft())
                taken[t] += 1
                progressed = True
                if not by_tenant[t]:
                    del by_tenant[t]
            if not progressed:
                # every waiting tenant got its capped share: fill the
                # leftover slots rather than dispatch empty rows
                cap = slots
        chosen = set(map(id, picked))
        remaining = [it for it in queue if id(it) not in chosen]
        queue.clear()
        queue.extend(remaining)
        return picked

    def _dispatch_one(self) -> bool:
        """Issue one cross-request batch to the backend (non-blocking)."""
        bucket = self._pick_bucket()
        if bucket is None:
            return False
        (qcfg, has_lin), queue = bucket
        slots = self.config.batch_slots
        items = self._take_items(queue)

        edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
            [it.req.part.subgraphs[it.idx] for it in items],
            qcfg.n_qubits,
            e_pad=edge_capacity(qcfg.n_qubits),
            n_rows=slots,
        )
        linears = None
        if has_lin:
            linears = qaoa_mod.pad_linear_arrays(
                [it.req.sub_lins[it.idx] for it in items],
                qcfg.n_qubits,
                n_rows=slots,
            )
        # §8: one dispatch span per issued batch, open until its harvest
        # (requests it carries are listed in attrs — batches cross
        # request and tenant boundaries, so the span cannot nest under
        # any single request root)
        ds = self.trace.begin(
            "dispatch", parent=trace_mod.ROOT,
            n_qubits=qcfg.n_qubits, slots=slots, filled=len(items),
            rids=sorted({it.req.id for it in items}),
        )
        res = self.backend.solve_batch(qcfg, edges, weights, masks,
                                       linears=linears)
        self._inflight.append(_Batch(qcfg, items, res, self._clock(), ds))
        for it in items:
            it.req.started = True  # §6.6: committed — no more re-plans

        self.stats.dispatches += 1
        self.stats.slots_total += slots
        self.stats.slots_filled += len(items)
        self.stats.max_inflight_seen = max(
            self.stats.max_inflight_seen, len(self._inflight)
        )
        for it in items:
            self.stats.tenant(it.req.tenant).slots += 1
        return True

    def _harvest_one(self) -> None:
        """Land the oldest in-flight batch (blocks) and run any merges it
        unblocks."""
        batch = self._inflight.popleft()
        bitstrings = np.asarray(batch.result.bitstrings)  # blocks here
        t_land = self._clock()
        # §8: the solve span is retroactive — the device runs batches
        # serially, so this batch's compute window starts when the
        # previous harvest ended, not at issue time, which would bill it
        # for the whole in-flight queue ahead of it and inflate c_solve
        # ~max_inflight-fold
        t_start = max(batch.t_issue, self._last_harvest_t)
        solve_span = self.trace.span_at(
            "solve", t_start, t_land, parent=batch.span,
            n_qubits=batch.qcfg.n_qubits, p_layers=batch.qcfg.p_layers,
            opt_steps=batch.qcfg.opt_steps, slots=self.config.batch_slots,
        )
        self._observe(solve_span)
        if batch.span is not None:
            self.trace.end(batch.span)
        self._last_harvest_t = t_land

        done_requests = []
        for slot, it in enumerate(batch.items):
            it.req.bit_indices[it.idx] = bitstrings[slot]
            it.req.remaining -= 1
            if it.req.remaining == 0:
                done_requests.append(it.req)
        for req in done_requests:
            req.solve_done_t = self._clock()
            self._merge(req)

    # --------------------------------------------------- §6.6 re-scoring --
    def _rescore_queued(self) -> None:
        """§6.6: one deadline pass over queued-but-undispatched requests.

        Expired deadlines drop the request (terminal ``"expired"``);
        otherwise `Planner.replan` re-scores the residual budget against
        the live (possibly recalibrated) cost model — keep, downgrade to
        the cheapest floor-meeting plan, or — on a shed verdict for an
        *already admitted* request — clamp to the floor plan instead of
        shedding: predictions drift with recalibration, so admission is
        the only place a prediction alone may reject work
        (tests/test_service_stress.py's recalibration-under-load case).
        Requests with any subgraph dispatched are committed (work would
        be discarded) and complete at their admitted knobs.
        """
        if not self.config.enforce_deadlines:
            return
        now = self._clock()
        for req in list(self._active.values()):
            if req.deadline_t is None or req.started:
                continue
            budget = req.deadline_t - now
            if budget <= 0.0:
                self._expire(req)
                continue
            decision = self.planner.replan(
                req.graph.n, req.graph.n_edges, budget, req.plan,
                floor_quality=req.sla.floor_quality,
            )
            if decision.verdict == "keep":
                continue
            if decision.verdict == "downgrade":
                self._apply_downgrade(req, decision.plan)
                continue
            # shed verdict post-admission: clamp to the floor plan (the
            # cheapest floor-meeting tuple) rather than retroactively shed
            floor = self.planner.floor_predicted(
                req.graph.n, req.graph.n_edges, req.sla.floor_quality
            )
            if floor is not None and floor[0] != req.plan.knobs:
                kn, pred = floor
                plan = KnobPlan(
                    knobs=kn,
                    merge_level=req.plan.merge_level,
                    predicted=pred,
                    quality=quality_score(kn),
                    meets_deadline=False,
                    meets_quality=req.sla.floor_quality is None
                    or quality_score(kn) >= req.sla.floor_quality - 1e-12,
                )
                self._apply_downgrade(req, plan)

    def _apply_downgrade(self, req: _Request, plan: KnobPlan) -> None:
        """Re-plan one queued request to cheaper knobs: pull its items
        from the old shape bucket, re-partition at the new qubit budget,
        and enqueue into the new bucket. Only legal before any of its
        subgraphs dispatched (`req.started` guards)."""
        old_key = (req.cfg.qaoa_config(), req.has_lin)
        queue = self._buckets.get(old_key)
        if queue is not None:
            keep = [it for it in queue if it.req is not req]
            queue.clear()
            queue.extend(keep)
        req.plan = plan
        req.cfg = plan.to_config()
        req.part = partition_for_solver(req.graph, plan.knobs.n_qubits)
        if req.has_lin:
            # re-partitioning moves range boundaries: the per-subgraph
            # linear split must follow the new first-coverage assignment
            req.sub_lins = split_linear(req.part, req.prob.linear)
        req.bit_indices = np.zeros(
            (req.part.m, plan.knobs.top_k), dtype=np.int64
        )
        req.remaining = req.part.m
        req.downgrades += 1
        self.stats.downgrade_events += 1
        # §8: a replan is an instant event — a zero-width span marks it
        # in the request's tree with the knobs it moved to
        t = self._clock()
        self.trace.span_at(
            "replan", t, t, parent=self._req_spans.get(req.id),
            verdict="downgrade", n_qubits=plan.knobs.n_qubits,
            m=req.part.m,
        )
        # new twins must not coalesce onto a primary that now plans
        # cheaper than they require
        if req.form is not None:
            primary = self._inflight_forms.get(req.form.key)
            if primary is not None and primary[0] == req.id:
                self._inflight_forms[req.form.key] = (req.id, plan.quality)
        new_queue = self._buckets.setdefault(
            (req.cfg.qaoa_config(), req.has_lin), deque()
        )
        for idx in range(req.part.m):
            new_queue.append(_Item(req, idx, self.stats.dispatches))

    def _expire(self, req: _Request) -> None:
        """Drop one queued request whose deadline passed before dispatch
        (terminal ``"expired"``), and release its coalesced followers
        back through admission-style re-scoring."""
        queue = self._buckets.get((req.cfg.qaoa_config(), req.has_lin))
        if queue is not None:
            keep = [it for it in queue if it.req is not req]
            queue.clear()
            queue.extend(keep)
        self._record_dropped(req.id, req.plan, req.submit_t, req.tenant,
                             "expired")
        del self._active[req.id]
        if req.form is not None:
            primary = self._inflight_forms.get(req.form.key)
            if primary is not None and primary[0] == req.id:
                self._inflight_forms.pop(req.form.key, None)
            for frid, g, sla, plan, form, t0, tenant in self._followers.pop(
                req.form.key, []
            ):
                budget = self._budget(sla, t0, self._clock())
                if not self._shed_if_floor_late(frid, g, sla, plan, budget,
                                                t0, tenant):
                    self._admit(frid, g, sla, plan, form, False, None,
                                tenant=tenant, t0=t0)

    # ------------------------------------------------------------- solve --
    def pump(self) -> bool:
        """One deterministic event-loop tick: drain the admission queue,
        re-score queued requests against their residual deadlines (§6.6:
        downgrade / expire before dispatch), fill the dispatch window (up
        to ``max_inflight`` batches issued without blocking), then
        harvest the oldest in-flight batch and run any merges it
        unblocks. Returns True while work remains."""
        self._process_admissions()
        self._rescore_queued()
        window = max(self.config.max_inflight, 1)  # 0 would never dispatch
        while len(self._inflight) < window:
            if not self._dispatch_one():
                break
        if self._inflight:
            self._harvest_one()
        return bool(
            self._inflight
            or self._admission
            or any(self._buckets.values())
        )

    def drain(self) -> "OrderedDict[int, RequestResult]":
        """Run the scheduler until every admitted request has a result."""
        while self.pump():
            pass
        return self.results

    # ----------------------------------------------------------- metrics --
    def metrics_registry(self) -> MetricsRegistry:
        """§8: the service's stats as a `MetricsRegistry` — counters and
        gauges copied at call time, latency histograms attached live —
        for JSON / Prometheus export (`serve_maxcut --metrics-out`)."""
        reg = MetricsRegistry()
        s = self.stats
        for f in _counter_fields(s):
            reg.counter(f"service.{f}").inc(getattr(s, f))
        reg.gauge("service.fill_ratio").set(s.fill_ratio)
        reg.gauge("service.attainment").set(s.attainment)
        reg.gauge("service.inflight").set(len(self._inflight))
        reg.attach_histogram("service.latency", s.latency)
        for t, ts in s.tenants.items():
            for f in ("submitted", "completed", "shed", "expired",
                      "sla_met", "sla_missed"):
                reg.counter(f"tenant.{t}.{f}").inc(getattr(ts, f))
            reg.attach_histogram(f"tenant.{t}.latency", ts.latency)
        return reg

    # ------------------------------------------------------------- merge --
    def _merge(self, req: _Request) -> None:
        anytime: list = []
        # §8: the merge span carries the observe_merge payload in its
        # attrs; installing the service tracer globally + attaching the
        # span parents `core.merge.merge_stream`'s per-level spans under
        # it without threading tracer arguments through the core API
        ms = self.trace.begin(
            "merge", parent=self._req_spans.get(req.id),
            knobs=req.plan.knobs, m=req.part.m, n_edges=req.graph.n_edges,
        )
        lin = req.prob.linear if req.has_lin else None
        with trace_mod.use_tracer(self.trace), self.trace.attach(ms):
            if req.stream and req.part.m >= self.config.anytime_min_levels:
                plan, bw = para_mod.merge_inputs(
                    req.part, req.bit_indices, req.cfg, linear=lin
                )
                best_cut, best_assign = -np.inf, None
                for snap in merge_mod.merge_stream(plan, bw):
                    # the stream scores the internal objective; surface
                    # the full one (offset is exactly 0.0 for Max-Cut)
                    val = snap.cut_value + req.prob.offset
                    if val > best_cut:
                        best_cut, best_assign = val, snap.assignment
                    anytime.append((snap.level, snap.n_levels, best_cut))
                    if req.on_update is not None:
                        req.on_update(req.id, snap.level, snap.n_levels,
                                      best_cut)
                assignment = best_assign
            else:
                assignment, _, _ = para_mod.merge_candidates(
                    req.part, req.bit_indices, req.cfg, linear=lin
                )
            # final re-score from scratch, exactly as core.solve reconciles
            # — the *full* objective, so a QUBO/MIS result and its cached
            # replay can never disagree on the linear part
            cut = float(problem_value(req.prob, jnp.asarray(assignment)))
        self.trace.end(ms)
        self._observe(ms)
        if req.stream and not anytime:
            # single-level merges skip the stream; still honor the anytime
            # contract with one final update
            anytime.append((1, 1, cut))
            if req.on_update is not None:
                req.on_update(req.id, 1, 1, cut)

        now = self._clock()
        if self.config.enable_cache:
            self.cache.store(
                req.prob,
                assignment,
                cut,
                quality=req.plan.quality,
                form=req.form,
            )
        met = None if req.deadline_t is None else bool(now <= req.deadline_t)
        self.results[req.id] = RequestResult(
            request_id=req.id,
            assignment=np.asarray(assignment),
            cut_value=cut,
            cached=False,
            plan=req.plan,
            latency_s=now - req.submit_t,
            timings={
                "solve_s": req.solve_done_t - req.submit_t,
                "merge_s": now - req.solve_done_t,
                "total_s": now - req.submit_t,
            },
            anytime=anytime,
            tenant=req.tenant,
            dispatches_waited=self.stats.dispatches - req.admit_dispatch,
            deadline_met=met,
            downgrades=req.downgrades,
        )
        self.stats.completed += 1
        ts = self.stats.tenant(req.tenant)
        ts.completed += 1
        self._count_deadline(met, ts)
        self.stats.latency.observe(now - req.submit_t)
        ts.latency.observe(now - req.submit_t)
        if req.downgrades:
            self.stats.downgraded += 1
            ts.downgraded += 1
        self._end_request_span(req.id, "completed", cached=False)
        del self._active[req.id]

        # serve coalesced isomorphic followers from the just-stored entry
        if req.form is not None:
            self._inflight_forms.pop(req.form.key, None)
            for frid, g, sla, plan, form, t0, tenant in self._followers.pop(
                req.form.key, []
            ):
                hit = self.cache.lookup(g, form=form, min_quality=plan.quality)
                if hit is not None:
                    self._record_cached(
                        frid, g, plan, hit[0], hit[1], t0, tenant=tenant,
                        deadline_t=None if sla.deadline_s is None
                        else t0 + sla.deadline_s,
                    )
                else:
                    # canonical-key collision (or a primary downgraded
                    # below this follower's required quality) surfaced by
                    # the cache's gate: solve the follower for real,
                    # re-scored against its own residual budget
                    budget = self._budget(sla, t0, self._clock())
                    if not self._shed_if_floor_late(frid, g, sla, plan,
                                                    budget, t0, tenant):
                        self._admit(frid, g, sla, plan, form, False, None,
                                    tenant=tenant, t0=t0)

"""Cross-request batching Max-Cut solve service (DESIGN.md §6.1).

The paper's pipeline solves one problem per invocation; the ROADMAP north
star is a service under concurrent load. The scheduler closes that gap by
amortizing solver capacity *across* requests:

  1. `submit` admits a request, consults the result cache (§6.3) on the
     canonical graph hash, and — on a miss — asks the SLA planner (§6.2)
     for a knob tuple, partitions via `core.partition.partition_for_solver`
     at the chosen qubit budget, and enqueues one work item per subgraph;
  2. `pump` packs pending subgraphs from *any* request into fixed-shape
     batches for the already-cached jitted `solve_subgraph_batch_program`.
     Batches are shape-bucketed by the QAOA config: every dispatch in a
     bucket uses exactly ``batch_slots`` rows padded to the qubit budget's
     edge capacity N·(N−1)/2 — the maximum a ≤N-vertex subgraph can carry
     — so a bucket compiles exactly once no matter how request sizes mix;
  3. per-request completion tracking (mirroring `serving/engine.py`'s done
     mask, here a remaining-subgraph count) fires the merge stage the
     moment a request's last candidate lands: the default path runs
     `core.paraqaoa.merge_candidates` — the *same* merge `core.solve`
     runs, which together with the per-row bit-stability of the batched
     solver makes service cuts bit-identical to solo `solve` runs on the
     same knobs — while streaming requests run the anytime
     `core.merge.merge_stream` and surface the best-known cut after every
     merge level (§6.4).

Everything is synchronous SPMD-style pumping, not threads: "concurrent"
means many admitted requests in flight across the shared batch queue,
exactly like the decode engine's continuous batching.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import paraqaoa as para_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import partition_for_solver
from repro.service.cache import ResultCache
from repro.service.canonical import canonical_form
from repro.service.planner import SLA, KnobPlan, Planner


def edge_capacity(n_qubits: int) -> int:
    """Max simple-edge count of a subgraph that fits an N-qubit solver."""
    return max(n_qubits * (n_qubits - 1) // 2, 1)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    batch_slots: int = 16  # fixed rows per solver dispatch (one shape/bucket)
    cache_capacity: int = 256
    enable_cache: bool = True
    max_qubits: int = 12  # hardware budget cap handed to the planner
    anytime_min_levels: int = 2  # stream only when the merge has >1 level


@dataclasses.dataclass
class RequestResult:
    request_id: int
    assignment: np.ndarray
    cut_value: float
    cached: bool
    plan: KnobPlan
    latency_s: float
    timings: dict
    anytime: list  # [(level, n_levels, best_known_cut)] for streamed requests


class _Request:
    def __init__(self, rid, graph, sla, plan, cfg, stream, on_update, form):
        self.id = rid
        self.graph = graph
        self.sla = sla
        self.plan = plan
        self.cfg = cfg  # ParaQAOAConfig derived from plan.knobs
        self.stream = stream
        self.on_update = on_update
        self.form = form  # canonical form, when the cache is enabled
        self.submit_t = time.perf_counter()
        self.part = None
        self.bit_indices = None  # (M, K) int64
        self.remaining = 0
        self.solve_done_t = None


@dataclasses.dataclass
class ServiceStats:
    dispatches: int = 0
    slots_total: int = 0
    slots_filled: int = 0
    completed: int = 0
    cache_served: int = 0

    @property
    def fill_ratio(self) -> float:
        return self.slots_filled / self.slots_total if self.slots_total else 0.0

    def as_dict(self) -> dict:
        return {
            "dispatches": self.dispatches,
            "slots_total": self.slots_total,
            "slots_filled": self.slots_filled,
            "fill_ratio": round(self.fill_ratio, 4),
            "completed": self.completed,
            "cache_served": self.cache_served,
        }


class SolveService:
    """Batched Max-Cut solve service over the ParaQAOA pipeline."""

    def __init__(
        self,
        config: ServiceConfig = ServiceConfig(),
        planner: Planner | None = None,
        cache: ResultCache | None = None,
    ):
        self.config = config
        self.planner = planner or Planner(
            max_qubits=config.max_qubits, batch_slots=config.batch_slots
        )
        self.cache = cache or ResultCache(config.cache_capacity)
        self.stats = ServiceStats()
        self.results: "OrderedDict[int, RequestResult]" = OrderedDict()
        self._next_id = 0
        self._active: dict[int, _Request] = {}
        # bucket key: the (frozen, hashable) QAOAConfig — one compiled
        # program and one queue per static solver configuration
        self._buckets: "OrderedDict[qaoa_mod.QAOAConfig, deque]" = OrderedDict()
        # in-flight dedup: canonical key → (primary request id, its quality);
        # isomorphic requests admitted while their twin is still solving
        # coalesce onto it and are served from cache when it completes
        self._inflight: dict[str, tuple[int, float]] = {}
        self._followers: dict[str, list] = {}

    # ------------------------------------------------------------- admit --
    def submit(
        self,
        graph: Graph,
        sla: SLA = SLA(),
        stream: bool = False,
        on_update: Optional[Callable] = None,
    ) -> int:
        """Admit one solve request; returns its request id.

        Cache hits complete immediately (the result is visible in
        `results` on return); misses enqueue the request's subgraphs into
        the shared batch queue — call `pump`/`drain` to make progress.
        """
        rid = self._next_id
        self._next_id += 1
        t0 = time.perf_counter()

        plan = self.planner.plan(graph.n, graph.n_edges, sla)
        form = None
        if self.config.enable_cache:
            form = canonical_form(graph)
            hit = self.cache.lookup(graph, form=form, min_quality=plan.quality)
            if hit is not None:
                assignment, cut = hit
                self._record_cached(
                    rid, graph, plan, assignment, cut, t0,
                    stream=stream, on_update=on_update,
                )
                return rid
            # coalesce onto an in-flight isomorphic twin of sufficient
            # quality: no work enqueued; served from cache at its merge.
            # Streaming requests bypass dedup — they want per-level updates.
            primary = self._inflight.get(form.key)
            if primary is not None and primary[1] >= plan.quality and not stream:
                self._followers.setdefault(form.key, []).append(
                    (rid, graph, sla, plan, form, t0)
                )
                return rid

        self._admit(rid, graph, sla, plan, form, stream, on_update)
        return rid

    def _admit(self, rid, graph, sla, plan, form, stream, on_update) -> None:
        """Enqueue a request's subgraphs into its shape bucket."""
        kn = plan.knobs
        cfg = para_mod.ParaQAOAConfig(
            n_qubits=kn.n_qubits,
            top_k=kn.top_k,
            merge_level=plan.merge_level,
            p_layers=kn.p_layers,
            opt_steps=kn.opt_steps,
            beam_width=kn.beam_width,
        )
        req = _Request(rid, graph, sla, plan, cfg, stream, on_update, form)
        req.part = partition_for_solver(graph, kn.n_qubits)
        req.bit_indices = np.zeros((req.part.m, kn.top_k), dtype=np.int64)
        req.remaining = req.part.m
        self._active[rid] = req
        if form is not None and form.key not in self._inflight:
            self._inflight[form.key] = (rid, plan.quality)

        qcfg = cfg.qaoa_config()
        queue = self._buckets.setdefault(qcfg, deque())
        for idx in range(req.part.m):
            queue.append((req, idx))

    def _record_cached(
        self, rid, graph, plan, assignment, cut, t0,
        stream=False, on_update=None,
    ) -> None:
        # a streamed request served from cache still gets its anytime
        # contract: one final update (the answer is complete immediately)
        anytime = [(1, 1, cut)] if stream else []
        if stream and on_update is not None:
            on_update(rid, 1, 1, cut)
        now = time.perf_counter()
        self.results[rid] = RequestResult(
            request_id=rid,
            assignment=assignment,
            cut_value=cut,
            cached=True,
            plan=plan,
            latency_s=now - t0,
            timings={"cache_s": now - t0},
            anytime=anytime,
        )
        self.stats.completed += 1
        self.stats.cache_served += 1

    # ------------------------------------------------------------- solve --
    def pump(self) -> bool:
        """Dispatch one cross-request batch (the fullest bucket) and run
        any merges it unblocks. Returns True while work remains."""
        bucket = max(
            (b for b in self._buckets.items() if b[1]),
            key=lambda b: len(b[1]),
            default=None,
        )
        if bucket is None:
            return False
        qcfg, queue = bucket
        slots = self.config.batch_slots
        items = [queue.popleft() for _ in range(min(slots, len(queue)))]

        edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
            [req.part.subgraphs[idx] for req, idx in items],
            qcfg.n_qubits,
            e_pad=edge_capacity(qcfg.n_qubits),
            n_rows=slots,
        )
        program = qaoa_mod.solve_subgraph_batch_program(qcfg)
        res = program(edges, weights, masks)
        bitstrings = np.asarray(res.bitstrings)

        self.stats.dispatches += 1
        self.stats.slots_total += slots
        self.stats.slots_filled += len(items)

        done_requests = []
        for slot, (req, idx) in enumerate(items):
            req.bit_indices[idx] = bitstrings[slot]
            req.remaining -= 1
            if req.remaining == 0:
                done_requests.append(req)
        for req in done_requests:
            req.solve_done_t = time.perf_counter()
            self._merge(req)
        return any(self._buckets.values())

    def drain(self) -> "OrderedDict[int, RequestResult]":
        """Run the scheduler until every admitted request has a result."""
        while self.pump():
            pass
        return self.results

    # ------------------------------------------------------------- merge --
    def _merge(self, req: _Request) -> None:
        anytime: list = []
        if req.stream and req.part.m >= self.config.anytime_min_levels:
            plan, bw = para_mod.merge_inputs(
                req.part, req.bit_indices, req.cfg
            )
            best_cut, best_assign = -np.inf, None
            for snap in merge_mod.merge_stream(plan, bw):
                if snap.cut_value > best_cut:
                    best_cut, best_assign = snap.cut_value, snap.assignment
                anytime.append((snap.level, snap.n_levels, best_cut))
                if req.on_update is not None:
                    req.on_update(req.id, snap.level, snap.n_levels, best_cut)
            assignment = best_assign
        else:
            assignment, _, _ = para_mod.merge_candidates(
                req.part, req.bit_indices, req.cfg
            )
        # final re-score from scratch, exactly as core.solve reconciles
        cut = float(cut_value(req.graph, jnp.asarray(assignment)))
        if req.stream and not anytime:
            # single-level merges skip the stream; still honor the anytime
            # contract with one final update
            anytime.append((1, 1, cut))
            if req.on_update is not None:
                req.on_update(req.id, 1, 1, cut)

        now = time.perf_counter()
        if self.config.enable_cache:
            self.cache.store(
                req.graph,
                assignment,
                cut,
                quality=req.plan.quality,
                form=req.form,
            )
        self.results[req.id] = RequestResult(
            request_id=req.id,
            assignment=np.asarray(assignment),
            cut_value=cut,
            cached=False,
            plan=req.plan,
            latency_s=now - req.submit_t,
            timings={
                "solve_s": req.solve_done_t - req.submit_t,
                "merge_s": now - req.solve_done_t,
                "total_s": now - req.submit_t,
            },
            anytime=anytime,
        )
        self.stats.completed += 1
        del self._active[req.id]

        # serve coalesced isomorphic followers from the just-stored entry
        if req.form is not None:
            self._inflight.pop(req.form.key, None)
            for frid, g, sla, plan, form, t0 in self._followers.pop(
                req.form.key, []
            ):
                hit = self.cache.lookup(g, form=form, min_quality=plan.quality)
                if hit is not None:
                    self._record_cached(frid, g, plan, hit[0], hit[1], t0)
                else:
                    # canonical-key collision surfaced by the cache's
                    # re-score: solve the follower for real
                    self._admit(frid, g, sla, plan, form, False, None)

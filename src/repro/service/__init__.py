"""Max-Cut solve service: cross-request batching, SLA-driven knob
selection, and a canonical-graph result cache (DESIGN.md §6)."""

from repro.service.cache import CacheStats, ResultCache
from repro.service.canonical import CanonicalForm, canonical_form, canonical_key
from repro.service.planner import (
    SLA,
    CostModel,
    KnobPlan,
    KnobTuple,
    Planner,
    quality_score,
)
from repro.service.scheduler import (
    RequestResult,
    ServiceConfig,
    ServiceStats,
    SolveService,
    edge_capacity,
)

__all__ = [
    "CacheStats",
    "ResultCache",
    "CanonicalForm",
    "canonical_form",
    "canonical_key",
    "SLA",
    "CostModel",
    "KnobPlan",
    "KnobTuple",
    "Planner",
    "quality_score",
    "RequestResult",
    "ServiceConfig",
    "ServiceStats",
    "SolveService",
    "edge_capacity",
]

"""Max-Cut solve service: cross-request batching over pluggable solver
backends (single-device or `solve_pool` over a `data` mesh), async
admission with per-tenant fairness, SLA-driven knob selection with online
recalibration, and a canonical-graph result cache (DESIGN.md §6)."""

from repro.service.backend import LocalBackend, MeshBackend, make_backend
from repro.service.cache import CacheStats, ResultCache
from repro.service.canonical import CanonicalForm, canonical_form, canonical_key
from repro.service.planner import (
    SLA,
    CalibrationStats,
    CostModel,
    KnobPlan,
    KnobTuple,
    Planner,
    ReplanDecision,
    quality_score,
)
from repro.service.scheduler import (
    RequestResult,
    ServiceConfig,
    ServiceStats,
    SolveService,
    TenantStats,
    edge_capacity,
)
from repro.service.workload import (
    Arrival,
    VirtualClock,
    arrival_trace,
    run_soak_virtual,
    run_soak_wall,
)

__all__ = [
    "LocalBackend",
    "MeshBackend",
    "make_backend",
    "CacheStats",
    "ResultCache",
    "CanonicalForm",
    "canonical_form",
    "canonical_key",
    "SLA",
    "CalibrationStats",
    "CostModel",
    "KnobPlan",
    "KnobTuple",
    "Planner",
    "ReplanDecision",
    "quality_score",
    "RequestResult",
    "ServiceConfig",
    "ServiceStats",
    "SolveService",
    "TenantStats",
    "edge_capacity",
    "Arrival",
    "VirtualClock",
    "arrival_trace",
    "run_soak_virtual",
    "run_soak_wall",
]

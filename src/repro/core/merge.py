"""Level-Aware Parallel Merge (paper Alg. 2), TPU-native form.

The paper DFSes the Cartesian product B₁×…×B_M with 2K^L parallel worker
processes. SPMD hardware wants the dual formulation: a *level-synchronous
frontier* swept by `lax.scan` over subgraph levels. The frontier ("beam")
holds (partial global assignment, partial score) rows:

  - level 0 seeds the frontier with both orientations of subgraph 1's K
    candidates (the paper's factor 2),
  - each later level extends every row by the K candidates of that
    subgraph, oriented so the shared vertex agrees (the paper's
    "only half can be selected" constraint, applied as a XOR flip),
  - scores update incrementally: every edge of the *original* graph is
    bucketed (host-side, O(|E|)) onto the first level at which both its
    endpoints are assigned — intra-subgraph and inter-partition edges are
    therefore counted exactly once, reproducing Cut(B*) of §3.4,
  - if the frontier would exceed ``beam_width`` rows, only the best
    ``beam_width`` survive (beyond-paper pruning). With
    ``beam_width ≥ 2·K^M`` no pruning ever triggers and the sweep is
    *exactly* the paper's exhaustive DFS (tested against brute force).

The paper's L knob (worker count 2K^L) maps to sharding the frontier rows
across the `data` mesh axis (see core/distributed.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.graph import Graph
from repro.core.partition import Partition
from repro.obs import trace as trace_mod


class MergePlan(NamedTuple):
    """Host-prepared, shape-stable inputs for the merge scan."""

    n_vert: int  # true vertex count V
    n_pad: int  # padded assignment width (V + n_max)
    n_max: int  # max subgraph size
    k: int  # candidates per subgraph
    lo: jnp.ndarray  # (M,) int32 window starts
    cand_bits: jnp.ndarray  # (M, K, n_max) int8 candidate bit arrays
    edge_u: jnp.ndarray  # (M, E_lv) int32 earlier-covered endpoint
    edge_v: jnp.ndarray  # (M, E_lv) int32 later-covered endpoint (>= lo)
    edge_w: jnp.ndarray  # (M, E_lv) float32
    lin: jnp.ndarray  # (M, n_max) float32 linear terms at first coverage


class MergePlanStatics(NamedTuple):
    """The hashable (shape-defining) half of a MergePlan — the cache key
    for compiled distributed-merge programs (core/distributed.py)."""

    n_vert: int
    n_pad: int
    n_max: int
    k: int


def plan_statics(plan: "MergePlan") -> MergePlanStatics:
    return MergePlanStatics(plan.n_vert, plan.n_pad, plan.n_max, plan.k)


def plan_arrays(plan: "MergePlan") -> tuple:
    """The traced (device-array) half of a MergePlan, in MergePlan order."""
    return (plan.lo, plan.cand_bits, plan.edge_u, plan.edge_v, plan.edge_w,
            plan.lin)


class MergeResult(NamedTuple):
    assignment: jnp.ndarray  # (V,) int8 best global assignment
    cut_value: jnp.ndarray  # scalar f32
    beam_assign: jnp.ndarray  # (W, V_pad) final frontier (for inspection)
    beam_score: jnp.ndarray  # (W,)


def build_merge_plan(
    part: Partition, bitstring_indices: np.ndarray, k: int, linear=None
) -> MergePlan:
    """Bucket edges by level and unpack candidate indices to bit arrays.

    bitstring_indices: (M, K) int basis indices from the QAOA solvers
    (bit q of subgraph i's index = local vertex q). ``linear`` (V,) f32,
    optional, buckets each vertex's diagonal term onto its first-coverage
    level (the same exactly-once rule edges follow), so the beam scores
    the full quadratic + linear objective.
    """
    g = part.graph
    m = part.m
    n_max = max(part.sizes)
    lo = np.asarray([r[0] for r in part.ranges], dtype=np.int32)
    hi = np.asarray([r[1] for r in part.ranges], dtype=np.int32)

    # first-coverage level per vertex: ranges are contiguous and sorted, so
    # vertex x is first covered by the earliest range with x < hi_l.
    cover = np.zeros(g.n, dtype=np.int32)
    cover_level = np.searchsorted(hi, np.arange(g.n), side="right")
    cover[:] = np.minimum(cover_level, m - 1)

    e = np.asarray(g.edges)[: g.n_edges]
    w = np.asarray(g.weights)[: g.n_edges]
    cu, cv = cover[e[:, 0]], cover[e[:, 1]]
    level = np.maximum(cu, cv)
    # order endpoints: u = earlier-covered, v = later-covered
    swap = cu > cv
    eu = np.where(swap, e[:, 1], e[:, 0])
    ev = np.where(swap, e[:, 0], e[:, 1])

    counts = np.bincount(level, minlength=m)
    e_lv = max(int(counts.max()) if counts.size else 1, 1)
    edge_u = np.zeros((m, e_lv), dtype=np.int32)
    edge_v = np.zeros((m, e_lv), dtype=np.int32)
    edge_w = np.zeros((m, e_lv), dtype=np.float32)
    fill = np.zeros(m, dtype=np.int64)
    order = np.argsort(level, kind="stable")
    for idx in order:
        l = level[idx]
        edge_u[l, fill[l]] = eu[idx]
        edge_v[l, fill[l]] = ev[idx]
        edge_w[l, fill[l]] = w[idx]
        fill[l] += 1
    # padding rows: u = v = 0 with weight 0 — zero contribution. But v must
    # satisfy v >= lo at its level for the windowed gather; remap pads to lo.
    for l in range(m):
        edge_u[l, fill[l] :] = lo[l]
        edge_v[l, fill[l] :] = lo[l]

    bits = (
        (np.asarray(bitstring_indices, dtype=np.int64)[:, :, None]
         >> np.arange(n_max, dtype=np.int64))
        & 1
    ).astype(np.int8)

    # linear terms at first coverage: vertex v lands in bucket cover[v] at
    # local position v - lo[cover[v]] (always < n_max since v is inside its
    # first range). Zero when no linear terms — the Max-Cut case scores
    # exact +0.0 contributions everywhere.
    lin_arr = np.zeros((m, n_max), dtype=np.float32)
    if linear is not None:
        lin_np = np.asarray(linear, dtype=np.float32)
        assert lin_np.shape == (g.n,), (lin_np.shape, g.n)
        verts = np.arange(g.n)
        lin_arr[cover, verts - lo[cover]] = lin_np

    return MergePlan(
        n_vert=g.n,
        n_pad=g.n + n_max,
        n_max=n_max,
        k=k,
        lo=jnp.asarray(lo),
        cand_bits=jnp.asarray(bits),
        edge_u=jnp.asarray(edge_u),
        edge_v=jnp.asarray(edge_v),
        edge_w=jnp.asarray(edge_w),
        lin=jnp.asarray(lin_arr),
    )


def _level_delta(beam_assign, oriented, lo, edge_u, edge_v, edge_w, n_max, lin):
    """Score contribution of this level's edge + linear buckets.

    beam_assign: (W, V_pad) int8; oriented: (W, K, n_max) int8; lin (n_max,).
    Returns (W, K) float32. The linear term is scored on the *oriented*
    candidate bits: Max-Cut's global flip symmetry (both orientations of a
    candidate share a cut value) is broken by nonzero ``lin``, and this is
    where the two orientations pick up their differing Σ h_v·x_v.
    """
    v_local = jnp.clip(edge_v - lo, 0, n_max - 1)  # (E,)
    u_local = jnp.clip(edge_u - lo, 0, n_max - 1)
    u_in_prefix = edge_u < lo

    s_u_prefix = beam_assign[:, edge_u]  # (W, E)
    s_u_cand = oriented[:, :, u_local]  # (W, K, E)
    s_v = oriented[:, :, v_local]  # (W, K, E)
    s_u = jnp.where(u_in_prefix[None, None, :], s_u_prefix[:, None, :], s_u_cand)
    crossed = (s_u ^ s_v).astype(jnp.float32)  # (W, K, E)
    return crossed @ edge_w + oriented.astype(jnp.float32) @ lin  # (W, K)


def _seed_frontier(plan: MergePlan, w_width: int):
    """Level-0 frontier: both orientations of subgraph 1's K candidates
    (the paper's factor 2), scored on the level-0 edge bucket. Shared by
    `merge_scan` and the anytime `merge_stream` so both sweeps start from
    the identical state."""
    k = plan.k
    neg = jnp.float32(-1e30)
    bits0 = plan.cand_bits[0]  # (K, n_max)
    cands0 = jnp.concatenate([bits0, 1 - bits0], axis=0)  # (2K, n_max)
    assign0 = jnp.zeros((2 * k, plan.n_pad), dtype=jnp.int8)
    assign0 = jax.lax.dynamic_update_slice(
        assign0, cands0, (0, plan.lo[0])
    )
    # score the level-0 bucket: prefix is empty, u always "candidate-local"
    delta0 = _level_delta(
        assign0,
        cands0[:, None, :],
        plan.lo[0],
        plan.edge_u[0],
        plan.edge_v[0],
        plan.edge_w[0],
        plan.n_max,
        plan.lin[0],
    )[:, 0]

    beam_assign = jnp.zeros((w_width, plan.n_pad), dtype=jnp.int8)
    beam_score = jnp.full((w_width,), neg, dtype=jnp.float32)
    rows = min(2 * k, w_width)
    if 2 * k > w_width:
        top_v, top_i = jax.lax.top_k(delta0, w_width)
        beam_assign = assign0[top_i]
        beam_score = top_v
    else:
        beam_assign = beam_assign.at[:rows].set(assign0)
        beam_score = beam_score.at[:rows].set(delta0)
    return beam_assign, beam_score


def _level_step(
    carry,
    xs,
    *,
    k: int,
    n_max: int,
    w_width: int,
    stripe: bool = False,
    n_shards: int = 1,
    shard_id=None,
    split_level: int = 1,
):
    """One merge level: orient, score, top-W prune, write the window.

    The single source of truth for the merge recurrence — `merge_scan`
    runs it under `lax.scan`, the service's anytime `merge_stream` runs
    it level-by-level through one cached jitted program (same shapes at
    every level, so it compiles exactly once).
    """
    neg = jnp.float32(-1e30)
    beam_assign, beam_score = carry
    (lo, bits, eu, ev, ew, lin), level = xs
    # orient candidates to agree with the shared vertex (lo)
    shared = beam_assign[:, lo]  # (W,)
    flip = (bits[None, :, 0] ^ shared[:, None]).astype(jnp.int8)  # (W, K)
    oriented = bits[None, :, :] ^ flip[:, :, None]  # (W, K, n_max)

    delta = _level_delta(beam_assign, oriented, lo, eu, ev, ew, n_max, lin)
    scores = beam_score[:, None] + delta  # (W, K); -inf rows stay -inf
    flat = scores.reshape(-1)
    if stripe:
        mine = (jnp.arange(flat.shape[0]) % n_shards) == shard_id
        flat = jnp.where((level == split_level) & ~mine, neg, flat)
    top_v, top_i = jax.lax.top_k(flat, w_width)
    w_idx = top_i // k
    k_idx = top_i % k

    new_assign = beam_assign[w_idx]  # (W, V_pad)
    picked = oriented[w_idx, k_idx]  # (W, n_max)
    cur = jax.lax.dynamic_slice(
        new_assign, (0, lo), (w_width, n_max)
    )
    merged = jnp.where(top_v[:, None] > neg / 2, picked, cur)
    new_assign = jax.lax.dynamic_update_slice(new_assign, merged, (0, lo))
    return (new_assign, top_v), None


def merge_scan(
    plan: MergePlan,
    beam_width: int,
    shard_id=None,
    n_shards: int = 1,
    split_level: int = 1,
) -> MergeResult:
    """Run the level-synchronous merge. Exact iff beam_width ≥ 2·K^M.

    Level-aware sharding (paper §3.4.2): when ``n_shards > 1`` the frontier
    is striped across shards at ``split_level`` — shard s keeps rows with
    (row index mod n_shards == s) and explores them independently, exactly
    like the paper's 2K^L DFS workers. ``shard_id`` may be a traced value
    (axis_index inside shard_map).
    """
    w_width = beam_width
    k = plan.k
    n_max = plan.n_max
    neg = jnp.float32(-1e30)
    stripe = shard_id is not None and n_shards > 1

    beam_assign, beam_score = _seed_frontier(plan, w_width)

    if stripe and split_level == 0:
        keep = (jnp.arange(w_width) % n_shards) == shard_id
        beam_score = jnp.where(keep, beam_score, neg)

    # ---- levels 1..M-1 ---------------------------------------------------
    step = functools.partial(
        _level_step,
        k=k,
        n_max=n_max,
        w_width=w_width,
        stripe=stripe,
        n_shards=n_shards,
        shard_id=shard_id,
        split_level=split_level,
    )

    if plan.lo.shape[0] > 1:
        m = plan.lo.shape[0]
        xs = (
            (
                plan.lo[1:],
                plan.cand_bits[1:],
                plan.edge_u[1:],
                plan.edge_v[1:],
                plan.edge_w[1:],
                plan.lin[1:],
            ),
            jnp.arange(1, m, dtype=jnp.int32),
        )
        (beam_assign, beam_score), _ = jax.lax.scan(
            step, (beam_assign, beam_score), xs
        )

    best = jnp.argmax(beam_score)
    return MergeResult(
        assignment=beam_assign[best, : plan.n_vert],
        cut_value=beam_score[best],
        beam_assign=beam_assign,
        beam_score=beam_score,
    )


class AnytimeSnapshot(NamedTuple):
    """One anytime-merge update (DESIGN.md §6.4): the best-known *complete*
    assignment after a merge level, with suffix vertices filled greedily."""

    level: int  # levels merged so far (1..M)
    n_levels: int  # M
    cut_value: float  # cut of `assignment` on the full graph
    assignment: np.ndarray  # (V,) int8 complete assignment
    is_final: bool  # True on the last level (beam fully merged)


@compat.cached_program
def _stream_step_program(statics: MergePlanStatics, beam_width: int):
    """One jitted merge level for the anytime stream. Every level of one
    plan has identical shapes, so this compiles once per (statics, width) —
    the python-level loop in `merge_stream` costs no retraces."""
    step = functools.partial(
        _level_step, k=statics.k, n_max=statics.n_max, w_width=beam_width
    )
    return jax.jit(lambda carry, xs: step(carry, xs)[0])


def _complete_suffix(plan_host, assign_pad: np.ndarray, level: int) -> np.ndarray:
    """Fill levels (level+1..M-1) of a partial assignment with each
    subgraph's top-1 candidate, oriented to agree on the shared vertex —
    the greedy completion that turns a frontier row into a full cut."""
    lo, cand_bits, n_max = plan_host
    a = assign_pad.copy()
    for j in range(level + 1, lo.shape[0]):
        bits = cand_bits[j, 0]  # (n_max,) top-1 candidate
        flip = np.int8(bits[0] ^ a[lo[j]])
        a[lo[j] : lo[j] + n_max] = bits ^ flip
    return a


def merge_stream(
    plan: MergePlan, beam_width: int
) -> Iterator[AnytimeSnapshot]:
    """Anytime form of `merge_scan`: yield the best-known complete cut
    after every merge level (DESIGN.md §6.4).

    Runs the *same* `_level_step` recurrence as `merge_scan`, but
    level-by-level through one cached jitted program instead of one
    `lax.scan`, so the caller can take an early answer between levels.
    After level l the best frontier row covers vertices [0, hi_l); the
    remaining subgraphs are completed greedily with their top-1
    candidates (oriented at the shared vertex), giving a valid full
    assignment whose cut is scored from the plan's edge buckets — every
    graph edge lives in exactly one bucket, so the score is exact.
    The final snapshot's frontier equals the fully-merged beam.
    """
    m = int(plan.lo.shape[0])
    carry = _seed_frontier(plan, beam_width)

    lo_h = np.asarray(plan.lo)
    bits_h = np.asarray(plan.cand_bits)
    eu_h, ev_h, ew_h = (
        np.asarray(plan.edge_u),
        np.asarray(plan.edge_v),
        np.asarray(plan.edge_w),
    )
    lin_h = np.asarray(plan.lin)
    plan_host = (lo_h, bits_h, plan.n_max)

    def snapshot(carry, level: int) -> AnytimeSnapshot:
        beam_assign, beam_score = carry
        best = int(np.argmax(np.asarray(beam_score)))
        partial = np.asarray(beam_assign[best], dtype=np.int8)
        full = _complete_suffix(plan_host, partial, level)
        # exact objective from the level buckets (each edge and each linear
        # term appears exactly once; padding rows have u == v and weight 0)
        crossed = (full[eu_h] ^ full[ev_h]).astype(np.float32)
        cut = float(np.sum(crossed * ew_h))
        for l in range(m):
            win = full[lo_h[l] : lo_h[l] + plan.n_max].astype(np.float32)
            cut += float(lin_h[l] @ win)
        return AnytimeSnapshot(
            level=level + 1,
            n_levels=m,
            cut_value=cut,
            assignment=full[: plan.n_vert],
            is_final=(level == m - 1),
        )

    # §8: one span per materialized level. Spans close *before* their
    # snapshot is yielded — a consumer may hold the generator between
    # yields arbitrarily long, and that wait is the caller's time, not
    # the merge's.
    tr = trace_mod.get_tracer()
    with tr.span("merge_level", level=1, n_levels=m):
        snap = snapshot(carry, 0)
    yield snap
    if m == 1:
        return

    step = _stream_step_program(plan_statics(plan), beam_width)
    for l in range(1, m):
        with tr.span("merge_level", level=l + 1, n_levels=m):
            xs = (
                (
                    plan.lo[l],
                    plan.cand_bits[l],
                    plan.edge_u[l],
                    plan.edge_v[l],
                    plan.edge_w[l],
                    plan.lin[l],
                ),
                jnp.int32(l),
            )
            carry = step(carry, xs)
            snap = snapshot(carry, l)
        yield snap


def global_winner(res: MergeResult, axis: str, shard_id):
    """Cross-shard winner selection for a striped merge (inside shard_map).

    pmax picks the best cut value; pmin over shard rank breaks exact ties
    deterministically (lowest shard wins); a masked psum broadcasts the
    winner's assignment so the return is replicated on every shard.
    Returns (assignment (V,), best cut value), both replicated.
    """
    best = jax.lax.pmax(res.cut_value, axis)
    rank = jnp.where(res.cut_value >= best, shard_id, jnp.int32(2**30))
    winner = jax.lax.pmin(rank, axis)
    mask = (shard_id == winner).astype(res.assignment.dtype)
    assign = jax.lax.psum(res.assignment * mask, axis)
    return assign, best


def exact_beam_width(k: int, m: int, cap: int = 1 << 22) -> int:
    """Frontier size that makes merge_scan exhaustive: 2·K^M (capped)."""
    w = 2
    for _ in range(m):
        w *= k
        if w > cap:
            return cap
    return max(w, 2 * k)


def striped_beam_width(
    k: int, m: int, n_shards: int, split_level: int, cap: int = 1 << 22
) -> int | None:
    """Per-shard frontier width that keeps a striped merge exhaustive.

    A merge striped at ``split_level`` is exact iff no shard ever loses a
    potential winner: before the split every shard carries the *full*
    frontier — 2·K^j rows survive the level-j step, so the width must
    reach 2·K^split — and after the split each shard's stripe grows by K
    per remaining level. Pruning at the final level is harmless (scores
    are complete there, so top-w keeps the true maximum), which makes
    ceil(2·K^split / n_shards) stripe roots an upper bound of the exact
    post-split requirement.
    Returns the smallest per-shard width covering both, or None when the
    exhaustive sweep (global 2·K^M, or the per-shard share) exceeds
    ``cap`` — the caller should then treat the merge as heuristic.
    """
    total = 2 * k**m
    if total > cap:
        return None
    l = min(split_level, m - 1)
    roots = -(-2 * k**l // n_shards)
    w = max(roots * k ** (m - 1 - l), 2 * k**l, 2 * k)
    return w if w <= cap else None

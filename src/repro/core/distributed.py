"""Distributed execution of ParaQAOA on a device mesh.

Three shard_map programs, matching DESIGN.md §2:

1. `solve_pool`       — solver-pool data parallelism: the vmapped subgraph
   batch is sharded across the `data` (and `pod`) axes. This is the paper's
   "N_s QAOA solvers × T rounds" recast as SPMD.

2. `sharded_qaoa`     — statevector tensor parallelism: one subproblem's
   2^n amplitudes sharded across the `model` axis. The transverse-field
   mixer factorizes per qubit, so only the log2(axis_size) "global" qubits
   need cross-device mixing; one qubit-swap `all_to_all` rotates them into
   locality. Lifts the paper's 26-qubit/GPU cap to 26 + log2(model) qubits.

   Two collective schedules:
     - "faithful":    swap in + swap back every layer (2 a2a/layer) — the
       direct port of a distributed gate-level simulator.
     - "alternating": keep the swapped layout between layers and evaluate
       the diagonal cost layer with *relabelled* cut values (1 a2a/layer —
       a diagonal Hamiltonian makes the layout change a pure relabelling).
       Beyond-paper optimization; measured by benchmarks/kernel_bench.py
       `run_schedules` (see EXPERIMENTS.md §Perf).

3. `merge_sharded`    — the merge frontier striped across `data` at the
   paper's starting level L: each shard prunes its own stripe locally (the
   paper's independent DFS workers); a pmax/pmin picks the global winner.

All three go through `repro.compat` (portable shard_map + mesh handling)
and are *cached compiled programs*: the jitted callable is built once per
static configuration (config, mesh, axes), not per call, with buffer
donation on backends that support it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# 1. solver-pool data parallelism
# ---------------------------------------------------------------------------
@compat.cached_program
def _solve_pool_program(
    cfg: qaoa_mod.QAOAConfig, mesh: Mesh, axes: tuple, donate: bool
):
    spec = P(axes)

    def run(e, w, mk):
        return qaoa_mod.solve_subgraph_batch(e, w, mk, cfg)

    sharded = compat.shard_map(
        run,
        mesh,
        in_specs=(spec, spec, spec),
        out_specs=qaoa_mod.QAOAResult(spec, spec, spec, spec, spec),
    )
    # donate only when solve_pool owns the (freshly padded) batch arrays —
    # donating caller-owned arrays would invalidate them behind its back
    return compat.jit(sharded, donate_argnums=(0, 1, 2) if donate else ())


def solve_pool(edges, weights, masks, cfg: qaoa_mod.QAOAConfig, mesh: Mesh,
               axes=("data",)):
    """Batched QAOA across the mesh: round-robin subgraphs over devices.

    Pads the batch to a multiple of the axis size (padding entries are
    empty graphs) and strips the padding on return.
    """
    axes = tuple(axes)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    m = edges.shape[0]
    m_pad = ((m + total - 1) // total) * total
    pad = m_pad - m
    if pad:
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad,) + edges.shape[1:], edges.dtype)]
        )
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,) + weights.shape[1:], weights.dtype)]
        )
        masks = jnp.concatenate([masks, jnp.ones((pad,), masks.dtype)])

    # normalize the cache key on non-donating backends: donate=True and
    # donate=False would otherwise compile byte-identical programs twice
    donate = bool(pad) and compat.supports_donation()
    program = _solve_pool_program(cfg, mesh, axes, donate)
    res = program(edges, weights, masks)
    return jax.tree.map(lambda x: x[:m], res)


# ---------------------------------------------------------------------------
# 2. sharded-statevector QAOA (statevector tensor parallelism)
# ---------------------------------------------------------------------------
class ShardedQAOAResult(NamedTuple):
    bitstrings: jnp.ndarray  # (K,) int32 global basis indices (replicated)
    probs: jnp.ndarray  # (K,)
    expectation: jnp.ndarray  # scalar


def _mix_bits(re, im, n_local: int, lo_bit: int, nbits: int, beta):
    """Mix qubits [lo_bit, lo_bit+nbits) of a flat 2^n_local local state."""
    x = 2 ** (n_local - lo_bit - nbits)
    y = 2**lo_bit
    C, D = ref.rx_kron_parts(beta, nbits)
    re3 = re.reshape(x, 2**nbits, y)
    im3 = im.reshape(x, 2**nbits, y)
    re_new = jnp.einsum("ab,xby->xay", C, re3) - jnp.einsum("ab,xby->xay", D, im3)
    im_new = jnp.einsum("ab,xby->xay", C, im3) + jnp.einsum("ab,xby->xay", D, re3)
    return re_new.reshape(-1), im_new.reshape(-1)


@compat.cached_program
def _sharded_qaoa_program(
    n: int,
    p_layers: int,
    mesh: Mesh,
    axis: str,
    top_k: int,
    schedule: str,
    group: int,
):
    d_ax = mesh.shape[axis]
    h = int(np.log2(d_ax))
    assert 2**h == d_ax, f"axis size {d_ax} must be a power of two"
    n_local = n - h
    L = 2**n_local
    chunk = L // d_ax
    assert chunk >= 1, f"statevector too small for the mesh: n={n}, axis={d_ax}"
    log2_chunk = int(np.log2(chunk))

    def local_run(edges, weights, gammas, betas):
        me = jax.lax.axis_index(axis)
        idx_a = me * L + jnp.arange(L, dtype=jnp.int32)
        q = jnp.arange(L, dtype=jnp.int32)
        idx_b = (q // chunk) * L + me * chunk + (q % chunk)
        cutv_a = ref.cutvals_at(idx_a, edges, weights)
        cutv_b = ref.cutvals_at(idx_b, edges, weights)

        re = jnp.full((L,), 2.0 ** (-n / 2), dtype=jnp.float32)
        im = jnp.zeros((L,), dtype=jnp.float32)

        def a2a(x):
            return jax.lax.all_to_all(
                x.reshape(d_ax, chunk), axis, split_axis=0, concat_axis=0
            ).reshape(-1)

        in_b = False
        for l in range(p_layers):  # p is small; unrolled keeps parity static
            g, b = gammas[l], betas[l]
            cutv = cutv_b if in_b else cutv_a
            re, im = ref.apply_phase(re, im, cutv, g)
            # mix the n-h locally-resident qubits
            re, im = ops.apply_mixer(re, im, n_local, b, group=group)
            # rotate the h shard-axis qubits into locality and mix them:
            # after the swap they sit at local bits [log2_chunk, log2_chunk+h)
            re, im = a2a(re), a2a(im)
            re, im = _mix_bits(re, im, n_local, log2_chunk, h, b)
            if schedule == "alternating":
                in_b = not in_b
            else:  # faithful: swap straight back to layout A
                re, im = a2a(re), a2a(im)

        cutv = cutv_b if in_b else cutv_a
        idx = idx_b if in_b else idx_a
        exp = jax.lax.psum(ref.expectation(re, im, cutv), axis)
        probs = re * re + im * im
        v, i_loc = jax.lax.top_k(probs, top_k)
        all_v = jax.lax.all_gather(v, axis).reshape(-1)
        all_i = jax.lax.all_gather(idx[i_loc], axis).reshape(-1)
        vv, ii = jax.lax.top_k(all_v, top_k)
        return ShardedQAOAResult(all_i[ii], vv, exp)

    run = compat.shard_map(
        local_run,
        mesh,
        in_specs=(P(), P(), P(), P()),
        out_specs=ShardedQAOAResult(P(), P(), P()),
    )
    return compat.jit(run)


def sharded_qaoa(
    edges,
    weights,
    n: int,
    gammas,
    betas,
    mesh: Mesh,
    axis: str = "model",
    top_k: int = 4,
    schedule: str = "alternating",
    group: int = 7,
):
    """One n-qubit QAOA circuit with amplitudes sharded over `axis`.

    Layouts: A (row-sharded: device d owns global indices [d·L, (d+1)·L));
    B (after the qubit-swap all_to_all: device p owns, for every d, the
    slice [d·L + p·chunk, d·L + (p+1)·chunk)). In layout B the local flat
    index's high h bits are the *original* high qubits — so a full local
    mixer still touches each original qubit exactly once per layer.
    """
    program = _sharded_qaoa_program(
        n, int(gammas.shape[0]), mesh, axis, top_k, schedule, group
    )
    return program(edges, weights, gammas, betas)


# ---------------------------------------------------------------------------
# 3. sharded merge frontier (level-aware workers)
# ---------------------------------------------------------------------------
@compat.cached_program
def _merge_sharded_program(
    statics: merge_mod.MergePlanStatics,
    beam_width: int,
    mesh: Mesh,
    axis: str,
    split_level: int,
):
    d_ax = mesh.shape[axis]

    def local_run(lo, cand_bits, edge_u, edge_v, edge_w):
        me = jax.lax.axis_index(axis)
        local_plan = merge_mod.MergePlan(
            *statics,
            lo=lo,
            cand_bits=cand_bits,
            edge_u=edge_u,
            edge_v=edge_v,
            edge_w=edge_w,
        )
        res = merge_mod.merge_scan(
            local_plan,
            beam_width,
            shard_id=me,
            n_shards=d_ax,
            split_level=split_level,
        )
        return merge_mod.global_winner(res, axis, me)

    run = compat.shard_map(
        local_run,
        mesh,
        in_specs=(P(), P(), P(), P(), P()),
        out_specs=(P(), P()),
    )
    return compat.jit(run)


def merge_sharded(
    plan: merge_mod.MergePlan,
    beam_width: int,
    mesh: Mesh,
    axis: str = "data",
    split_level: int = 1,
):
    """Level-aware merge: frontier striped across `axis` at `split_level`.

    Each shard sweeps its own beam of beam_width rows — the global frontier
    is n_shards × beam_width (the paper's "2K^L workers ⇒ runtime halves
    per doubling" regime). Returns (assignment (V,), cut value), replicated.
    """
    program = _merge_sharded_program(
        merge_mod.plan_statics(plan), beam_width, mesh, axis, split_level
    )
    return program(*merge_mod.plan_arrays(plan))

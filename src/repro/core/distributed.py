"""Distributed execution of ParaQAOA on a device mesh.

Three shard_map programs plus the end-to-end orchestrator that wires them
into one pipeline (`solve_distributed`, DESIGN.md §2.4), matching
DESIGN.md §2:

1. `solve_pool`       — solver-pool data parallelism: the vmapped subgraph
   batch is sharded across the `data` (and `pod`) axes. This is the paper's
   "N_s QAOA solvers × T rounds" recast as SPMD.

2. `sharded_qaoa`     — statevector tensor parallelism: one subproblem's
   2^n amplitudes sharded across the `model` axis. The transverse-field
   mixer factorizes per qubit, so only the log2(axis_size) "global" qubits
   need cross-device mixing; one qubit-swap `all_to_all` rotates them into
   locality. Lifts the paper's 26-qubit/GPU cap to 26 + log2(model) qubits.
   The per-layer evolution is the shared statevector engine
   (`core/engine.py`, DESIGN.md §2.6): every op dispatches through
   `kernels.ops` per shard, the whole evolution is differentiable through
   the collectives, and `sharded_qaoa_batch` scans stacked same-n
   subproblems through one cached program.

   Two collective schedules:
     - "faithful":    swap in + swap back every layer (2 a2a/layer) — the
       direct port of a distributed gate-level simulator.
     - "alternating": keep the swapped layout between layers and evaluate
       the diagonal cost layer with *relabelled* cut values (1 a2a/layer —
       a diagonal Hamiltonian makes the layout change a pure relabelling).
       Beyond-paper optimization; measured by benchmarks/kernel_bench.py
       `run_schedules` (see EXPERIMENTS.md §Perf).

3. `merge_sharded`    — the merge frontier striped across `data` at the
   paper's starting level L: each shard prunes its own stripe locally (the
   paper's independent DFS workers); a pmax/pmin picks the global winner.

All three go through `repro.compat` (portable shard_map + mesh handling)
and are *cached compiled programs*: the jitted callable is built once per
static configuration (config, mesh, axes), not per call, with buffer
donation on backends that support it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import engine
from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.kernels import ops
from repro.kernels import tuning


# ---------------------------------------------------------------------------
# 1. solver-pool data parallelism
# ---------------------------------------------------------------------------
@compat.cached_program
def _solve_pool_program(
    cfg: qaoa_mod.QAOAConfig, mesh: Mesh, axes: tuple, donate: bool,
    impl: str,
    tune: tuple,
    has_lin: bool = False,
):
    # the per-shard `kernels.ops` dispatch is a trace-time choice, so
    # `ops.using_implementation` only reaches the pool if each
    # implementation gets its own compiled program; the keyed `impl` is
    # re-asserted during tracing because jit traces lazily on first call,
    # possibly outside the context the program was requested under. The
    # `kernels.tuning` block-shape state is trace-time in the same way,
    # so it is keyed and re-asserted alongside (DESIGN.md §2.7). `has_lin`
    # keys the linear-terms (QUBO/MIS) variant; False compiles the exact
    # Max-Cut program, keeping that path bit-identical.
    spec = P(axes)

    if has_lin:

        def run(e, w, mk, l):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return qaoa_mod.solve_subgraph_batch_linear(e, w, mk, cfg, l)

        in_specs = (spec, spec, spec, spec)
        donate_args = (0, 1, 2, 3)
    else:

        def run(e, w, mk):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return qaoa_mod.solve_subgraph_batch(e, w, mk, cfg)

        in_specs = (spec, spec, spec)
        donate_args = (0, 1, 2)

    sharded = compat.shard_map(
        run,
        mesh,
        in_specs=in_specs,
        out_specs=qaoa_mod.QAOAResult(spec, spec, spec, spec, spec),
    )
    # donate only when solve_pool owns the (freshly padded) batch arrays —
    # donating caller-owned arrays would invalidate them behind its back
    return compat.jit(sharded, donate_argnums=donate_args if donate else ())


def solve_pool(edges, weights, masks, cfg: qaoa_mod.QAOAConfig, mesh: Mesh,
               axes=("data",), linears=None):
    """Batched QAOA across the mesh: round-robin subgraphs over devices.

    Pads the batch to a multiple of the axis size (padding entries are
    empty graphs) and strips the padding on return. ``linears``
    (B, n_qubits) f32, optional, carries per-vertex diagonal terms
    (QUBO/MIS buckets); ``None`` runs the unchanged Max-Cut program.
    """
    axes = tuple(axes)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    m = edges.shape[0]
    m_pad = ((m + total - 1) // total) * total
    pad = m_pad - m
    if pad:
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad,) + edges.shape[1:], edges.dtype)]
        )
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,) + weights.shape[1:], weights.dtype)]
        )
        masks = jnp.concatenate([masks, jnp.ones((pad,), masks.dtype)])
        if linears is not None:
            linears = jnp.concatenate(
                [linears, jnp.zeros((pad,) + linears.shape[1:], linears.dtype)]
            )

    # normalize the cache key on non-donating backends: donate=True and
    # donate=False would otherwise compile byte-identical programs twice
    donate = bool(pad) and compat.supports_donation()
    program = _solve_pool_program(
        cfg, mesh, axes, donate, ops.get_implementation(), tuning.state(),
        linears is not None,
    )
    res = (program(edges, weights, masks) if linears is None
           else program(edges, weights, masks, linears))
    return jax.tree.map(lambda x: x[:m], res)


# ---------------------------------------------------------------------------
# 2. sharded-statevector QAOA (statevector tensor parallelism)
# ---------------------------------------------------------------------------
class ShardedQAOAResult(NamedTuple):
    bitstrings: jnp.ndarray  # (K,) int32 global basis indices (replicated)
    probs: jnp.ndarray  # (K,)
    expectation: jnp.ndarray  # scalar
    gammas: jnp.ndarray  # (p,) as run (optimized when opt_steps > 0)
    betas: jnp.ndarray  # (p,)


@compat.cached_program
def _sharded_qaoa_program(
    n: int,
    p_layers: int,
    batch: int,
    mesh: Mesh,
    axis: str,
    top_k: int,
    schedule: str,
    group: int,
    opt_steps: int,
    learning_rate: float,
    impl: str,
    tune: tuple,
    has_lin: bool = False,
):
    """Cached sharded-statevector program over the shared engine.

    ``batch`` > 1 runs a `lax.scan` over stacked same-n subgraphs — one
    compiled program for the whole oversized-subproblem group instead of
    one compile-shaped call per subgraph. ``impl`` is the `kernels.ops`
    implementation the program runs: dispatch happens at trace time, so
    it is part of the cache key *and* re-asserted inside the traced
    function (jit traces lazily on first call, possibly outside the
    context the program was requested under) for
    `ops.using_implementation` to reach the per-shard kernels. ``tune``
    keys and re-asserts the `kernels.tuning` block-shape state the same
    way (DESIGN.md §2.7).
    """
    # `p_layers` is cache-key-only (like array shapes, re-handled by
    # jit's own cache)
    del p_layers
    layout = engine.ShardedLayout(
        n=n,
        axis=axis,
        axis_size=int(mesh.shape[axis]),
        schedule=schedule,
        group=group,
    )

    def one(edges, weights, gammas, betas, linear=None):
        cut = engine.cut_table(layout, edges, weights, linear)
        if opt_steps:
            gammas, betas = engine.sharded_ascent(
                layout, cut, gammas, betas, opt_steps, learning_rate
            )
        re, im, in_b = engine.evolve(layout, cut, gammas, betas)
        exp = engine.expectation(layout, re, im, cut, in_b)
        bits, probs = engine.top_candidates(layout, re, im, cut, in_b, top_k)
        return ShardedQAOAResult(bits, probs, exp, gammas, betas)

    if batch == 1:
        local_run = one
    elif has_lin:

        def local_run(edges, weights, gammas, betas, linears):
            def body(_, ewl):
                e, w, l = ewl
                return 0, one(e, w, gammas, betas, l)

            _, res = jax.lax.scan(body, 0, (edges, weights, linears))
            return res

    else:

        def local_run(edges, weights, gammas, betas):
            def body(_, ew):
                e, w = ew
                return 0, one(e, w, gammas, betas)

            _, res = jax.lax.scan(body, 0, (edges, weights))
            return res

    if has_lin:

        def local_run_impl(edges, weights, gammas, betas, linears):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return local_run(edges, weights, gammas, betas, linears)

        in_specs = (P(), P(), P(), P(), P())
    else:

        def local_run_impl(edges, weights, gammas, betas):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return local_run(edges, weights, gammas, betas)

        in_specs = (P(), P(), P(), P())

    run = compat.shard_map(
        local_run_impl,
        mesh,
        in_specs=in_specs,
        out_specs=ShardedQAOAResult(P(), P(), P(), P(), P()),
    )
    return compat.jit(run)


def sharded_qaoa(
    edges,
    weights,
    n: int,
    gammas,
    betas,
    mesh: Mesh,
    axis: str = "model",
    top_k: int = 4,
    schedule: str = "alternating",
    group: int = 7,
    opt_steps: int = 0,
    learning_rate: float = 0.05,
    linear=None,
):
    """One n-qubit QAOA circuit with amplitudes sharded over `axis`.

    Layouts: A (row-sharded: device d owns global indices [d·L, (d+1)·L));
    B (after the qubit-swap all_to_all: device p owns, for every d, the
    slice [d·L + p·chunk, d·L + (p+1)·chunk)). In layout B the local flat
    index's high h bits are the *original* high qubits — so a full local
    mixer still touches each original qubit exactly once per layer.

    ``gammas``/``betas`` are the run (or, with ``opt_steps`` > 0, the
    initial) parameters; the sharded Adam ascent (`engine.sharded_ascent`,
    DESIGN.md §2.6) then optimizes them through the collective schedule
    before the final evolution. ``opt_steps=0`` runs them as given —
    bit-identical to the pre-engine behavior.
    """
    program = _sharded_qaoa_program(
        n, int(gammas.shape[0]), 1, mesh, axis, top_k, schedule, group,
        int(opt_steps), float(learning_rate), ops.get_implementation(),
        tuning.state(), linear is not None,
    )
    if linear is None:
        return program(edges, weights, gammas, betas)
    return program(edges, weights, gammas, betas, linear)


def sharded_qaoa_batch(
    edges,
    weights,
    n: int,
    gammas,
    betas,
    mesh: Mesh,
    axis: str = "model",
    top_k: int = 4,
    schedule: str = "alternating",
    group: int = 7,
    opt_steps: int = 0,
    learning_rate: float = 0.05,
    linears=None,
):
    """`sharded_qaoa` over a stacked batch of same-n subgraphs.

    ``edges`` (B, E_pad, 2) / ``weights`` (B, E_pad) padded with
    zero-weight rows (exact no-ops for the cut values); one cached
    program `lax.scan`s the B subgraphs through the sharded engine.
    ``linears`` (B, n) f32, optional per-vertex diagonal terms.
    Result fields carry a leading (B,) axis.
    """
    b = int(edges.shape[0])
    if b == 1:  # singleton batch: reuse the (scan-free) unbatched program
        res = sharded_qaoa(
            edges[0], weights[0], n, gammas, betas, mesh, axis=axis,
            top_k=top_k, schedule=schedule, group=group,
            opt_steps=opt_steps, learning_rate=learning_rate,
            linear=None if linears is None else linears[0],
        )
        return jax.tree.map(lambda x: jnp.asarray(x)[None], res)
    program = _sharded_qaoa_program(
        n, int(gammas.shape[0]), b, mesh, axis, top_k, schedule, group,
        int(opt_steps), float(learning_rate), ops.get_implementation(),
        tuning.state(), linears is not None,
    )
    if linears is None:
        return program(edges, weights, gammas, betas)
    return program(edges, weights, gammas, betas, linears)


# ---------------------------------------------------------------------------
# 3. sharded merge frontier (level-aware workers)
# ---------------------------------------------------------------------------
@compat.cached_program
def _merge_sharded_program(
    statics: merge_mod.MergePlanStatics,
    beam_width: int,
    mesh: Mesh,
    axis: str,
    split_level: int,
):
    d_ax = mesh.shape[axis]

    def local_run(lo, cand_bits, edge_u, edge_v, edge_w, lin):
        me = jax.lax.axis_index(axis)
        local_plan = merge_mod.MergePlan(
            *statics,
            lo=lo,
            cand_bits=cand_bits,
            edge_u=edge_u,
            edge_v=edge_v,
            edge_w=edge_w,
            lin=lin,
        )
        res = merge_mod.merge_scan(
            local_plan,
            beam_width,
            shard_id=me,
            n_shards=d_ax,
            split_level=split_level,
        )
        return merge_mod.global_winner(res, axis, me)

    run = compat.shard_map(
        local_run,
        mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P()),
    )
    return compat.jit(run)


def merge_sharded(
    plan: merge_mod.MergePlan,
    beam_width: int,
    mesh: Mesh,
    axis: str = "data",
    split_level: int = 1,
):
    """Level-aware merge: frontier striped across `axis` at `split_level`.

    Each shard sweeps its own beam of beam_width rows — the global frontier
    is n_shards × beam_width (the paper's "2K^L workers ⇒ runtime halves
    per doubling" regime). Returns (assignment (V,), cut value), replicated.
    """
    program = _merge_sharded_program(
        merge_mod.plan_statics(plan), beam_width, mesh, axis, split_level
    )
    return program(*merge_mod.plan_arrays(plan))


# ---------------------------------------------------------------------------
# 4. end-to-end orchestrator (DESIGN.md §2.4)
# ---------------------------------------------------------------------------
def as_mesh(mesh_spec):
    """Resolve a Mesh | parsed-spec dict | 'data=2,model=4' string | None."""
    if mesh_spec is None or isinstance(mesh_spec, Mesh):
        return mesh_spec
    from repro.launch import mesh as mesh_mod

    spec = (
        mesh_mod.parse_mesh_spec(mesh_spec)
        if isinstance(mesh_spec, str)
        else dict(mesh_spec)
    )
    return mesh_mod.build_mesh(spec)


def solve_distributed(
    graph,
    cfg,
    mesh_spec,
    partition=None,
    schedule: str = "alternating",
    split_level: int | None = None,
    merge_mode: str = "auto",
):
    """End-to-end ParaQAOA across a device mesh (paper Fig. 3, SPMD form).

    The single-device `repro.core.solve` stages, each replaced by its
    shard_map program where the mesh provides the matching axis:

      1. partition on host — with the qubit budget *lifted* to
         ``cfg.n_qubits + log2(model)`` when a `model` axis is present
         (the sharded statevector holds what one device cannot);
      2. subgraphs that fit one device solve as a padded batch through the
         cached `solve_pool` program over the `data` (and `pod`) axes;
         oversized subgraphs route, grouped by qubit count, through
         batched `sharded_qaoa_batch` programs over `model` with
         `schedule`-selected collectives — linear-ramp parameters when
         ``cfg.sharded_opt_steps == 0``, per-subgraph Adam-ascended
         through the sharded evolution otherwise (DESIGN.md §2.2, §2.6);
      3. the merge frontier stripes across the `data` axis at
         ``split_level`` (default: the paper's L knob,
         ``cfg.merge_level``) via `merge_sharded`; `global_winner`
         replicates the best assignment.
         ``merge_mode`` picks the striping policy (see the stage-3 comment
         below and DESIGN.md §2.3): "auto" stripes only when provably
         exhaustive so the cut value is identical to single-device
         `solve`; "striped" always stripes (the paper's independent
         workers); "single" keeps the merge on one device.

    ``mesh_spec`` is a `jax.sharding.Mesh`, a parsed ``{"data": 2}`` dict,
    a ``"data=2,model=4"`` CLI string, or None — None (or an empty mesh)
    falls back to the single-device `solve` unchanged. ``graph`` may be a
    `Graph` (Max-Cut) or a `core.graph.Problem` (weighted Max-Cut / QUBO /
    MIS); linear terms thread through every stage and the reported value is
    the full objective including the constant offset. Returns the same
    `ParaQAOAOutput` as `solve`.
    """
    from repro.core import paraqaoa as para_mod
    from repro.core import partition as partition_mod
    from repro.core.graph import as_problem, problem_value
    from repro.core.partition import partition_for_solver
    from repro.obs import trace as trace_mod

    mesh = as_mesh(mesh_spec)
    if mesh is None or not mesh.shape:
        return para_mod.solve(graph, cfg, partition=partition)

    prob = as_problem(graph)
    graph = prob.graph
    has_lin = prob.has_linear

    data_axes = compat.mesh_data_axes(mesh)
    model_axis = compat.mesh_model_axis(mesh)
    h = int(np.log2(mesh.shape[model_axis])) if model_axis else 0
    device_cap = cfg.n_qubits
    budget = device_cap + h

    # §8: stage timings come from the ambient tracer's spans (a
    # non-recording tracer by default; `solve_maxcut --trace-out`
    # installs a recording one)
    tr = trace_mod.get_tracer()
    root = tr.begin("solve", n=graph.n, n_edges=graph.n_edges,
                    mesh=dict(mesh.shape))
    with tr.attach(root):
        # ---- stage 1: host-side partition at the lifted budget -----------
        with tr.span("partition", n_qubits=budget) as sp_part:
            part = partition or partition_for_solver(graph, budget)
            # each vertex's linear term lands in exactly one subproblem
            # (first-coverage rule; shared vertices see h = 0 downstream)
            sub_lins = (
                partition_mod.split_linear(part, prob.linear)
                if has_lin else None
            )

        # ---- stage 2: solver pool + oversized-subproblem routing ---------
        qcfg = cfg.qaoa_config()
        small = [i for i, s in enumerate(part.sizes) if s <= device_cap]
        big = [i for i, s in enumerate(part.sizes) if s > device_cap]
        if big and not model_axis:
            tr.end(root)
            raise ValueError(
                f"subgraphs of {max(part.sizes)} qubits exceed the "
                f"{device_cap}-qubit device cap and the mesh has no "
                "`model` axis"
            )

        bit_indices = np.zeros((part.m, cfg.top_k), dtype=np.int64)
        with tr.span("solve_pool", m=part.m, n_small=len(small),
                     n_big=len(big)) as sp_solve:
            if small:
                edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
                    [part.subgraphs[i] for i in small], device_cap
                )
                linears = (
                    qaoa_mod.pad_linear_arrays(
                        [sub_lins[i] for i in small], device_cap
                    )
                    if has_lin else None
                )
                if data_axes:
                    res = solve_pool(edges, weights, masks, qcfg, mesh,
                                     axes=data_axes, linears=linears)
                elif has_lin:  # model-only mesh: single-device pool
                    res = qaoa_mod.solve_subgraph_batch_program(
                        qcfg, has_linear=True
                    )(edges, weights, masks, linears)
                else:
                    res = qaoa_mod.solve_subgraph_batch_program(qcfg)(
                        edges, weights, masks
                    )
                bit_indices[small] = np.asarray(res.bitstrings)
            # oversized subproblems: grouped by qubit count and run as
            # stacked batches through one cached sharded-engine program per
            # n (edge arrays padded with exact-no-op zero rows) — instead
            # of one compile-shaped call per subgraph. With
            # `sharded_opt_steps > 0` the linear-ramp initialization is
            # Adam-ascended per subgraph *through* the sharded evolution
            # (DESIGN.md §2.6); 0 runs the ramp as-is.
            sharded_steps = int(getattr(cfg, "sharded_opt_steps", 0))
            gammas0, betas0 = qaoa_mod.linear_ramp_init(
                cfg.p_layers, cfg.ramp_delta
            )
            by_n: dict[int, list[int]] = {}
            for i in big:
                by_n.setdefault(part.subgraphs[i].n, []).append(i)
            for n_sub, idxs in sorted(by_n.items()):
                with tr.span("sharded_ascent", n_qubits=n_sub,
                             batch=len(idxs), opt_steps=sharded_steps):
                    subs = [part.subgraphs[i] for i in idxs]
                    b_edges, b_weights, _ = qaoa_mod.pad_subgraph_arrays(
                        subs, n_sub
                    )
                    b_linears = (
                        qaoa_mod.pad_linear_arrays(
                            [sub_lins[i] for i in idxs], n_sub
                        )
                        if has_lin else None
                    )
                    res = sharded_qaoa_batch(
                        b_edges,
                        b_weights,
                        n_sub,
                        gammas0,
                        betas0,
                        mesh,
                        axis=model_axis,
                        top_k=cfg.top_k,
                        schedule=schedule,
                        group=qcfg.mixer_group,
                        opt_steps=sharded_steps,
                        learning_rate=cfg.learning_rate,
                        linears=b_linears,
                    )
                    bit_indices[idxs] = (
                        np.asarray(res.bitstrings)
                        .reshape(len(idxs), -1)[:, : cfg.top_k]
                    )

        # ---- stage 3: merge frontier (striped when the policy allows) ----
        # "auto":    stripe only when the striped sweep is provably
        #            exhaustive (no shard ever prunes) — then the cut value
        #            is identical to the single-device merge on the same
        #            candidates;
        # "striped": always stripe (the paper's independent DFS workers).
        #            In the beam-pruned regime each shard prunes within its
        #            own stripe, a *different* heuristic from one global
        #            beam — often better, but not value-identical to
        #            `solve`;
        # "single":  keep the merge on one device (pool/statevector only).
        if merge_mode not in ("auto", "striped", "single"):
            tr.end(root)
            raise ValueError(f"unknown merge_mode {merge_mode!r}")
        with tr.span("merge", m=part.m) as sp_merge:
            plan = merge_mod.build_merge_plan(
                part, bit_indices, cfg.top_k,
                linear=prob.linear if has_lin else None,
            )
            bw = cfg.beam_width or merge_mod.exact_beam_width(
                cfg.top_k, part.m, cap=cfg.beam_cap
            )
            # merge_sharded stripes over one axis only (the innermost data
            # axis); a `pod` axis replicates the striped sweep rather than
            # widening it
            n_shards = int(mesh.shape[data_axes[-1]]) if data_axes else 1
            sl = min(cfg.merge_level if split_level is None else split_level,
                     part.m - 1)
            per_shard = None
            if n_shards > 1 and part.m > 1 and merge_mode != "single":
                w_exact = merge_mod.striped_beam_width(
                    cfg.top_k, part.m, n_shards, sl, cap=cfg.beam_cap
                )
                if w_exact is not None and (cfg.beam_width is None or bw >= 2 * cfg.top_k**part.m):
                    per_shard = w_exact
                elif merge_mode == "striped":
                    per_shard = max(-(-bw // n_shards), 2 * cfg.top_k)
            if per_shard is not None:
                assign, val = merge_sharded(
                    plan, per_shard, mesh, axis=data_axes[-1], split_level=sl
                )
                assignment = np.asarray(assign).reshape(-1)[: graph.n]
                cut = float(np.asarray(val).reshape(-1)[0])
            else:
                merged = merge_mod.merge_scan(plan, bw)
                assignment = np.asarray(merged.assignment)
                cut = float(merged.cut_value)

        # ---- optional beyond-paper refinement ----------------------------
        with tr.span("refine", steps=cfg.refine_steps) as sp_refine:
            if cfg.refine_steps > 0:
                from repro.core.baselines.local_search import refine

                assignment, cut = refine(
                    part.graph, assignment, cfg.refine_steps,
                    linear=prob.linear if has_lin else None,
                )
    tr.end(root)

    # re-score with the full objective; the merge's beam score must agree
    # on the internal (offset-free) part
    obj = float(problem_value(prob, jnp.asarray(assignment)))
    internal = obj - prob.offset
    if cfg.refine_steps == 0:
        assert abs(internal - cut) < 1e-2 * max(1.0, abs(internal)), (internal, cut)
    cut = obj

    timings = {
        "partition_s": sp_part.duration_s,
        "solve_s": sp_solve.duration_s,
        "merge_s": sp_merge.duration_s,
        "refine_s": sp_refine.duration_s,
        "total_s": root.duration_s,
    }
    from repro.core.pei import SolveReport

    report = SolveReport(
        method="paraqaoa-distributed",
        n_vertices=graph.n,
        cut_value=cut,
        runtime_s=timings["total_s"],
        extra={
            "m_subgraphs": part.m,
            "k": cfg.top_k,
            "beam": bw,
            "mesh": dict(mesh.shape),
            "merge_shards": n_shards if per_shard is not None else 1,
            "merge_mode": merge_mode,
            "merge_per_shard_beam": per_shard,
            "sharded_subproblems": len(big),
            "sharded_opt_steps": sharded_steps,
            "schedule": schedule,
            **timings,
        },
    )
    return para_mod.ParaQAOAOutput(
        assignment=assignment,
        cut_value=cut,
        partition=part,
        report=report,
        timings=timings,
    )

"""Distributed execution of ParaQAOA on a device mesh.

Three shard_map programs, matching DESIGN.md §2:

1. `solve_pool`       — solver-pool data parallelism: the vmapped subgraph
   batch is sharded across the `data` (and `pod`) axes. This is the paper's
   "N_s QAOA solvers × T rounds" recast as SPMD.

2. `sharded_qaoa`     — statevector tensor parallelism: one subproblem's
   2^n amplitudes sharded across the `model` axis. The transverse-field
   mixer factorizes per qubit, so only the log2(axis_size) "global" qubits
   need cross-device mixing; one qubit-swap `all_to_all` rotates them into
   locality. Lifts the paper's 26-qubit/GPU cap to 26 + log2(model) qubits.

   Two collective schedules:
     - "faithful":    swap in + swap back every layer (2 a2a/layer) — the
       direct port of a distributed gate-level simulator.
     - "alternating": keep the swapped layout between layers and evaluate
       the diagonal cost layer with *relabelled* cut values (1 a2a/layer —
       a diagonal Hamiltonian makes the layout change a pure relabelling).
       Beyond-paper optimization; see EXPERIMENTS.md §Perf.

3. `merge_sharded`    — the merge frontier striped across `data` at the
   paper's starting level L: each shard prunes its own stripe locally (the
   paper's independent DFS workers); a pmax/pmin picks the global winner.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map

from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# 1. solver-pool data parallelism
# ---------------------------------------------------------------------------
def solve_pool(edges, weights, masks, cfg: qaoa_mod.QAOAConfig, mesh: Mesh,
               axes=("data",)):
    """Batched QAOA across the mesh: round-robin subgraphs over devices.

    Pads the batch to a multiple of the axis size (padding entries are
    empty graphs) and strips the padding on return.
    """
    total = int(np.prod([mesh.shape[a] for a in axes]))
    m = edges.shape[0]
    m_pad = ((m + total - 1) // total) * total
    if m_pad != m:
        pad = m_pad - m
        edges = jnp.concatenate(
            [edges, jnp.zeros((pad,) + edges.shape[1:], edges.dtype)]
        )
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,) + weights.shape[1:], weights.dtype)]
        )
        masks = jnp.concatenate([masks, jnp.ones((pad,), masks.dtype)])

    spec = P(axes)

    def run(e, w, mk):
        return qaoa_mod.solve_subgraph_batch(e, w, mk, cfg)

    sharded = shard_map(
        run,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=qaoa_mod.QAOAResult(spec, spec, spec, spec, spec),
        check_vma=False,
    )
    res = jax.jit(sharded)(edges, weights, masks)
    return jax.tree.map(lambda x: x[:m], res)


# ---------------------------------------------------------------------------
# 2. sharded-statevector QAOA (statevector tensor parallelism)
# ---------------------------------------------------------------------------
class ShardedQAOAResult(NamedTuple):
    bitstrings: jnp.ndarray  # (K,) int32 global basis indices (replicated)
    probs: jnp.ndarray  # (K,)
    expectation: jnp.ndarray  # scalar


def _mix_bits(re, im, n_local: int, lo_bit: int, nbits: int, beta):
    """Mix qubits [lo_bit, lo_bit+nbits) of a flat 2^n_local local state."""
    x = 2 ** (n_local - lo_bit - nbits)
    y = 2**lo_bit
    C, D = ref.rx_kron_parts(beta, nbits)
    re3 = re.reshape(x, 2**nbits, y)
    im3 = im.reshape(x, 2**nbits, y)
    re_new = jnp.einsum("ab,xby->xay", C, re3) - jnp.einsum("ab,xby->xay", D, im3)
    im_new = jnp.einsum("ab,xby->xay", C, im3) + jnp.einsum("ab,xby->xay", D, re3)
    return re_new.reshape(-1), im_new.reshape(-1)


def sharded_qaoa(
    edges,
    weights,
    n: int,
    gammas,
    betas,
    mesh: Mesh,
    axis: str = "model",
    top_k: int = 4,
    schedule: str = "alternating",
    group: int = 7,
):
    """One n-qubit QAOA circuit with amplitudes sharded over `axis`.

    Layouts: A (row-sharded: device d owns global indices [d·L, (d+1)·L));
    B (after the qubit-swap all_to_all: device p owns, for every d, the
    slice [d·L + p·chunk, d·L + (p+1)·chunk)). In layout B the local flat
    index's high h bits are the *original* high qubits — so a full local
    mixer still touches each original qubit exactly once per layer.
    """
    d_ax = mesh.shape[axis]
    h = int(np.log2(d_ax))
    assert 2**h == d_ax, f"axis size {d_ax} must be a power of two"
    n_local = n - h
    L = 2**n_local
    chunk = L // d_ax
    assert chunk >= 1, f"statevector too small for the mesh: n={n}, axis={d_ax}"
    log2_chunk = int(np.log2(chunk))
    p_layers = int(gammas.shape[0])

    def local_run(edges, weights, gammas, betas):
        me = jax.lax.axis_index(axis)
        idx_a = me * L + jnp.arange(L, dtype=jnp.int32)
        q = jnp.arange(L, dtype=jnp.int32)
        idx_b = (q // chunk) * L + me * chunk + (q % chunk)
        cutv_a = ref.cutvals_at(idx_a, edges, weights)
        cutv_b = ref.cutvals_at(idx_b, edges, weights)

        re = jnp.full((L,), 2.0 ** (-n / 2), dtype=jnp.float32)
        im = jnp.zeros((L,), dtype=jnp.float32)

        def a2a(x):
            return jax.lax.all_to_all(
                x.reshape(d_ax, chunk), axis, split_axis=0, concat_axis=0
            ).reshape(-1)

        in_b = False
        for l in range(p_layers):  # p is small; unrolled keeps parity static
            g, b = gammas[l], betas[l]
            cutv = cutv_b if in_b else cutv_a
            re, im = ref.apply_phase(re, im, cutv, g)
            # mix the n-h locally-resident qubits
            re, im = ops.apply_mixer(re, im, n_local, b, group=group)
            # rotate the h shard-axis qubits into locality and mix them:
            # after the swap they sit at local bits [log2_chunk, log2_chunk+h)
            re, im = a2a(re), a2a(im)
            re, im = _mix_bits(re, im, n_local, log2_chunk, h, b)
            if schedule == "alternating":
                in_b = not in_b
            else:  # faithful: swap straight back to layout A
                re, im = a2a(re), a2a(im)

        cutv = cutv_b if in_b else cutv_a
        idx = idx_b if in_b else idx_a
        exp = jax.lax.psum(ref.expectation(re, im, cutv), axis)
        probs = re * re + im * im
        v, i_loc = jax.lax.top_k(probs, top_k)
        all_v = jax.lax.all_gather(v, axis).reshape(-1)
        all_i = jax.lax.all_gather(idx[i_loc], axis).reshape(-1)
        vv, ii = jax.lax.top_k(all_v, top_k)
        return ShardedQAOAResult(all_i[ii], vv, exp)

    run = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(P(), P(), P(), P()),
        out_specs=ShardedQAOAResult(P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(run)(edges, weights, gammas, betas)


# ---------------------------------------------------------------------------
# 3. sharded merge frontier (level-aware workers)
# ---------------------------------------------------------------------------
def merge_sharded(
    plan: merge_mod.MergePlan,
    beam_width: int,
    mesh: Mesh,
    axis: str = "data",
    split_level: int = 1,
):
    """Level-aware merge: frontier striped across `axis` at `split_level`.

    Each shard sweeps its own beam of beam_width rows — the global frontier
    is n_shards × beam_width (the paper's "2K^L workers ⇒ runtime halves
    per doubling" regime). Returns (assignment (V,), cut value), replicated.
    """
    d_ax = mesh.shape[axis]

    def local_run(lo, cand_bits, edge_u, edge_v, edge_w):
        me = jax.lax.axis_index(axis)
        local_plan = merge_mod.MergePlan(
            n_vert=plan.n_vert,
            n_pad=plan.n_pad,
            n_max=plan.n_max,
            k=plan.k,
            lo=lo,
            cand_bits=cand_bits,
            edge_u=edge_u,
            edge_v=edge_v,
            edge_w=edge_w,
        )
        res = merge_mod.merge_scan(
            local_plan,
            beam_width,
            shard_id=me,
            n_shards=d_ax,
            split_level=split_level,
        )
        best = jax.lax.pmax(res.cut_value, axis)
        rank = jnp.where(res.cut_value >= best, me, jnp.int32(2**30))
        winner = jax.lax.pmin(rank, axis)
        mask = (me == winner).astype(res.assignment.dtype)
        assign = jax.lax.psum(res.assignment * mask, axis)
        return assign, best

    run = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(run)(
        plan.lo, plan.cand_bits, plan.edge_u, plan.edge_v, plan.edge_w
    )

"""Vectorized 1-flip local search.

Used two ways:
  - as a classical baseline (`local_search`, random restarts),
  - as the beyond-paper refinement pass on ParaQAOA's merged assignment
    (`refine`) — a few sweeps of best-improvement flips recover most of the
    AR lost to dropped inter-partition edges at negligible cost.

The flip gain for vertex v is  g(v) = deg_w(v) - 2 * cut_incident(v),
computed for all vertices at once from the edge list (no dense matrix), so
one sweep is O(|E|) and fully vectorized.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, cut_value
from repro.core.pei import SolveReport


@functools.partial(jax.jit, static_argnums=(4, 5))
def _sweeps(edges, weights, linear, assignment, steps: int, n: int):
    # Acceptance threshold is *relative* to the objective scale: the old
    # absolute 1e-6 silently rejected every real improvement on graphs with
    # uniformly tiny weights (and accepted float noise on huge ones).
    scale = jnp.sum(jnp.abs(weights)) + jnp.sum(jnp.abs(linear))
    eps = 1e-6 * scale

    def gains(s):
        su = s[edges[:, 0]]
        sv = s[edges[:, 1]]
        crossed = (su ^ sv).astype(weights.dtype)
        # incident cut weight and degree per vertex
        inc = jnp.zeros((n,), weights.dtype)
        inc = inc.at[edges[:, 0]].add(weights * crossed)
        inc = inc.at[edges[:, 1]].add(weights * crossed)
        deg = jnp.zeros((n,), weights.dtype)
        deg = deg.at[edges[:, 0]].add(weights)
        deg = deg.at[edges[:, 1]].add(weights)
        quad = deg - 2.0 * inc  # gain of flipping each vertex alone
        # flipping v changes the linear term by h_v * (1 - 2 s_v)
        return quad + linear * (1.0 - 2.0 * s.astype(weights.dtype))

    def body(s, _):
        g = gains(s)
        v = jnp.argmax(g)
        improve = g[v] > eps
        s = jnp.where(
            jnp.arange(n) == v, jnp.where(improve, 1 - s[v], s[v]), s
        ).astype(s.dtype)
        return s, None

    s, _ = jax.lax.scan(body, assignment, None, length=steps)
    return s


def _score(graph: Graph, s: np.ndarray, linear) -> float:
    """From-scratch objective of a final assignment. The scan used to carry
    a running score updated by +g[v] per flip; in float32 that carry drifts
    from the true value over hundreds of sweeps on weighted instances, so
    every caller now re-scores the *assignment* instead."""
    val = float(cut_value(graph, jnp.asarray(s)))
    if linear is not None:
        lin = np.asarray(linear, dtype=np.float64)
        val += float(lin @ np.asarray(s, dtype=np.float64))
    return val


def refine(graph: Graph, assignment: np.ndarray, steps: int, linear=None):
    """Best-improvement 1-flip refinement of an existing assignment.

    ``linear`` (n,) f32, optional, refines the full internal objective
    (quadratic cut + per-vertex linear terms) for QUBO/MIS problems.
    """
    s = jnp.asarray(assignment, dtype=jnp.int32)
    lin = (
        jnp.zeros((graph.n,), dtype=jnp.float32)
        if linear is None
        else jnp.asarray(linear, dtype=jnp.float32)
    )
    s = _sweeps(graph.edges, graph.weights, lin, s, steps, graph.n)
    out = np.asarray(s, dtype=np.int8)
    return out, _score(graph, out, linear)


def local_search(graph: Graph, restarts: int = 8, steps: int = 200, seed: int = 0):
    """Random-restart 1-flip local search baseline."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    zeros = jnp.zeros((graph.n,), dtype=jnp.float32)
    best_s, best_v = None, -np.inf
    for _ in range(restarts):
        s0 = rng.integers(0, 2, size=graph.n).astype(np.int32)
        s = _sweeps(graph.edges, graph.weights, zeros, jnp.asarray(s0), steps, graph.n)
        s = np.asarray(s, dtype=np.int8)
        v = _score(graph, s, None)
        if v > best_v:
            best_v, best_s = v, s
    t1 = time.perf_counter()
    report = SolveReport(
        method="local_search",
        n_vertices=graph.n,
        cut_value=best_v,
        runtime_s=t1 - t0,
    )
    return best_s, best_v, report

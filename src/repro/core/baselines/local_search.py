"""Vectorized 1-flip local search.

Used two ways:
  - as a classical baseline (`local_search`, random restarts),
  - as the beyond-paper refinement pass on ParaQAOA's merged assignment
    (`refine`) — a few sweeps of best-improvement flips recover most of the
    AR lost to dropped inter-partition edges at negligible cost.

The flip gain for vertex v is  g(v) = deg_w(v) - 2 * cut_incident(v),
computed for all vertices at once from the edge list (no dense matrix), so
one sweep is O(|E|) and fully vectorized.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, cut_value
from repro.core.pei import SolveReport


@functools.partial(jax.jit, static_argnums=(3, 4))
def _sweeps(edges, weights, assignment, steps: int, n: int):
    def gains(s):
        su = s[edges[:, 0]]
        sv = s[edges[:, 1]]
        crossed = (su ^ sv).astype(weights.dtype)
        # incident cut weight and degree per vertex
        inc = jnp.zeros((n,), weights.dtype)
        inc = inc.at[edges[:, 0]].add(weights * crossed)
        inc = inc.at[edges[:, 1]].add(weights * crossed)
        deg = jnp.zeros((n,), weights.dtype)
        deg = deg.at[edges[:, 0]].add(weights)
        deg = deg.at[edges[:, 1]].add(weights)
        return deg - 2.0 * inc  # gain of flipping each vertex alone

    def body(carry, _):
        s, cut = carry
        g = gains(s)
        v = jnp.argmax(g)
        improve = g[v] > 1e-6
        s = jnp.where(
            jnp.arange(n) == v, jnp.where(improve, 1 - s[v], s[v]), s
        ).astype(s.dtype)
        cut = cut + jnp.where(improve, g[v], 0.0)
        return (s, cut), None

    su = assignment[edges[:, 0]]
    sv = assignment[edges[:, 1]]
    cut0 = jnp.sum(weights * (su ^ sv).astype(weights.dtype))
    (s, cut), _ = jax.lax.scan(body, (assignment, cut0), None, length=steps)
    return s, cut


def refine(graph: Graph, assignment: np.ndarray, steps: int):
    """Best-improvement 1-flip refinement of an existing assignment."""
    s = jnp.asarray(assignment, dtype=jnp.int32)
    s, cut = _sweeps(graph.edges, graph.weights, s, steps, graph.n)
    return np.asarray(s, dtype=np.int8), float(cut)


def local_search(graph: Graph, restarts: int = 8, steps: int = 200, seed: int = 0):
    """Random-restart 1-flip local search baseline."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    best_s, best_v = None, -1.0
    for _ in range(restarts):
        s0 = rng.integers(0, 2, size=graph.n).astype(np.int32)
        s, v = _sweeps(graph.edges, graph.weights, jnp.asarray(s0), steps, graph.n)
        if float(v) > best_v:
            best_v, best_s = float(v), np.asarray(s, dtype=np.int8)
    t1 = time.perf_counter()
    report = SolveReport(
        method="local_search",
        n_vertices=graph.n,
        cut_value=best_v,
        runtime_s=t1 - t0,
    )
    return best_s, best_v, report

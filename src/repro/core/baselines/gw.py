"""Goemans–Williamson baseline via Burer–Monteiro low-rank SDP.

The paper uses GW (0.878-guarantee, interior-point SDP) as the medium-scale
reference. Interior-point SDP is O(V^3)+ and dies well before 10,000
vertices, so we solve the SDP relaxation in its Burer–Monteiro low-rank
factorized form — maximize sum_ij w_ij (1 - <x_i, x_j>)/2 over unit vectors
x_i in R^r with r = ceil(sqrt(2V)) (above the Barvinok–Pataki rank bound, so
the factorized problem has no spurious local optima in practice) — with
projected-gradient ascent in JAX, then classic random-hyperplane rounding.
This keeps GW-quality cuts available as a reference at every scale the
paper touches (and is itself a beyond-paper engineering contribution).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, cut_value_batch
from repro.core.pei import SolveReport


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def _bm_optimize(edges, weights, x0, n: int, steps: int, lr: float):
    """Projected gradient ascent on the low-rank SDP objective."""

    def objective(x):
        # sum_e w_e (1 - <x_u, x_v>) / 2 ; constants dropped for the gradient
        dots = jnp.sum(x[edges[:, 0]] * x[edges[:, 1]], axis=-1)
        return -0.5 * jnp.sum(weights * dots)

    grad = jax.grad(objective)

    def body(x, _):
        g = grad(x)
        x = x + lr * g
        x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        return x, None

    x, _ = jax.lax.scan(body, x0, None, length=steps)
    return x


@functools.partial(jax.jit, static_argnums=(2,))
def _round_hyperplanes(x, key, rounds: int):
    r = x.shape[-1]
    h = jax.random.normal(key, (rounds, r), dtype=x.dtype)
    signs = (x @ h.T) >= 0.0  # (V, rounds)
    return signs.T.astype(jnp.int8)  # (rounds, V)


def goemans_williamson(
    graph: Graph,
    steps: int = 300,
    rounds: int = 64,
    lr: float = 0.05,
    seed: int = 0,
    rank: int | None = None,
):
    """Returns (assignment, cut value, SolveReport)."""
    t0 = time.perf_counter()
    n = graph.n
    r = rank or max(4, int(np.ceil(np.sqrt(2.0 * n))))
    key = jax.random.PRNGKey(seed)
    k0, k1 = jax.random.split(key)
    x0 = jax.random.normal(k0, (n, r), dtype=jnp.float32)
    x0 = x0 / jnp.linalg.norm(x0, axis=-1, keepdims=True)

    x = _bm_optimize(graph.edges, graph.weights, x0, n, steps, lr)
    assigns = _round_hyperplanes(x, k1, rounds)
    cuts = cut_value_batch(graph, assigns)
    best = int(jnp.argmax(cuts))
    val = float(cuts[best])
    t1 = time.perf_counter()
    report = SolveReport(
        method="gw", n_vertices=n, cut_value=val, runtime_s=t1 - t0,
        extra={"rank": r, "steps": steps, "rounds": rounds},
    )
    return np.asarray(assigns[best]), val, report

from repro.core.baselines.brute_force import brute_force_maxcut
from repro.core.baselines.gw import goemans_williamson
from repro.core.baselines.local_search import local_search, refine
from repro.core.baselines.qaoa_in_qaoa import qaoa_in_qaoa

__all__ = [
    "brute_force_maxcut",
    "goemans_williamson",
    "local_search",
    "refine",
    "qaoa_in_qaoa",
]

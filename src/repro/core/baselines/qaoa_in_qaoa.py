"""QAOA-in-QAOA (QAOA², Zhou et al. 2023) baseline.

Partition the graph, QAOA-solve each subgraph, then decide each subgraph's
global orientation (keep / flip) by solving a *contracted* Max-Cut whose M
supernodes are the subgraphs: an inter-edge (u, v) between subgraphs a and b
crosses the global cut iff s_u ⊕ s_v ⊕ z_a ⊕ z_b = 1, so the orientation
problem is Max-Cut on the contracted graph with signed weights
(w_diff − w_same). The contraction recurses until it fits one solver —
exactly the hierarchical "QAOA within QAOA" scheme.

Note on fairness: the reference QAOA² implementation enumerates subproblem
combinations exhaustively on the host, which is why the paper measures hours
at 400 vertices. This reimplementation solves the same contracted problem
on-device, so runtime comparisons in our benchmarks are *conservative*
(QAOA² is faster here than in the paper; AR math is identical).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import connectivity_preserving_partition
from repro.core.pei import SolveReport


def _solve_orientation(contracted: Graph, n_qubits: int, cfg) -> np.ndarray:
    """Max-Cut on the (possibly signed) contracted graph."""
    m = contracted.n
    if m == 1:
        return np.zeros(1, dtype=np.int8)
    if m <= n_qubits:
        edges, weights, masks = qaoa_mod.pad_subgraph_arrays([contracted], n_qubits)
        res = qaoa_mod.solve_subgraph_batch(edges, weights, masks, cfg)
        idx = int(np.asarray(res.bitstrings)[0, 0])
        return ((idx >> np.arange(m)) & 1).astype(np.int8)
    return _recurse(contracted, n_qubits, cfg)


def _contract(graph: Graph, ranges, local_bits) -> tuple[Graph, np.ndarray]:
    """Build the signed contracted graph from per-subgraph solutions."""
    m = len(ranges)
    n = graph.n
    owner = np.zeros(n, dtype=np.int32)
    sbits = np.zeros(n, dtype=np.int8)
    for a, ((lo, hi), bits) in enumerate(zip(ranges, local_bits)):
        owner[lo:hi] = a
        sbits[lo:hi] = bits[: hi - lo]

    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    oa, ob = owner[e[:, 0]], owner[e[:, 1]]
    inter = oa != ob
    su, sv = sbits[e[:, 0]], sbits[e[:, 1]]
    # signed weight: +w if crossing when z_a != z_b (s_u == s_v), else -w
    sign = np.where((su ^ sv)[inter] == 0, 1.0, -1.0)
    wmat = np.zeros((m, m), dtype=np.float64)
    a_, b_ = oa[inter], ob[inter]
    np.add.at(wmat, (a_, b_), sign * w[inter])
    np.add.at(wmat, (b_, a_), sign * w[inter])
    iu, ju = np.triu_indices(m, k=1)
    nz = wmat[iu, ju] != 0
    contracted = Graph.from_edges(
        m, np.stack([iu[nz], ju[nz]], 1), wmat[iu, ju][nz].astype(np.float32)
    )
    return contracted, sbits


def _recurse(graph: Graph, n_qubits: int, cfg) -> np.ndarray:
    m_parts = int(np.ceil(graph.n / (n_qubits - 1)))
    part = connectivity_preserving_partition(graph, m_parts)
    edges, weights, masks = qaoa_mod.pad_subgraph_arrays(part.subgraphs, n_qubits)
    res = qaoa_mod.solve_subgraph_batch(edges, weights, masks, cfg)
    idx = np.asarray(res.bitstrings)[:, 0]  # top-1 per subgraph
    local_bits = [
        ((int(idx[i]) >> np.arange(part.sizes[i])) & 1).astype(np.int8)
        for i in range(part.m)
    ]
    contracted, sbits = _contract(graph, part.ranges, local_bits)
    z = _solve_orientation(contracted, n_qubits, cfg)
    owner = np.zeros(graph.n, dtype=np.int32)
    for a, (lo, hi) in enumerate(part.ranges):
        owner[lo:hi] = a
    return (sbits ^ z[owner]).astype(np.int8)


def qaoa_in_qaoa(
    graph: Graph,
    n_qubits: int = 14,
    p_layers: int = 3,
    opt_steps: int = 30,
    top_k: int = 1,
):
    """Returns (assignment, cut value, SolveReport)."""
    t0 = time.perf_counter()
    cfg = qaoa_mod.QAOAConfig(
        n_qubits=n_qubits, p_layers=p_layers, opt_steps=opt_steps, top_k=max(top_k, 1)
    )
    if graph.n <= n_qubits:
        edges, weights, masks = qaoa_mod.pad_subgraph_arrays([graph], n_qubits)
        res = qaoa_mod.solve_subgraph_batch(edges, weights, masks, cfg)
        idx = int(np.asarray(res.bitstrings)[0, 0])
        assignment = ((idx >> np.arange(graph.n)) & 1).astype(np.int8)
    else:
        assignment = _recurse(graph, n_qubits, cfg)
    val = float(cut_value(graph, jnp.asarray(assignment)))
    t1 = time.perf_counter()
    report = SolveReport(
        method="qaoa_in_qaoa", n_vertices=graph.n, cut_value=val, runtime_s=t1 - t0
    )
    return assignment, val, report

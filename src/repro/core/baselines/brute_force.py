"""Exact Max-Cut by exhaustive enumeration (paper Table 2's oracle).

Feasible to ~24 vertices; enumeration reuses the kernels' all-basis-state
cut-value op (the same math that powers the QAOA diagonal cost layer), so
the oracle and the solver share one audited code path.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, Problem, as_problem
from repro.core.pei import SolveReport
from repro.kernels import ops


def brute_force_maxcut(graph: Graph, chunk_qubits: int = 22):
    """Returns (assignment (n,) int8, cut value float, SolveReport)."""
    n = graph.n
    if n > 30:
        raise ValueError(f"brute force infeasible for n={n}")
    t0 = time.perf_counter()
    best_val = -1.0
    best_idx = 0
    # fix vertex 0 = 0 (cut symmetry) → enumerate 2^(n-1)
    total = 1 << (n - 1)
    step = 1 << min(chunk_qubits, n - 1)
    edges, weights = graph.edges, graph.weights
    for start in range(0, total, step):
        m = min(step, total - start)
        idx = jnp.arange(start, start + m, dtype=jnp.int32) << 1  # bit0 = 0
        s0 = (idx[:, None] >> edges[None, :, 0]) & 1
        s1 = (idx[:, None] >> edges[None, :, 1]) & 1
        cuts = ((s0 ^ s1).astype(jnp.float32) @ weights)
        j = int(jnp.argmax(cuts))
        v = float(cuts[j])
        if v > best_val:
            best_val = v
            best_idx = start + j
    bits = ((np.int64(best_idx) << 1) >> np.arange(n)) & 1
    t1 = time.perf_counter()
    report = SolveReport(
        method="brute_force", n_vertices=n, cut_value=best_val, runtime_s=t1 - t0
    )
    return bits.astype(np.int8), best_val, report


def brute_force_problem(problem: Graph | Problem, chunk_qubits: int = 22):
    """Exact maximizer of a full `Problem` objective (quadratic + linear +
    offset) by exhaustive enumeration.

    Unlike `brute_force_maxcut` this enumerates *all* 2^n assignments: the
    bit0 = 0 symmetry it exploits holds only for pure cuts — a nonzero
    linear term breaks the global flip invariance. Returns
    (assignment (n,) int8, objective value float, SolveReport).
    """
    prob = as_problem(problem)
    graph = prob.graph
    n = graph.n
    if n > 26:
        raise ValueError(f"brute force infeasible for n={n}")
    t0 = time.perf_counter()
    edges = graph.edges
    weights = graph.weights
    lin = jnp.asarray(prob.linear, dtype=jnp.float32)
    best_val = -np.inf
    best_idx = 0
    total = 1 << n
    step = 1 << min(chunk_qubits, n)
    vbits = jnp.arange(n, dtype=jnp.int32)
    for start in range(0, total, step):
        m = min(step, total - start)
        idx = jnp.arange(start, start + m, dtype=jnp.int32)
        s0 = (idx[:, None] >> edges[None, :, 0]) & 1
        s1 = (idx[:, None] >> edges[None, :, 1]) & 1
        vals = (s0 ^ s1).astype(jnp.float32) @ weights
        xbits = ((idx[:, None] >> vbits[None, :]) & 1).astype(jnp.float32)
        vals = vals + xbits @ lin
        j = int(jnp.argmax(vals))
        v = float(vals[j])
        if v > best_val:
            best_val = v
            best_idx = start + j
    bits = (np.int64(best_idx) >> np.arange(n)) & 1
    best_val += float(prob.offset)
    t1 = time.perf_counter()
    report = SolveReport(
        method="brute_force", n_vertices=n, cut_value=best_val, runtime_s=t1 - t0
    )
    return bits.astype(np.int8), best_val, report

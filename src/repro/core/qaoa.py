"""Statevector QAOA solver for Max-Cut subproblems.

Max-Cut's cost Hamiltonian is diagonal in the computational basis, so one
QAOA layer is:  (1) an elementwise phase by the per-basis-state cut value,
(2) the transverse-field mixer RX(2β)^{⊗n}, applied as grouped matmuls.
The evolution itself lives in `repro.core.engine` (DESIGN.md §2.6) — the
same engine the sharded program runs per shard — with every op dispatched
through `repro.kernels.ops` (Pallas on TPU, jnp on CPU).

The classical outer loop (paper: per-subgraph scipy-style optimizers) is a
*batched, differentiable* Adam ascent on ⟨H_C⟩ — all subgraphs optimize
simultaneously under one `vmap`, initialized from a linear ramp
[Sack & Serbyn 2021; Montañez-Barrera & Michielsen 2025].
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import engine
from repro.core.graph import Graph
from repro.kernels import ops
from repro.kernels import tuning


@dataclasses.dataclass(frozen=True)
class QAOAConfig:
    n_qubits: int  # statevector size (subgraphs padded to this)
    p_layers: int = 3
    opt_steps: int = 30
    learning_rate: float = 0.05
    ramp_delta: float = 0.75  # linear-ramp initialization scale
    top_k: int = 4  # paper's K (Selective Distribution Exploration)
    mixer_group: int = 7  # qubits per fused mixer matmul (7 → 128×128)


class QAOAResult(NamedTuple):
    bitstrings: jnp.ndarray  # (K,) int32 basis indices (pad bits forced to 0)
    probs: jnp.ndarray  # (K,) float32 marginal probabilities
    expectation: jnp.ndarray  # scalar: final ⟨cut⟩
    gammas: jnp.ndarray  # (p,) optimized
    betas: jnp.ndarray  # (p,)


def linear_ramp_init(p: int, delta: float):
    """γ_l ramps up, β_l ramps down — discretized annealing schedule."""
    l = (jnp.arange(p, dtype=jnp.float32) + 0.5) / p
    return delta * l, delta * (1.0 - l)


def qaoa_statevector(cutv, n: int, gammas, betas, group: int = 7):
    """Run the p-layer ansatz; returns (re, im) planes of the final state.

    A thin wrapper over the shared engine's `evolve` on a `FlatLayout` —
    the identical per-layer code the sharded program runs per shard
    (DESIGN.md §2.6).
    """
    layout = engine.FlatLayout(n=n, group=group)
    cut = engine.CutTable(cutv, None, None, None)
    re, im, _ = engine.evolve(layout, cut, gammas, betas)
    return re, im


def qaoa_expectation(params, cutv, n: int, group: int = 7):
    gammas, betas = params
    re, im = qaoa_statevector(cutv, n, gammas, betas, group=group)
    return ops.expectation(re, im, cutv)


def optimize_params(cutv, n: int, cfg: QAOAConfig):
    """Adam ascent on ⟨cut⟩. Returns optimized (gammas, betas).

    The update rule is the shared `engine.adam_scan` — the same scan the
    sharded ascent runs per shard (DESIGN.md §2.6). Like
    `engine.sharded_ascent`, the differentiated evolution runs under the
    caller's active implementation: the `kernels.ops` custom-vjp rules
    (DESIGN.md §2.7) make the backward trace fire the same dispatched
    kernels, so no `xla` gradient pin is needed."""
    g0, b0 = linear_ramp_init(cfg.p_layers, cfg.ramp_delta)

    neg_obj = lambda p: -qaoa_expectation(p, cutv, n, group=cfg.mixer_group)
    return engine.adam_scan(
        jax.grad(neg_obj), (g0, b0), cfg.opt_steps, cfg.learning_rate
    )


def topk_marginal(re, im, n: int, real_mask, k: int):
    """Top-k bitstrings of the *marginal* over real (non-padding) qubits.

    Padding qubits keep the statevector shape uniform across a vmapped
    subgraph batch; their amplitude mass is folded back onto the
    pad-bits-zero representative via a masked-key segment sum so top-k never
    returns duplicates that differ only in padding bits. ``real_mask`` is
    (2^n_real - 1) and may be traced (per-subgraph under vmap).
    """
    probs = re * re + im * im
    idx = jnp.arange(2**n, dtype=jnp.int32)
    keys = idx & real_mask
    marg = jnp.zeros_like(probs).at[keys].add(probs)
    vals, inds = jax.lax.top_k(marg, k)
    return inds, vals


def solve_subgraph(edges, weights, real_mask, cfg: QAOAConfig, linear=None) -> QAOAResult:
    """End-to-end QAOA solve of one (padded) subgraph.

    edges/weights are padded to a common (E_pad,) size; real_mask encodes the
    live qubit count. ``linear`` (n_qubits,) f32, optional, adds per-vertex
    diagonal terms (QUBO/MIS) to the cost oracle; ``None`` keeps the Max-Cut
    trace identical to the linear-free solver. Designed to be vmapped across
    a subgraph batch.
    """
    n = cfg.n_qubits
    cutv = ops.cutvals(n, edges, weights, linear)
    gammas, betas = optimize_params(cutv, n, cfg)
    re, im = qaoa_statevector(cutv, n, gammas, betas, group=cfg.mixer_group)
    exp = ops.expectation(re, im, cutv)
    bits, probs = topk_marginal(re, im, n, real_mask, cfg.top_k)
    return QAOAResult(bits, probs, exp, gammas, betas)


solve_subgraph_batch = jax.vmap(solve_subgraph, in_axes=(0, 0, 0, None))
solve_subgraph_batch_linear = jax.vmap(solve_subgraph, in_axes=(0, 0, 0, None, 0))


@compat.cached_program
def _solve_subgraph_batch_program(
    cfg: QAOAConfig, impl: str, tune: tuple, has_lin: bool = False
):
    """Impl- and tuning-keyed builder behind `solve_subgraph_batch_program`.

    The `kernels.ops` dispatch reads the active implementation at
    *trace* time, so two impls must map to two compiled programs for
    `ops.using_implementation` to reach this path (the same contract
    `_sharded_qaoa_program` keeps, DESIGN.md §2.6). The keyed ``impl``
    is re-asserted inside the traced function: jit traces lazily on
    first call, which may happen outside the context the program was
    requested under — the key and the traced dispatch must not disagree.
    ``tune`` is the `kernels.tuning` block-shape state (DESIGN.md §2.7),
    re-asserted the same way and for the same reason — tile choices are
    trace-time too, and the key makes them visible to the compile ledger.
    ``has_lin`` selects the linear-terms variant (QUBO/MIS buckets, 4th
    input array); the False key compiles the exact Max-Cut program of the
    linear-free solver, keeping that path bit-identical.
    """

    if has_lin:

        def run(e, w, m, l):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return solve_subgraph_batch_linear(e, w, m, cfg, l)

    else:

        def run(e, w, m):
            with ops.using_implementation(impl), tuning.using_state(tune):
                return solve_subgraph_batch(e, w, m, cfg)

    return jax.jit(run)


def solve_subgraph_batch_program(cfg: QAOAConfig, has_linear: bool = False):
    """Cached whole-batch jit of `solve_subgraph_batch` for one config.

    The end-to-end drivers run this instead of the eager vmap: one fused
    XLA program per static config (~1.7x faster on CPU), and — because the
    distributed `solve_pool` wraps the *same* jitted computation in
    shard_map — the single-device and pool-parallel paths produce
    bit-identical candidates (XLA's eager op-by-op dispatch rounds
    differently from the fused program; the default 30 Adam steps
    (``QAOAConfig.opt_steps``) on a non-convex landscape amplify that
    last-ulp difference into different top-k picks). The underlying
    cache keys on (config, active `kernels.ops` implementation, active
    `kernels.tuning` block-shape state, linear-terms variant).
    """
    return _solve_subgraph_batch_program(
        cfg, ops.get_implementation(), tuning.state(), bool(has_linear)
    )


def index_to_bits(indices: jnp.ndarray, n: int) -> jnp.ndarray:
    """(...,) int32 basis indices → (..., n) int8 bit arrays (bit q = vertex q)."""
    shifts = jnp.arange(n, dtype=jnp.int32)
    return ((indices[..., None] >> shifts) & 1).astype(jnp.int8)


def pad_subgraph_arrays(
    subgraphs, n_qubits: int, e_pad: int | None = None,
    n_rows: int | None = None,
):
    """Stack per-subgraph (edges, weights, real_mask) into batch arrays.

    ``n_rows`` pads the batch dimension with empty-graph filler rows
    (mask 1, no edges — the same convention `solve_pool` pads with), the
    shape-stable packing the serve-side scheduler relies on (one source
    of truth for the DESIGN.md §6.1 parity contract).
    """
    import numpy as np

    if e_pad is None:
        e_pad = max(max(g.edges.shape[0] for g in subgraphs), 1)
    b = len(subgraphs)
    rows = b if n_rows is None else n_rows
    assert rows >= b, (rows, b)
    edges = np.zeros((rows, e_pad, 2), dtype=np.int32)
    weights = np.zeros((rows, e_pad), dtype=np.float32)
    masks = np.ones((rows,), dtype=np.int32)
    for i, g in enumerate(subgraphs):
        m = g.edges.shape[0]
        assert m <= e_pad, (m, e_pad)
        assert g.n <= n_qubits, (g.n, n_qubits)
        edges[i, :m] = np.asarray(g.edges)
        weights[i, :m] = np.asarray(g.weights)
        masks[i] = (1 << g.n) - 1
    return jnp.asarray(edges), jnp.asarray(weights), jnp.asarray(masks)


def pad_linear_arrays(linears, n_qubits: int, n_rows: int | None = None):
    """Stack per-subgraph linear-term vectors into one (rows, n_qubits)
    float32 batch array, zero-padded on both axes — the companion of
    `pad_subgraph_arrays` for QUBO/MIS buckets (padding qubits and filler
    rows contribute h = 0, so they stay objective-neutral)."""
    import numpy as np

    b = len(linears)
    rows = b if n_rows is None else n_rows
    assert rows >= b, (rows, b)
    out = np.zeros((rows, n_qubits), dtype=np.float32)
    for i, l in enumerate(linears):
        l = np.asarray(l, dtype=np.float32)
        assert l.shape[0] <= n_qubits, (l.shape[0], n_qubits)
        out[i, : l.shape[0]] = l
    return jnp.asarray(out)

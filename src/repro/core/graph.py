"""Graph representation for Max-Cut instances.

Graphs are stored as padded edge lists so every downstream JAX computation
is shape-stable: ``edges`` is ``(E_pad, 2) int32``, ``weights`` is
``(E_pad,) float32`` with zero weight on padding rows. Padding rows point at
vertex 0 on both endpoints, which contributes nothing to any cut because the
XOR of identical endpoints is zero *and* the weight is zero — both guards
hold so either representation change stays safe.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """A padded, undirected, weighted graph.

    Attributes:
      n: number of vertices (static).
      edges: (E_pad, 2) int32 vertex indices, padding rows are (0, 0).
      weights: (E_pad,) float32, zero on padding rows.
      n_edges: true (unpadded) edge count, static python int.
    """

    n: int
    edges: jnp.ndarray
    weights: jnp.ndarray
    n_edges: int

    # -- pytree plumbing (n / n_edges are static aux data) ------------------
    def tree_flatten(self):
        return (self.edges, self.weights), (self.n, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        edges, weights = children
        n, n_edges = aux
        return cls(n=n, edges=edges, weights=weights, n_edges=n_edges)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        n: int,
        edge_list: Iterable[tuple[int, int]],
        weights: Sequence[float] | None = None,
        pad_to: int | None = None,
    ) -> "Graph":
        edge_arr = np.asarray(list(edge_list), dtype=np.int32).reshape(-1, 2)
        m = edge_arr.shape[0]
        w = (
            np.ones((m,), dtype=np.float32)
            if weights is None
            else np.asarray(weights, dtype=np.float32)
        )
        if pad_to is None:
            pad_to = m
        if pad_to < m:
            raise ValueError(f"pad_to={pad_to} < n_edges={m}")
        ep = np.zeros((pad_to, 2), dtype=np.int32)
        wp = np.zeros((pad_to,), dtype=np.float32)
        ep[:m] = edge_arr
        wp[:m] = w
        return cls(n=n, edges=jnp.asarray(ep), weights=jnp.asarray(wp), n_edges=m)

    @classmethod
    def erdos_renyi(cls, n: int, p: float, seed: int, pad_to: int | None = None) -> "Graph":
        """Erdős–Rényi G(n, p), matching the paper's instance generator."""
        rng = np.random.default_rng(seed)
        iu, ju = np.triu_indices(n, k=1)
        mask = rng.random(iu.shape[0]) < p
        edge_arr = np.stack([iu[mask], ju[mask]], axis=1).astype(np.int32)
        g = cls.from_edges(n, edge_arr, pad_to=pad_to)
        return g

    @classmethod
    def erdos_renyi_weighted(
        cls,
        n: int,
        p: float,
        seed: int,
        pad_to: int | None = None,
        low: float = 0.1,
        high: float = 1.0,
    ) -> "Graph":
        """G(n, p) with edge weights drawn uniformly from [low, high).

        Same topology as :meth:`erdos_renyi` for the same seed — the weight
        draw consumes the generator *after* the edge mask, so weighted and
        unit-weight instances share an edge set.
        """
        rng = np.random.default_rng(seed)
        iu, ju = np.triu_indices(n, k=1)
        mask = rng.random(iu.shape[0]) < p
        edge_arr = np.stack([iu[mask], ju[mask]], axis=1).astype(np.int32)
        w = rng.uniform(low, high, size=edge_arr.shape[0]).astype(np.float32)
        return cls.from_edges(n, edge_arr, w, pad_to=pad_to)

    @classmethod
    def spin_glass(cls, n: int, p: float, seed: int, pad_to: int | None = None) -> "Graph":
        """G(n, p) topology with ±1 couplings (Edwards–Anderson spin glass)."""
        rng = np.random.default_rng(seed)
        iu, ju = np.triu_indices(n, k=1)
        mask = rng.random(iu.shape[0]) < p
        edge_arr = np.stack([iu[mask], ju[mask]], axis=1).astype(np.int32)
        w = rng.choice(np.asarray([-1.0, 1.0], dtype=np.float32), size=edge_arr.shape[0])
        return cls.from_edges(n, edge_arr, w.astype(np.float32), pad_to=pad_to)

    # -- basic quantities ----------------------------------------------------
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.weights)

    def dense_adjacency(self) -> jnp.ndarray:
        """(n, n) float32 symmetric adjacency. Only for small graphs."""
        a = jnp.zeros((self.n, self.n), dtype=jnp.float32)
        i, j = self.edges[:, 0], self.edges[:, 1]
        a = a.at[i, j].add(self.weights)
        a = a.at[j, i].add(self.weights)
        # padding rows add weight 0 at (0, 0): harmless.
        return a

    def degree(self) -> jnp.ndarray:
        d = jnp.zeros((self.n,), dtype=jnp.float32)
        d = d.at[self.edges[:, 0]].add(self.weights)
        d = d.at[self.edges[:, 1]].add(self.weights)
        return d


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Problem:
    """A diagonal-cost objective over ``n`` binary variables.

    The solver maximizes

        ``sum_{(u,v)} w_uv * (x_u XOR x_v)  +  sum_v h_v * x_v  +  offset``

    over assignments ``x in {0,1}^n``. Plain Max-Cut is the ``h = 0,
    offset = 0`` special case; arbitrary QUBOs and penalty-encoded MIS map
    onto the same (quadratic XOR + linear) form via the identity
    ``x_u * x_v = (x_u + x_v - (x_u XOR x_v)) / 2``. Every kernel and merge
    path scores the *internal* objective (quadratic + linear); the constant
    ``offset`` is applied only at reporting time (:func:`problem_value`).

    Attributes:
      graph: quadratic part as a padded XOR edge list.
      linear: (n,) float32 per-vertex linear coefficients ``h_v``.
      offset: constant term, static python float.
      kind: provenance tag ("maxcut" | "qubo" | "mis"), static.
    """

    graph: Graph
    linear: jnp.ndarray
    offset: float
    kind: str

    def tree_flatten(self):
        return (self.graph, self.linear), (self.offset, self.kind)

    @classmethod
    def tree_unflatten(cls, aux, children):
        graph, linear = children
        offset, kind = aux
        return cls(graph=graph, linear=linear, offset=offset, kind=kind)

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def has_linear(self) -> bool:
        """True when any linear coefficient is nonzero (host-side check)."""
        return bool(np.any(np.asarray(self.linear)))

    # -- constructors --------------------------------------------------------
    @classmethod
    def maxcut(cls, graph: Graph) -> "Problem":
        """Wrap a weighted Max-Cut instance (zero linear terms, zero offset)."""
        return cls(
            graph=graph,
            linear=jnp.zeros((graph.n,), dtype=jnp.float32),
            offset=0.0,
            kind="maxcut",
        )

    @classmethod
    def qubo(
        cls,
        n: int,
        quad_edges: Iterable[tuple[int, int]],
        quad_coeffs: Sequence[float],
        linear: Sequence[float] | None = None,
        offset: float = 0.0,
        pad_to: int | None = None,
    ) -> "Problem":
        """Maximize ``sum_{i<j} Q_ij x_i x_j + sum_i h_i x_i + offset``.

        Conversion: ``x_i x_j = (x_i + x_j - (x_i XOR x_j)) / 2`` turns each
        quadratic coefficient ``Q_ij`` into XOR edge weight ``-Q_ij / 2``
        plus ``+Q_ij / 2`` on the linear term of both endpoints.
        """
        e = np.asarray(list(quad_edges), dtype=np.int32).reshape(-1, 2)
        q = np.asarray(quad_coeffs, dtype=np.float64).reshape(-1)
        if e.shape[0] != q.shape[0]:
            raise ValueError(f"{e.shape[0]} quad edges but {q.shape[0]} coefficients")
        h = np.zeros((n,), dtype=np.float64)
        if linear is not None:
            h += np.asarray(linear, dtype=np.float64)
        np.add.at(h, e[:, 0], q / 2.0)
        np.add.at(h, e[:, 1], q / 2.0)
        g = Graph.from_edges(n, e, (-q / 2.0).astype(np.float32), pad_to=pad_to)
        return cls(
            graph=g,
            linear=jnp.asarray(h.astype(np.float32)),
            offset=float(offset),
            kind="qubo",
        )

    @classmethod
    def mis(cls, graph: Graph, penalty: float = 2.0) -> "Problem":
        """Maximum independent set on ``graph`` via the penalty QUBO.

        Maximize ``sum_i x_i - P * sum_{(i,j) in E} x_i x_j`` with
        ``P >= 2``: any edge inside the chosen set costs more than the two
        vertices gain, so the optimum is a maximum independent set. Edge
        weights of ``graph`` are ignored — it is a conflict graph. In XOR
        form: edge weight ``+P/2``, ``h_i = 1 - P * deg_i / 2``.
        """
        if penalty < 2.0:
            raise ValueError(f"penalty={penalty} < 2 does not guarantee independence")
        e = np.asarray(graph.edges)[: graph.n_edges]
        q = np.full((graph.n_edges,), -float(penalty))
        p = cls.qubo(graph.n, e, q, linear=np.ones((graph.n,)),
                     pad_to=graph.edges.shape[0])
        return dataclasses.replace(p, kind="mis")


def as_problem(obj: Graph | Problem) -> Problem:
    """Normalize a Graph (treated as Max-Cut) or Problem to a Problem."""
    if isinstance(obj, Problem):
        return obj
    return Problem.maxcut(obj)


def problem_value(problem: Problem, assignment: jnp.ndarray) -> jnp.ndarray:
    """Full objective (quadratic + linear + offset) of one 0/1 assignment."""
    x = assignment.astype(problem.linear.dtype)
    return cut_value(problem.graph, assignment) + problem.linear @ x + problem.offset


def problem_value_batch(problem: Problem, assignments: jnp.ndarray) -> jnp.ndarray:
    """Full objective for a batch of 0/1 assignments, shape (B, n) → (B,)."""
    x = assignments.astype(problem.linear.dtype)
    return cut_value_batch(problem.graph, assignments) + x @ problem.linear + problem.offset


def independent_set_violations(graph: Graph, assignment: np.ndarray) -> int:
    """Number of (unpadded) edges with both endpoints selected. Host-side."""
    e = np.asarray(graph.edges)[: graph.n_edges]
    x = np.asarray(assignment).astype(np.int64)
    return int(np.sum(x[e[:, 0]] * x[e[:, 1]]))


def cut_value(graph: Graph, assignment: jnp.ndarray) -> jnp.ndarray:
    """Cut value of one 0/1 assignment vector of shape (n,)."""
    s = assignment.astype(jnp.int32)
    crossed = s[graph.edges[:, 0]] ^ s[graph.edges[:, 1]]
    return jnp.sum(graph.weights * crossed.astype(graph.weights.dtype))


def cut_value_batch(graph: Graph, assignments: jnp.ndarray) -> jnp.ndarray:
    """Cut values for a batch of 0/1 assignments, shape (B, n) → (B,)."""
    s = assignments.astype(jnp.int32)
    crossed = s[:, graph.edges[:, 0]] ^ s[:, graph.edges[:, 1]]
    return crossed.astype(graph.weights.dtype) @ graph.weights


def subgraph(graph: Graph, lo: int, hi: int, pad_to: int | None = None) -> Graph:
    """Induced subgraph on the contiguous vertex range [lo, hi).

    Host-side (numpy) — partitioning is preprocessing, as in the paper.
    Vertices are relabelled to [0, hi-lo).
    """
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    m = (e[:, 0] >= lo) & (e[:, 0] < hi) & (e[:, 1] >= lo) & (e[:, 1] < hi)
    sub_e = e[m] - lo
    return Graph.from_edges(hi - lo, sub_e, w[m], pad_to=pad_to)


def networkx_to_graph(nx_graph, pad_to: int | None = None) -> Graph:
    """Convert a networkx graph (integer-labelled 0..n-1) to a Graph."""
    n = nx_graph.number_of_nodes()
    edges, weights = [], []
    for u, v, data in nx_graph.edges(data=True):
        edges.append((u, v))
        weights.append(float(data.get("weight", 1.0)))
    return Graph.from_edges(n, edges, weights, pad_to=pad_to)

"""Graph representation for Max-Cut instances.

Graphs are stored as padded edge lists so every downstream JAX computation
is shape-stable: ``edges`` is ``(E_pad, 2) int32``, ``weights`` is
``(E_pad,) float32`` with zero weight on padding rows. Padding rows point at
vertex 0 on both endpoints, which contributes nothing to any cut because the
XOR of identical endpoints is zero *and* the weight is zero — both guards
hold so either representation change stays safe.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """A padded, undirected, weighted graph.

    Attributes:
      n: number of vertices (static).
      edges: (E_pad, 2) int32 vertex indices, padding rows are (0, 0).
      weights: (E_pad,) float32, zero on padding rows.
      n_edges: true (unpadded) edge count, static python int.
    """

    n: int
    edges: jnp.ndarray
    weights: jnp.ndarray
    n_edges: int

    # -- pytree plumbing (n / n_edges are static aux data) ------------------
    def tree_flatten(self):
        return (self.edges, self.weights), (self.n, self.n_edges)

    @classmethod
    def tree_unflatten(cls, aux, children):
        edges, weights = children
        n, n_edges = aux
        return cls(n=n, edges=edges, weights=weights, n_edges=n_edges)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_edges(
        cls,
        n: int,
        edge_list: Iterable[tuple[int, int]],
        weights: Sequence[float] | None = None,
        pad_to: int | None = None,
    ) -> "Graph":
        edge_arr = np.asarray(list(edge_list), dtype=np.int32).reshape(-1, 2)
        m = edge_arr.shape[0]
        w = (
            np.ones((m,), dtype=np.float32)
            if weights is None
            else np.asarray(weights, dtype=np.float32)
        )
        if pad_to is None:
            pad_to = m
        if pad_to < m:
            raise ValueError(f"pad_to={pad_to} < n_edges={m}")
        ep = np.zeros((pad_to, 2), dtype=np.int32)
        wp = np.zeros((pad_to,), dtype=np.float32)
        ep[:m] = edge_arr
        wp[:m] = w
        return cls(n=n, edges=jnp.asarray(ep), weights=jnp.asarray(wp), n_edges=m)

    @classmethod
    def erdos_renyi(cls, n: int, p: float, seed: int, pad_to: int | None = None) -> "Graph":
        """Erdős–Rényi G(n, p), matching the paper's instance generator."""
        rng = np.random.default_rng(seed)
        iu, ju = np.triu_indices(n, k=1)
        mask = rng.random(iu.shape[0]) < p
        edge_arr = np.stack([iu[mask], ju[mask]], axis=1).astype(np.int32)
        g = cls.from_edges(n, edge_arr, pad_to=pad_to)
        return g

    # -- basic quantities ----------------------------------------------------
    def total_weight(self) -> jnp.ndarray:
        return jnp.sum(self.weights)

    def dense_adjacency(self) -> jnp.ndarray:
        """(n, n) float32 symmetric adjacency. Only for small graphs."""
        a = jnp.zeros((self.n, self.n), dtype=jnp.float32)
        i, j = self.edges[:, 0], self.edges[:, 1]
        a = a.at[i, j].add(self.weights)
        a = a.at[j, i].add(self.weights)
        # padding rows add weight 0 at (0, 0): harmless.
        return a

    def degree(self) -> jnp.ndarray:
        d = jnp.zeros((self.n,), dtype=jnp.float32)
        d = d.at[self.edges[:, 0]].add(self.weights)
        d = d.at[self.edges[:, 1]].add(self.weights)
        return d


def cut_value(graph: Graph, assignment: jnp.ndarray) -> jnp.ndarray:
    """Cut value of one 0/1 assignment vector of shape (n,)."""
    s = assignment.astype(jnp.int32)
    crossed = s[graph.edges[:, 0]] ^ s[graph.edges[:, 1]]
    return jnp.sum(graph.weights * crossed.astype(graph.weights.dtype))


def cut_value_batch(graph: Graph, assignments: jnp.ndarray) -> jnp.ndarray:
    """Cut values for a batch of 0/1 assignments, shape (B, n) → (B,)."""
    s = assignments.astype(jnp.int32)
    crossed = s[:, graph.edges[:, 0]] ^ s[:, graph.edges[:, 1]]
    return crossed.astype(graph.weights.dtype) @ graph.weights


def subgraph(graph: Graph, lo: int, hi: int, pad_to: int | None = None) -> Graph:
    """Induced subgraph on the contiguous vertex range [lo, hi).

    Host-side (numpy) — partitioning is preprocessing, as in the paper.
    Vertices are relabelled to [0, hi-lo).
    """
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    m = (e[:, 0] >= lo) & (e[:, 0] < hi) & (e[:, 1] >= lo) & (e[:, 1] < hi)
    sub_e = e[m] - lo
    return Graph.from_edges(hi - lo, sub_e, w[m], pad_to=pad_to)


def networkx_to_graph(nx_graph, pad_to: int | None = None) -> Graph:
    """Convert a networkx graph (integer-labelled 0..n-1) to a Graph."""
    n = nx_graph.number_of_nodes()
    edges, weights = [], []
    for u, v, data in nx_graph.edges(data=True):
        edges.append((u, v))
        weights.append(float(data.get("weight", 1.0)))
    return Graph.from_edges(n, edges, weights, pad_to=pad_to)

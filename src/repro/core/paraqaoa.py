"""End-to-end ParaQAOA orchestrator: partition → solve (batched QAOA) →
level-aware merge → report. Mirrors Fig. 3 of the paper.

Parameter taxonomy (paper §4.2):
  hardware-dependent: n_solvers (N_s), n_qubits (N)
  input-dependent:    m_subgraphs (M = ceil(|V|/(N-1))), rounds (T = ceil(M/N_s))
  tunable:            top_k (K), merge_level (L) / beam_width
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import (
    Partition,
    connectivity_preserving_partition,
    partition_for_solver,
)
from repro.core.pei import SolveReport


@dataclasses.dataclass(frozen=True)
class ParaQAOAConfig:
    # hardware-dependent (paper: N_s solvers × N qubits)
    n_qubits: int = 14  # N — per-solver qubit budget (26 on the paper's GPUs)
    n_solvers: int = 1  # N_s — concurrent solver instances (mesh data-axis size)
    # tunable (paper: K, L)
    top_k: int = 2  # K — candidates kept per subgraph
    merge_level: int = 2  # L — frontier materialization level (distributed merge)
    beam_width: Optional[int] = None  # None → exact 2·K^M (capped)
    beam_cap: int = 1 << 18
    # QAOA solver knobs
    p_layers: int = 3
    opt_steps: int = 30
    learning_rate: float = 0.05
    ramp_delta: float = 0.75
    # Adam steps on oversized (model-axis sharded) subproblems, run
    # *through* the sharded evolution (engine.sharded_ascent, DESIGN.md
    # §2.6); 0 keeps the linear-ramp parameters — the pre-engine behavior
    sharded_opt_steps: int = 0
    # beyond-paper: vectorized 1-flip local-search refinement of the merged cut
    refine_steps: int = 0

    def qaoa_config(self) -> qaoa_mod.QAOAConfig:
        return qaoa_mod.QAOAConfig(
            n_qubits=self.n_qubits,
            p_layers=self.p_layers,
            opt_steps=self.opt_steps,
            learning_rate=self.learning_rate,
            ramp_delta=self.ramp_delta,
            top_k=self.top_k,
        )


@dataclasses.dataclass
class ParaQAOAOutput:
    assignment: np.ndarray
    cut_value: float
    partition: Partition
    report: SolveReport
    timings: dict


def merge_inputs(
    part: Partition, bit_indices: np.ndarray, cfg: ParaQAOAConfig
) -> tuple[merge_mod.MergePlan, int]:
    """Stage-3 (plan, beam width) derivation, shared by every merge
    consumer — `merge_candidates` below and the service's anytime stream
    (DESIGN.md §6.4) — so the beam/cap rules cannot silently diverge."""
    plan = merge_mod.build_merge_plan(part, bit_indices, cfg.top_k)
    bw = cfg.beam_width or merge_mod.exact_beam_width(
        cfg.top_k, part.m, cap=cfg.beam_cap
    )
    return plan, bw


def merge_candidates(
    part: Partition, bit_indices: np.ndarray, cfg: ParaQAOAConfig
) -> tuple[np.ndarray, float, int]:
    """Stage-3 merge of solved candidates → (assignment, cut, beam width).

    The single merge path shared by `solve` and the serve-side scheduler
    (`repro.service.scheduler`, DESIGN.md §6.1): running the identical
    plan/beam computation is what keeps service results bit-identical to
    solo `solve` runs on the same knobs.
    """
    plan, bw = merge_inputs(part, bit_indices, cfg)
    merged = merge_mod.merge_scan(plan, bw)
    return np.asarray(merged.assignment), float(merged.cut_value), bw


def solve(
    graph: Graph,
    cfg: ParaQAOAConfig = ParaQAOAConfig(),
    partition: Partition | None = None,
) -> ParaQAOAOutput:
    """Solve one Max-Cut instance end to end on the current default device."""
    t0 = time.perf_counter()

    # ---- stage 1: graph partition (paper Alg. 1) -------------------------
    part = partition or partition_for_solver(graph, cfg.n_qubits)
    t_part = time.perf_counter()

    # ---- stage 2: parallelized QAOA execution ----------------------------
    qcfg = cfg.qaoa_config()
    edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
        part.subgraphs, qcfg.n_qubits
    )
    result = qaoa_mod.solve_subgraph_batch_program(qcfg)(edges, weights, masks)
    bit_indices = np.asarray(result.bitstrings)  # (M, K)
    t_solve = time.perf_counter()

    # ---- stage 3: level-aware parallel merge -----------------------------
    assignment, cut, bw = merge_candidates(part, bit_indices, cfg)
    t_merge = time.perf_counter()

    # ---- optional beyond-paper refinement --------------------------------
    if cfg.refine_steps > 0:
        from repro.core.baselines.local_search import refine

        assignment, cut = refine(part.graph, assignment, cfg.refine_steps)
    t_end = time.perf_counter()

    # sanity: merge's incremental score must equal a from-scratch evaluation
    check = float(cut_value(part.graph, jnp.asarray(assignment)))
    if cfg.refine_steps == 0:
        assert abs(check - cut) < 1e-2 * max(1.0, abs(check)), (check, cut)
    cut = check

    timings = {
        "partition_s": t_part - t0,
        "solve_s": t_solve - t_part,
        "merge_s": t_merge - t_solve,
        "refine_s": t_end - t_merge,
        "total_s": t_end - t0,
    }
    report = SolveReport(
        method="paraqaoa",
        n_vertices=graph.n,
        cut_value=cut,
        runtime_s=timings["total_s"],
        extra={"m_subgraphs": part.m, "k": cfg.top_k, "beam": bw, **timings},
    )
    return ParaQAOAOutput(
        assignment=assignment,
        cut_value=cut,
        partition=part,
        report=report,
        timings=timings,
    )

"""End-to-end ParaQAOA orchestrator: partition → solve (batched QAOA) →
level-aware merge → report. Mirrors Fig. 3 of the paper.

Parameter taxonomy (paper §4.2):
  hardware-dependent: n_solvers (N_s), n_qubits (N)
  input-dependent:    m_subgraphs (M = ceil(|V|/(N-1))), rounds (T = ceil(M/N_s))
  tunable:            top_k (K), merge_level (L) / beam_width
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, Problem, as_problem, cut_value, problem_value
from repro.core.partition import (
    Partition,
    connectivity_preserving_partition,
    partition_for_solver,
    split_linear,
)
from repro.core.pei import SolveReport
from repro.obs import trace as trace_mod


@dataclasses.dataclass(frozen=True)
class ParaQAOAConfig:
    # hardware-dependent (paper: N_s solvers × N qubits)
    n_qubits: int = 14  # N — per-solver qubit budget (26 on the paper's GPUs)
    n_solvers: int = 1  # N_s — concurrent solver instances (mesh data-axis size)
    # tunable (paper: K, L)
    top_k: int = 2  # K — candidates kept per subgraph
    merge_level: int = 2  # L — frontier materialization level (distributed merge)
    beam_width: Optional[int] = None  # None → exact 2·K^M (capped)
    beam_cap: int = 1 << 18
    # QAOA solver knobs
    p_layers: int = 3
    opt_steps: int = 30
    learning_rate: float = 0.05
    ramp_delta: float = 0.75
    # Adam steps on oversized (model-axis sharded) subproblems, run
    # *through* the sharded evolution (engine.sharded_ascent, DESIGN.md
    # §2.6); 0 keeps the linear-ramp parameters — the pre-engine behavior
    sharded_opt_steps: int = 0
    # beyond-paper: vectorized 1-flip local-search refinement of the merged cut
    refine_steps: int = 0

    def qaoa_config(self) -> qaoa_mod.QAOAConfig:
        return qaoa_mod.QAOAConfig(
            n_qubits=self.n_qubits,
            p_layers=self.p_layers,
            opt_steps=self.opt_steps,
            learning_rate=self.learning_rate,
            ramp_delta=self.ramp_delta,
            top_k=self.top_k,
        )


@dataclasses.dataclass
class ParaQAOAOutput:
    assignment: np.ndarray
    cut_value: float
    partition: Partition
    report: SolveReport
    timings: dict


def merge_inputs(
    part: Partition, bit_indices: np.ndarray, cfg: ParaQAOAConfig,
    linear=None,
) -> tuple[merge_mod.MergePlan, int]:
    """Stage-3 (plan, beam width) derivation, shared by every merge
    consumer — `merge_candidates` below and the service's anytime stream
    (DESIGN.md §6.4) — so the beam/cap rules cannot silently diverge.
    ``linear`` (V,) f32, optional, scores the QUBO/MIS linear terms in the
    beam (each vertex counted once, at its first-coverage level)."""
    plan = merge_mod.build_merge_plan(part, bit_indices, cfg.top_k,
                                      linear=linear)
    bw = cfg.beam_width or merge_mod.exact_beam_width(
        cfg.top_k, part.m, cap=cfg.beam_cap
    )
    return plan, bw


def merge_candidates(
    part: Partition, bit_indices: np.ndarray, cfg: ParaQAOAConfig,
    linear=None,
) -> tuple[np.ndarray, float, int]:
    """Stage-3 merge of solved candidates → (assignment, score, beam width).

    The single merge path shared by `solve` and the serve-side scheduler
    (`repro.service.scheduler`, DESIGN.md §6.1): running the identical
    plan/beam computation is what keeps service results bit-identical to
    solo `solve` runs on the same knobs. The returned score is the internal
    (offset-free) objective: quadratic cut + linear terms.
    """
    plan, bw = merge_inputs(part, bit_indices, cfg, linear=linear)
    merged = merge_mod.merge_scan(plan, bw)
    return np.asarray(merged.assignment), float(merged.cut_value), bw


def solve(
    graph: Graph | Problem,
    cfg: ParaQAOAConfig = ParaQAOAConfig(),
    partition: Partition | None = None,
) -> ParaQAOAOutput:
    """Solve one instance end to end on the current default device.

    ``graph`` may be a plain `Graph` (Max-Cut) or a `core.graph.Problem`
    (weighted Max-Cut / QUBO / MIS): linear terms thread through the cost
    oracle, the partition (each vertex's term to exactly one subproblem)
    and the merge beam; the reported value is the full objective including
    the constant offset. A `Graph` input follows the exact zero-linear
    special case — byte-identical traces to the linear-free solver.
    """
    prob = as_problem(graph)
    graph = prob.graph
    has_lin = prob.has_linear
    # §8: stage timings come from the ambient tracer's spans — with the
    # default (non-recording) tracer this is the same perf_counter
    # stamping as before; `solve_maxcut --trace-out` installs a
    # recording tracer and the same spans become the exported trace
    tr = trace_mod.get_tracer()
    with tr.span("solve", n=graph.n, n_edges=graph.n_edges) as root:
        # ---- stage 1: graph partition (paper Alg. 1) ---------------------
        with tr.span("partition", n_qubits=cfg.n_qubits) as sp_part:
            part = partition or partition_for_solver(graph, cfg.n_qubits)
            sub_lins = split_linear(part, prob.linear) if has_lin else None

        # ---- stage 2: parallelized QAOA execution ------------------------
        with tr.span("solve_pool", m=part.m,
                     n_qubits=cfg.n_qubits) as sp_solve:
            qcfg = cfg.qaoa_config()
            edges, weights, masks = qaoa_mod.pad_subgraph_arrays(
                part.subgraphs, qcfg.n_qubits
            )
            if has_lin:
                linears = qaoa_mod.pad_linear_arrays(sub_lins, qcfg.n_qubits)
                result = qaoa_mod.solve_subgraph_batch_program(
                    qcfg, has_linear=True
                )(edges, weights, masks, linears)
            else:
                result = qaoa_mod.solve_subgraph_batch_program(qcfg)(
                    edges, weights, masks
                )
            bit_indices = np.asarray(result.bitstrings)  # (M, K)

        # ---- stage 3: level-aware parallel merge -------------------------
        with tr.span("merge", m=part.m) as sp_merge:
            assignment, cut, bw = merge_candidates(
                part, bit_indices, cfg,
                linear=prob.linear if has_lin else None,
            )

        # ---- optional beyond-paper refinement ----------------------------
        with tr.span("refine", steps=cfg.refine_steps) as sp_refine:
            if cfg.refine_steps > 0:
                from repro.core.baselines.local_search import refine

                assignment, cut = refine(
                    part.graph, assignment, cfg.refine_steps,
                    linear=prob.linear if has_lin else None,
                )

    # sanity: merge's incremental score must equal a from-scratch evaluation
    # of the internal (offset-free) objective; report the full objective
    obj = float(problem_value(prob, jnp.asarray(assignment)))
    internal = obj - prob.offset
    if cfg.refine_steps == 0:
        assert abs(internal - cut) < 1e-2 * max(1.0, abs(internal)), (internal, cut)
    cut = obj

    timings = {
        "partition_s": sp_part.duration_s,
        "solve_s": sp_solve.duration_s,
        "merge_s": sp_merge.duration_s,
        "refine_s": sp_refine.duration_s,
        "total_s": root.duration_s,
    }
    report = SolveReport(
        method="paraqaoa",
        n_vertices=graph.n,
        cut_value=cut,
        runtime_s=timings["total_s"],
        extra={"m_subgraphs": part.m, "k": cfg.top_k, "beam": bw, **timings},
    )
    return ParaQAOAOutput(
        assignment=assignment,
        cut_value=cut,
        partition=part,
        report=report,
        timings=timings,
    )

"""ParaQAOA core: the paper's contribution as composable JAX modules."""

from repro.core.graph import Graph, cut_value, cut_value_batch
from repro.core.partition import (
    Partition,
    connectivity_preserving_partition,
    partition_for_solver,
    random_partition,
)
from repro.core.distributed import solve_distributed
from repro.core.paraqaoa import ParaQAOAConfig, ParaQAOAOutput, solve
from repro.core.pei import approximation_ratio, efficiency_factor, pei

__all__ = [
    "Graph",
    "cut_value",
    "cut_value_batch",
    "Partition",
    "connectivity_preserving_partition",
    "partition_for_solver",
    "random_partition",
    "ParaQAOAConfig",
    "ParaQAOAOutput",
    "solve",
    "solve_distributed",
    "approximation_ratio",
    "efficiency_factor",
    "pei",
]

"""Connectivity-Preserving Partitioning (paper Alg. 1) and baselines.

The CPP algorithm splits vertex indices into M contiguous ranges where
adjacent ranges overlap in exactly one vertex (the "shared node"). The base
partition size is s = floor(|V|/M) - 1 and range i covers
[i*s, i*s + s + 1), with the last range absorbing the remainder — a direct
transcription of Alg. 1. Complexity is O(|V| + |E|): one pass to slice the
ranges, one pass over edges per subgraph extraction (done as one global
pass here).

The partition output also records the *inter-partition* edges (the edges
dropped from every subgraph), which the merge phase re-scores globally —
paper §3.4 eq. Cut(B*).
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class Partition:
    """Result of partitioning a graph into a chain of subgraphs.

    Attributes:
      subgraphs: list of induced subgraphs, each with *local* vertex labels.
      ranges: list of (lo, hi) global vertex ranges per subgraph;
        ranges[i].hi - 1 == ranges[i+1].lo is the shared vertex.
      sizes: number of vertices per subgraph.
      inter_edges: (E_x, 2) int32 global-index edges not inside any subgraph.
      inter_weights: (E_x,) float32.
      graph: the original graph.
    """

    subgraphs: List[Graph]
    ranges: List[tuple]
    sizes: List[int]
    inter_edges: np.ndarray
    inter_weights: np.ndarray
    graph: Graph

    @property
    def m(self) -> int:
        return len(self.subgraphs)


def alg1_ranges(n: int, m: int) -> List[tuple]:
    """Paper Alg. 1, verbatim: s = floor(|V|/M) - 1, range i covers
    [i*s, i*s + s + 1), last range absorbs the remainder.

    NOTE: the verbatim algorithm can overflow the last partition well past
    ceil(|V|/M) (e.g. |V|=400, M=16 → last size 40 > 26 qubits), violating
    the paper's own QAOA-compatibility constraint (2). Kept for fidelity
    experiments; `balanced_ranges` below is the default.
    """
    if m < 1:
        raise ValueError("need at least one partition")
    if m == 1:
        return [(0, n)]
    s = n // m - 1
    if s < 1:
        raise ValueError(f"partition size too small: |V|={n}, M={m}")
    ranges = []
    for i in range(1, m + 1):
        start = (i - 1) * s
        end = n if i == m else start + s + 1
        ranges.append((start, end))
    return ranges


def balanced_ranges(n: int, m: int) -> List[tuple]:
    """Alg. 1 with the remainder spread across partitions instead of dumped
    on the last one: every range gets floor(n/m) or ceil(n/m) fresh vertices
    (+1 shared vertex for ranges after the first), so sizes differ by at
    most 1 and the M = ceil(|V|/(N-1)) choice really honors |V_i| <= N."""
    if m < 1:
        raise ValueError("need at least one partition")
    if m == 1:
        return [(0, n)]
    q, r = divmod(n, m)
    if q < 1 or (q == 1 and r == 0 and m > 1):
        raise ValueError(f"partition size too small: |V|={n}, M={m}")
    ranges = []
    pos = 0
    for i in range(m):
        fresh = q + (1 if i < r else 0)
        if i == 0:
            lo, hi = 0, fresh
        else:
            lo, hi = pos - 1, pos - 1 + fresh + 1
        ranges.append((lo, hi))
        pos = hi
    assert ranges[-1][1] == n, ranges
    return ranges


def _contiguous_ranges(n: int, m: int, exact_alg1: bool = False) -> List[tuple]:
    return alg1_ranges(n, m) if exact_alg1 else balanced_ranges(n, m)


def connectivity_preserving_partition(
    graph: Graph, m: int, pad_edges: bool = True
) -> Partition:
    """Paper Alg. 1: contiguous ranges with one shared vertex per boundary."""
    ranges = _contiguous_ranges(graph.n, m)
    return _build_partition(graph, ranges, pad_edges)


def random_partition(graph: Graph, m: int, seed: int, pad_edges: bool = True) -> Partition:
    """QAOA²-style randomized partitioning (baseline): random vertex order,
    then contiguous ranges over the shuffled labels. Returned subgraphs use
    the same chain/shared-vertex contract as CPP so the merge phase is
    interchangeable; the relabelling permutation is applied to the graph."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(graph.n).astype(np.int32)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(graph.n, dtype=np.int32)
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]
    relabelled = Graph.from_edges(graph.n, inv[e], w, pad_to=graph.edges.shape[0])
    ranges = _contiguous_ranges(graph.n, m)
    part = _build_partition(relabelled, ranges, pad_edges)
    return part


def partition_for_solver(graph: Graph, max_qubits: int) -> Partition:
    """Input-dependent parameter selection (paper §4.2):
    M = ceil(|V| / (N - 1)) so every subgraph fits an N-qubit solver."""
    if graph.n <= max_qubits:
        return connectivity_preserving_partition(graph, 1)
    m = int(np.ceil(graph.n / (max_qubits - 1)))
    while True:
        ranges = balanced_ranges(graph.n, m)
        if max(hi - lo for lo, hi in ranges) <= max_qubits:
            break
        m += 1
    part = connectivity_preserving_partition(graph, m)
    assert max(part.sizes) <= max_qubits, (
        f"partition produced subgraph of {max(part.sizes)} > N={max_qubits}"
    )
    return part


def _build_partition(graph: Graph, ranges: List[tuple], pad_edges: bool) -> Partition:
    e = np.asarray(graph.edges)[: graph.n_edges]
    w = np.asarray(graph.weights)[: graph.n_edges]

    subgraphs: List[Graph] = []
    sizes: List[int] = []
    covered = np.zeros(e.shape[0], dtype=bool)

    # One O(|E|) pass per membership test, vectorised in numpy.
    sub_edge_lists = []
    for lo, hi in ranges:
        inside = (e[:, 0] >= lo) & (e[:, 0] < hi) & (e[:, 1] >= lo) & (e[:, 1] < hi)
        covered |= inside
        sub_edge_lists.append((lo, hi, e[inside] - lo, w[inside]))
        sizes.append(hi - lo)

    # Shared-vertex edges live in *both* adjacent subgraphs only if both
    # endpoints sit in the overlap — impossible for distinct endpoints, so
    # each intra edge belongs to exactly one subgraph except edges touching
    # the shared vertex, which the (lo, hi) window assigns uniquely. An edge
    # between the two vertices adjacent to a boundary shared vertex can be
    # in neither — those fall into inter_edges below.
    pad = max(max((el.shape[0] for _, _, el, _ in sub_edge_lists), default=1), 1)
    if not pad_edges:
        pad = None
    for lo, hi, el, wl in sub_edge_lists:
        subgraphs.append(Graph.from_edges(hi - lo, el, wl, pad_to=pad))

    inter = ~covered
    return Partition(
        subgraphs=subgraphs,
        ranges=list(ranges),
        sizes=sizes,
        inter_edges=e[inter].astype(np.int32),
        inter_weights=w[inter].astype(np.float32),
        graph=graph,
    )


def split_linear(part: Partition, linear) -> List[np.ndarray]:
    """Assign each vertex's linear term to exactly one subproblem.

    Adjacent ranges overlap in one shared vertex, so a naive per-range slice
    would double-count its ``h_v``. Vertex v's term goes to its *first*
    covering range (the same first-coverage rule `merge.build_merge_plan`
    uses for vertices); later ranges see h = 0 at the shared position.
    ``linear`` is indexed in ``part.graph``'s vertex labels; returns one
    (size_i,) float32 array per subgraph in local labels.
    """
    lin = np.asarray(linear, dtype=np.float32)
    assert lin.shape == (part.graph.n,), (lin.shape, part.graph.n)
    hi_arr = np.asarray([hi for _, hi in part.ranges], dtype=np.int64)
    level = np.searchsorted(hi_arr, np.arange(part.graph.n), side="right")
    level = np.clip(level, 0, part.m - 1)
    out: List[np.ndarray] = []
    for i, (lo, hi) in enumerate(part.ranges):
        li = np.zeros(hi - lo, dtype=np.float32)
        idx = np.nonzero(level == i)[0]
        li[idx - lo] = lin[idx]
        out.append(li)
    return out


def stitch_assignments(part: Partition, local_bits: List[np.ndarray]) -> np.ndarray:
    """Concatenate per-subgraph 0/1 assignments into a global assignment.

    Adjacent subgraphs overlap in one vertex; the caller must have oriented
    each local bitstring so the shared vertex agrees (merge.py guarantees
    this). The later subgraph's value wins on the overlap (they're equal by
    construction).
    """
    out = np.zeros(part.graph.n, dtype=np.int8)
    for (lo, hi), bits in zip(part.ranges, local_bits):
        out[lo:hi] = np.asarray(bits, dtype=np.int8)[: hi - lo]
    return out

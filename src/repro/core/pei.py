"""Performance Efficiency Index (paper §3.5).

PEI = AR × EF × 100, where AR is the approximation ratio against an optimal
or best-known cut, and EF is a sigmoid over the runtime gap to a baseline —
EDP-inspired [Horowitz '94], bounded to (0, 1) with EF = 0.5 at parity.
"""

from __future__ import annotations

import dataclasses
import math


def approximation_ratio(cut_alg: float, cut_opt: float) -> float:
    if cut_opt <= 0:
        return 1.0 if cut_alg <= 0 else 0.0
    return float(cut_alg) / float(cut_opt)


def efficiency_factor(t_alg: float, t_base: float, alpha: float = 1e-3) -> float:
    # overflow-safe sigmoid
    x = alpha * (t_alg - t_base)
    if x >= 0:
        z = math.exp(-x)
        return z / (1.0 + z)
    z = math.exp(x)
    return 1.0 / (1.0 + z)


def pei(
    cut_alg: float,
    cut_opt: float,
    t_alg: float,
    t_base: float,
    alpha: float = 1e-3,
) -> float:
    return (
        approximation_ratio(cut_alg, cut_opt)
        * efficiency_factor(t_alg, t_base, alpha)
        * 100.0
    )


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """Uniform result record for any Max-Cut solver (used by benchmarks)."""

    method: str
    n_vertices: int
    cut_value: float
    runtime_s: float
    extra: dict | None = None

    def ar(self, cut_opt: float) -> float:
        return approximation_ratio(self.cut_value, cut_opt)

    def pei(self, cut_opt: float, t_base: float, alpha: float = 1e-3) -> float:
        return pei(self.cut_value, cut_opt, self.runtime_s, t_base, alpha)

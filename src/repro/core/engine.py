"""One differentiable statevector engine under every QAOA solve path.

Before this module existed the repo had two forks of the per-layer QAOA
evolution: `qaoa.qaoa_statevector` (single-device, routed through
`kernels.ops`) and a hand-rolled loop inside the sharded program
(`ref.apply_phase` / `_mix_bits` einsums that the Pallas dispatch could
never reach). This module is the merge point (DESIGN.md §2.6):

  - `Layout` describes *where the amplitudes live*: `FlatLayout` (one
    device, full 2^n vector) or `ShardedLayout` (2^n amplitudes sharded
    over a mesh axis, with the `faithful`/`alternating` all_to_all
    schedules of DESIGN.md §2.2 and the layout-A/layout-B index maps).
  - `cut_table(layout, edges, weights)` materializes the diagonal cost
    in every layout the schedule will visit.
  - `evolve(layout, cut, gammas, betas)` runs the p-layer ansatz with
    every op — phase, grouped mixer, cutvals-at-indices, expectation —
    going through the `kernels.ops` dispatch, so `pallas` /
    `pallas_interpret` / `xla` selection (including the fused
    phase+mixer kernel, §Perf C3) applies identically per shard.
  - the evolution is differentiable end to end: `all_to_all` is its own
    transpose and the expectation's `psum` transposes to a broadcast,
    so `jax.grad` through `evolve` matches the single-device gradient
    (tests/test_distributed.py::test_engine_gradient_parity). That is
    what `sharded_ascent` exploits to optimize oversized-subproblem
    parameters instead of freezing them at the linear ramp.

Layout-B geometry (also documented on `sharded_qaoa`): in layout A
device d owns global indices [d·L, (d+1)·L); after the qubit-swap
all_to_all (layout B) device p owns, for every d, the slice
[d·L + p·chunk, d·L + (p+1)·chunk). In layout B the local flat index's
bits [log2(chunk), log2(chunk)+h) are the *original* high h qubits, so
one local `apply_mixer_bits` call mixes exactly the qubits that were
out of reach in layout A (property-tested in tests/test_engine.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Single-device layout: the full 2^n statevector in basis order."""

    n: int
    group: int = 7


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Model-axis sharded layout. Only meaningful inside `shard_map` over
    ``axis`` (the index maps call ``jax.lax.axis_index``)."""

    n: int
    axis: str
    axis_size: int
    schedule: str = "alternating"
    group: int = 7

    def __post_init__(self):
        assert 2**self.h == self.axis_size, (
            f"axis size {self.axis_size} must be a power of two"
        )
        assert self.chunk >= 1, (
            f"statevector too small for the mesh: n={self.n}, "
            f"axis={self.axis_size}"
        )
        assert self.schedule in ("faithful", "alternating"), self.schedule

    @property
    def h(self) -> int:
        """Number of shard-axis ("global") qubits."""
        return int(np.log2(self.axis_size))

    @property
    def n_local(self) -> int:
        return self.n - self.h

    @property
    def local_dim(self) -> int:
        """L — amplitudes resident per device."""
        return 2**self.n_local

    @property
    def chunk(self) -> int:
        """all_to_all block size: L / axis_size."""
        return self.local_dim // self.axis_size

    @property
    def log2_chunk(self) -> int:
        return int(np.log2(self.chunk))


Layout = Union[FlatLayout, ShardedLayout]


class CutTable(NamedTuple):
    """Diagonal cost (and owned global indices) per layout position.

    Flat layouts carry only ``cutv_a`` (basis order); sharded layouts
    carry both layout-A and layout-B views so the alternating schedule
    can evaluate the cost layer without swapping back (DESIGN.md §2.2).
    """

    cutv_a: jnp.ndarray
    idx_a: jnp.ndarray | None
    cutv_b: jnp.ndarray | None
    idx_b: jnp.ndarray | None

    def at(self, in_b: bool) -> jnp.ndarray:
        return self.cutv_b if in_b else self.cutv_a

    def idx(self, in_b: bool) -> jnp.ndarray:
        return self.idx_b if in_b else self.idx_a


def layout_index_maps(layout: ShardedLayout, device: int):
    """Host-side (numpy) layout-A/B global-index rows for one device.

    The traced `cut_table` below computes the same maps with
    ``lax.axis_index``; this pure form exists so the layout geometry is
    property-testable without a mesh (tests/test_engine.py).
    """
    L, chunk = layout.local_dim, layout.chunk
    q = np.arange(L, dtype=np.int64)
    idx_a = device * L + q
    idx_b = (q // chunk) * L + device * chunk + (q % chunk)
    return idx_a, idx_b


def cut_table(layout: Layout, edges, weights, linear=None) -> CutTable:
    """Objective values of every owned basis state, in every layout visited.

    ``linear`` (n,) f32, optional, adds per-vertex diagonal terms (QUBO/MIS)
    to every view; ``None`` keeps the Max-Cut trace unchanged.
    """
    if isinstance(layout, FlatLayout):
        return CutTable(
            ops.cutvals(layout.n, edges, weights, linear), None, None, None
        )
    L, chunk = layout.local_dim, layout.chunk
    me = jax.lax.axis_index(layout.axis)
    q = jnp.arange(L, dtype=jnp.int32)
    idx_a = me * L + q
    idx_b = (q // chunk) * L + me * chunk + (q % chunk)
    # both views are built unconditionally; the faithful schedule never
    # reads the B view and XLA dead-code-eliminates it
    return CutTable(
        ops.cutvals_at(idx_a, edges, weights, linear),
        idx_a,
        ops.cutvals_at(idx_b, edges, weights, linear),
        idx_b,
    )


def init_state(layout: Layout):
    """|+>^n as (re, im) planes — the locally-resident slice for shards."""
    dim = 2**layout.n if isinstance(layout, FlatLayout) else layout.local_dim
    re = jnp.full((dim,), 2.0 ** (-layout.n / 2), dtype=jnp.float32)
    im = jnp.zeros((dim,), dtype=jnp.float32)
    return re, im


def _a2a(layout: ShardedLayout, x):
    """The qubit-swap all_to_all: layout A <-> layout B (self-inverse)."""
    return jax.lax.all_to_all(
        x.reshape(layout.axis_size, layout.chunk),
        layout.axis,
        split_axis=0,
        concat_axis=0,
    ).reshape(-1)


def evolve(layout: Layout, cut: CutTable, gammas, betas):
    """Run the p-layer QAOA ansatz from |+>^n.

    Returns ``(re, im, in_b)`` — the final state planes plus the (static)
    layout position, ``True`` when the state ends in layout B (odd p
    under the alternating schedule). Every op dispatches through
    `kernels.ops`; differentiable w.r.t. (gammas, betas) on both layout
    kinds under every dispatch path — the ops carry analytic custom-vjp
    rules (DESIGN.md §2.7), so `jax.grad` re-enters the same kernels
    with negated angles on the backward trace.
    """
    re, im = init_state(layout)
    if isinstance(layout, FlatLayout):

        def layer(carry, gb):
            re, im = carry
            g, b = gb
            re, im = ops.apply_layer(
                re, im, cut.cutv_a, g, b, layout.n, group=layout.group
            )
            return (re, im), None

        (re, im), _ = jax.lax.scan(layer, (re, im), (gammas, betas))
        return re, im, False

    in_b = False
    for l in range(int(gammas.shape[0])):  # p is small; unrolled keeps the
        g, b = gammas[l], betas[l]  # layout position static per layer
        # phase + the n-h locally-resident qubits, one fused-dispatch layer
        re, im = ops.apply_layer(
            re, im, cut.at(in_b), g, b, layout.n_local, group=layout.group
        )
        # rotate the h shard-axis qubits into locality and mix them: after
        # the swap they sit at local bits [log2_chunk, log2_chunk + h)
        re, im = _a2a(layout, re), _a2a(layout, im)
        re, im = ops.apply_mixer_bits(
            re, im, layout.n_local, layout.log2_chunk, layout.h, b
        )
        if layout.schedule == "alternating":
            in_b = not in_b
        else:  # faithful: swap straight back to layout A
            re, im = _a2a(layout, re), _a2a(layout, im)
    return re, im, in_b


def expectation(layout: Layout, re, im, cut: CutTable, in_b: bool = False):
    """⟨cut⟩ of the evolved state; psummed to the global value on shards."""
    e = ops.expectation(re, im, cut.at(in_b))
    if isinstance(layout, ShardedLayout):
        e = jax.lax.psum(e, layout.axis)
    return e


def top_candidates(layout: Layout, re, im, cut: CutTable, in_b: bool, k: int):
    """Top-k (global basis indices, probabilities), replicated on shards."""
    probs = re * re + im * im
    if isinstance(layout, FlatLayout):
        v, i = jax.lax.top_k(probs, k)
        return i, v
    idx = cut.idx(in_b)
    v, i_loc = jax.lax.top_k(probs, k)
    all_v = jax.lax.all_gather(v, layout.axis).reshape(-1)
    all_i = jax.lax.all_gather(idx[i_loc], layout.axis).reshape(-1)
    vv, ii = jax.lax.top_k(all_v, k)
    return all_i[ii], vv


# ---------------------------------------------------------------------------
# parameter optimization
# ---------------------------------------------------------------------------
def adam_scan(grad_fn, params, steps: int, learning_rate: float):
    """Adam descent on ``grad_fn`` for ``steps`` under one `lax.scan`.

    The update rule shared by the single-device batched ascent
    (`qaoa.optimize_params`) and the sharded ascent below — one source
    of truth so the two optimizers cannot drift.
    """
    beta1, beta2, eps = 0.9, 0.999, 1e-8
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = (params, zeros, zeros)

    def step(state, i):
        params, m, v = state
        g = grad_fn(params)
        m = jax.tree.map(lambda a, b: beta1 * a + (1 - beta1) * b, m, g)
        v = jax.tree.map(lambda a, b: beta2 * a + (1 - beta2) * b * b, v, g)
        t = i + 1
        mh = jax.tree.map(lambda a: a / (1 - beta1**t), m)
        vh = jax.tree.map(lambda a: a / (1 - beta2**t), v)
        params = jax.tree.map(
            lambda p, a, b: p - learning_rate * a / (jnp.sqrt(b) + eps),
            params,
            mh,
            vh,
        )
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(
        step, state, jnp.arange(steps, dtype=jnp.float32)
    )
    return params


def sharded_ascent(
    layout: ShardedLayout,
    cut: CutTable,
    gammas,
    betas,
    steps: int,
    learning_rate: float,
):
    """Adam ascent on the *global* ⟨cut⟩ through the sharded evolution.

    The per-device loss is the local (unsummed) expectation; its gradient
    is psummed, which equals the gradient of the psummed expectation —
    d(Σ_d exp_d)/dθ = Σ_d d exp_d/dθ — without leaning on any particular
    psum-transpose rule. Every device sees identical psummed gradients,
    so the Adam moments stay replicated and the ascent is deterministic
    across shards.

    The differentiated evolution runs under the caller's active
    implementation: the `kernels.ops` entry points carry analytic
    custom-vjp rules (DESIGN.md §2.7), so the forward and backward
    traces fire the same dispatched kernels — the historical
    `using_implementation("xla")` gradient pin is gone.
    """

    if isinstance(gammas, jax.core.Tracer):
        from repro.obs.ledger import get_ledger

        get_ledger().note_op("sharded_ascent", ops.get_implementation())

    def neg_local(params):
        g, b = params
        re, im, in_b = evolve(layout, cut, g, b)
        return -ops.expectation(re, im, cut.at(in_b))

    raw_grad = jax.grad(neg_local)

    def grad_fn(params):
        return jax.tree.map(
            lambda x: jax.lax.psum(x, layout.axis), raw_grad(params)
        )

    return adam_scan(grad_fn, (gammas, betas), steps, learning_rate)

"""Multi-device correctness checks, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests/test_distributed.py).

Prints one JSON object; the parent test asserts on it.
"""

from __future__ import annotations

import json
import sys

from repro import compat

# standalone-friendly: emulate 8 host devices when run without the test
# harness's XLA_FLAGS (no-op if the jax backend is already initialized)
compat.ensure_host_device_count(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import connectivity_preserving_partition
from repro.kernels import ref


def check_solve_pool():
    mesh = compat.make_mesh((8,), ("data",))
    g = Graph.erdos_renyi(60, 0.4, seed=0)
    part = connectivity_preserving_partition(g, 6)
    cfg = qaoa_mod.QAOAConfig(n_qubits=11, p_layers=2, opt_steps=10, top_k=2)
    edges, weights, masks = qaoa_mod.pad_subgraph_arrays(part.subgraphs, 11)
    # single-device reference
    want = qaoa_mod.solve_subgraph_batch(edges, weights, masks, cfg)
    got = dist.solve_pool(edges, weights, masks, cfg, mesh)
    return {
        "bitstrings_equal": bool(
            np.array_equal(np.asarray(want.bitstrings), np.asarray(got.bitstrings))
        ),
        "exp_close": bool(
            np.allclose(
                np.asarray(want.expectation), np.asarray(got.expectation), atol=1e-4
            )
        ),
    }


def check_sharded_qaoa():
    out = {}
    n = 10
    g = Graph.erdos_renyi(n, 0.5, seed=1)
    gammas = jnp.asarray([0.3, 0.55], jnp.float32)
    betas = jnp.asarray([0.9, 0.4], jnp.float32)
    # single-device reference
    cutv = ref.cutvals(n, g.edges, g.weights)
    re, im = qaoa_mod.qaoa_statevector(cutv, n, gammas, betas)
    want_exp = float(ref.expectation(re, im, cutv))
    probs = re * re + im * im
    want_v, want_i = jax.lax.top_k(probs, 4)

    for axis_size in (4, 8):
        mesh = compat.make_mesh((axis_size,), ("model",))
        for schedule in ("faithful", "alternating"):
            res = dist.sharded_qaoa(
                g.edges, g.weights, n, gammas, betas, mesh,
                axis="model", top_k=4, schedule=schedule,
            )
            key = f"d{axis_size}_{schedule}"
            out[key + "_exp_close"] = bool(
                np.allclose(float(res.expectation[0] if res.expectation.ndim else res.expectation), want_exp, atol=1e-4)
            )
            # the top-1 *index* can differ under exact prob ties (|psi_b| ==
            # |psi_~b| by flip symmetry); compare its probability instead
            top1 = int(np.asarray(res.bitstrings).reshape(-1)[0])
            out[key + "_top1_match"] = bool(
                np.isclose(float(probs[top1]), float(want_v[0]), atol=1e-6)
            )
            out[key + "_probs_close"] = bool(
                np.allclose(
                    np.sort(np.asarray(res.probs).reshape(-1)),
                    np.sort(np.asarray(want_v)),
                    atol=1e-5,
                )
            )
    return out


def check_merge_sharded():
    mesh = compat.make_mesh((8,), ("data",))
    g = Graph.erdos_renyi(32, 0.5, seed=2)
    part = connectivity_preserving_partition(g, 4)
    rng = np.random.default_rng(0)
    k = 2
    cand = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = merge_mod.build_merge_plan(part, cand, k)
    # exact single-device answer
    want = merge_mod.merge_scan(plan, merge_mod.exact_beam_width(k, part.m))
    assign, val = dist.merge_sharded(plan, 16, mesh, split_level=1)
    achieved = float(
        cut_value(g, jnp.asarray(np.asarray(assign).reshape(-1)[: g.n]))
    )
    val = float(np.asarray(val).reshape(-1)[0])
    out = {
        "val_matches_exact": bool(abs(val - float(want.cut_value)) < 1e-3),
        "assignment_achieves_val": bool(abs(achieved - val) < 1e-3),
    }
    # striped_beam_width must yield an exhaustive sweep at every split
    # level (regression: the pre-split frontier term undercounted, so
    # split_level >= 2 pruned partial-score rows and lost the optimum)
    for sl in (1, 2, 3):
        w = merge_mod.striped_beam_width(k, part.m, 8, sl)
        _, v = dist.merge_sharded(plan, w, mesh, split_level=sl)
        v = float(np.asarray(v).reshape(-1)[0])
        out[f"split{sl}_exact_at_proven_width"] = bool(
            abs(v - float(want.cut_value)) < 1e-3
        )
    return out


def check_solve_distributed():
    """End-to-end `solve_distributed` vs single-device `solve` parity.

    Two regimes (DESIGN.md §2.4):
      - data-only mesh: identical partition + the same compiled pool
        program + provably-exhaustive striped merge ⇒ cut values equal;
      - data+model mesh at opt_steps=0: oversized subgraphs route
        through the sharded statevector at the same linear-ramp
        parameters the (lifted-budget) single-device pool uses ⇒ equal.
    """
    import dataclasses

    from repro.core import paraqaoa as para_mod
    from repro.core import distributed as dist_mod
    from repro.core.partition import partition_for_solver

    g = Graph.erdos_renyi(48, 0.3, seed=7)
    cfg = para_mod.ParaQAOAConfig(
        n_qubits=8, top_k=2, p_layers=2, opt_steps=10
    )
    want = para_mod.solve(g, cfg)
    got = dist_mod.solve_distributed(g, cfg, {"data": 4})
    out = {
        "pool_cut_matches_single": bool(got.cut_value == want.cut_value),
        "striped_merge_engaged": bool(got.report.extra["merge_shards"] == 4),
        "assignments_consistent": bool(
            float(cut_value(g, jnp.asarray(got.assignment))) == got.cut_value
        ),
    }

    cfg0 = dataclasses.replace(cfg, opt_steps=0)
    part = partition_for_solver(g, 10)  # budget lifted by log2(model)=2
    want0 = para_mod.solve(
        g, dataclasses.replace(cfg0, n_qubits=10), partition=part
    )
    got0 = dist_mod.solve_distributed(g, cfg0, {"data": 2, "model": 4})
    out["model_cut_matches_lifted_single"] = bool(
        got0.cut_value == want0.cut_value
    )
    out["model_routed_subproblems"] = bool(
        got0.report.extra["sharded_subproblems"] > 0
    )
    return out


def main():
    checks = {
        "solve_pool": check_solve_pool,
        "sharded_qaoa": check_sharded_qaoa,
        "merge_sharded": check_merge_sharded,
        "solve_distributed": check_solve_distributed,
    }
    which = sys.argv[1] if len(sys.argv) > 1 else ""
    if which not in checks:
        print(f"usage: python -m repro.core._dist_checks {{{'|'.join(checks)}}}")
        raise SystemExit(2)
    print(json.dumps(checks[which]()))


if __name__ == "__main__":
    main()

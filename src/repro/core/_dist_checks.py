"""Multi-device correctness checks, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests/test_distributed.py).

Prints one JSON object; the parent test asserts on it.
"""

from __future__ import annotations

import json
import sys

from repro import compat

# standalone-friendly: emulate 8 host devices when run without the test
# harness's XLA_FLAGS (no-op if the jax backend is already initialized)
compat.ensure_host_device_count(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import merge as merge_mod
from repro.core import qaoa as qaoa_mod
from repro.core.graph import Graph, cut_value
from repro.core.partition import connectivity_preserving_partition
# the harness's whole job is comparing impls against the reference
from repro.kernels import ref  # reprolint: disable=dispatch-purity


def check_solve_pool():
    mesh = compat.make_mesh((8,), ("data",))
    g = Graph.erdos_renyi(60, 0.4, seed=0)
    part = connectivity_preserving_partition(g, 6)
    cfg = qaoa_mod.QAOAConfig(n_qubits=11, p_layers=2, opt_steps=10, top_k=2)
    edges, weights, masks = qaoa_mod.pad_subgraph_arrays(part.subgraphs, 11)
    # single-device reference
    want = qaoa_mod.solve_subgraph_batch(edges, weights, masks, cfg)
    got = dist.solve_pool(edges, weights, masks, cfg, mesh)
    return {
        "bitstrings_equal": bool(
            np.array_equal(np.asarray(want.bitstrings), np.asarray(got.bitstrings))
        ),
        "exp_close": bool(
            np.allclose(
                np.asarray(want.expectation), np.asarray(got.expectation), atol=1e-4
            )
        ),
    }


def check_sharded_qaoa():
    out = {}
    n = 10
    g = Graph.erdos_renyi(n, 0.5, seed=1)
    gammas = jnp.asarray([0.3, 0.55], jnp.float32)
    betas = jnp.asarray([0.9, 0.4], jnp.float32)
    # single-device reference
    cutv = ref.cutvals(n, g.edges, g.weights)
    re, im = qaoa_mod.qaoa_statevector(cutv, n, gammas, betas)
    want_exp = float(ref.expectation(re, im, cutv))
    probs = re * re + im * im
    want_v, want_i = jax.lax.top_k(probs, 4)

    for axis_size in (4, 8):
        mesh = compat.make_mesh((axis_size,), ("model",))
        for schedule in ("faithful", "alternating"):
            res = dist.sharded_qaoa(
                g.edges, g.weights, n, gammas, betas, mesh,
                axis="model", top_k=4, schedule=schedule,
            )
            key = f"d{axis_size}_{schedule}"
            out[key + "_exp_close"] = bool(
                np.allclose(float(res.expectation[0] if res.expectation.ndim else res.expectation), want_exp, atol=1e-4)
            )
            # the top-1 *index* can differ under exact prob ties (|psi_b| ==
            # |psi_~b| by flip symmetry); compare its probability instead
            top1 = int(np.asarray(res.bitstrings).reshape(-1)[0])
            out[key + "_top1_match"] = bool(
                np.isclose(float(probs[top1]), float(want_v[0]), atol=1e-6)
            )
            out[key + "_probs_close"] = bool(
                np.allclose(
                    np.sort(np.asarray(res.probs).reshape(-1)),
                    np.sort(np.asarray(want_v)),
                    atol=1e-5,
                )
            )
    return out


def check_merge_sharded():
    mesh = compat.make_mesh((8,), ("data",))
    g = Graph.erdos_renyi(32, 0.5, seed=2)
    part = connectivity_preserving_partition(g, 4)
    rng = np.random.default_rng(0)
    k = 2
    cand = rng.integers(0, 2 ** min(part.sizes), size=(part.m, k))
    plan = merge_mod.build_merge_plan(part, cand, k)
    # exact single-device answer
    want = merge_mod.merge_scan(plan, merge_mod.exact_beam_width(k, part.m))
    assign, val = dist.merge_sharded(plan, 16, mesh, split_level=1)
    achieved = float(
        cut_value(g, jnp.asarray(np.asarray(assign).reshape(-1)[: g.n]))
    )
    val = float(np.asarray(val).reshape(-1)[0])
    out = {
        "val_matches_exact": bool(abs(val - float(want.cut_value)) < 1e-3),
        "assignment_achieves_val": bool(abs(achieved - val) < 1e-3),
    }
    # striped_beam_width must yield an exhaustive sweep at every split
    # level (regression: the pre-split frontier term undercounted, so
    # split_level >= 2 pruned partial-score rows and lost the optimum)
    for sl in (1, 2, 3):
        w = merge_mod.striped_beam_width(k, part.m, 8, sl)
        _, v = dist.merge_sharded(plan, w, mesh, split_level=sl)
        v = float(np.asarray(v).reshape(-1)[0])
        out[f"split{sl}_exact_at_proven_width"] = bool(
            abs(v - float(want.cut_value)) < 1e-3
        )
    return out


def check_engine_grad():
    """jax.grad through the sharded evolution vs the single-device
    gradient (float32 tolerance), plus the sharded Adam ascent improving
    on the linear ramp — the DESIGN.md §2.6 differentiability contract."""
    from jax.sharding import PartitionSpec as P

    from repro.core import engine
    from repro.kernels import ops

    out = {}
    n = 10
    g = Graph.erdos_renyi(n, 0.5, seed=3)
    gammas, betas = qaoa_mod.linear_ramp_init(3, 0.75)

    cutv = ref.cutvals(n, g.edges, g.weights)
    flat_loss = lambda p: qaoa_mod.qaoa_expectation(p, cutv, n)
    want = jax.grad(flat_loss)((gammas, betas))
    scale = max(float(jnp.max(jnp.abs(x))) for x in want)

    for d in (2, 4):
        mesh = compat.make_mesh((d,), ("model",))
        layout = engine.ShardedLayout(n=n, axis="model", axis_size=d)

        def local_grad(edges, weights, gm, bt):
            cut = engine.cut_table(layout, edges, weights)

            def local_exp(params):
                gg, bb = params
                re, im, in_b = engine.evolve(layout, cut, gg, bb)
                return ops.expectation(re, im, cut.at(in_b))

            grads = jax.grad(local_exp)((gm, bt))
            return jax.tree.map(lambda x: jax.lax.psum(x, "model"), grads)

        run = compat.jit(
            compat.shard_map(
                local_grad, mesh, in_specs=(P(),) * 4, out_specs=(P(), P())
            )
        )
        got = run(g.edges, g.weights, gammas, betas)
        err = max(
            float(jnp.max(jnp.abs(w - g_))) for w, g_ in zip(want, got)
        )
        # float32 forward/backward through p=3 layers + collectives: the
        # elementwise error is a few 1e-4 of the gradient scale
        out[f"d{d}_grad_close"] = bool(err <= 2e-3 * max(scale, 1.0))

    mesh = compat.make_mesh((4,), ("model",))
    r_ramp = dist.sharded_qaoa(g.edges, g.weights, n, gammas, betas, mesh)
    r_opt = dist.sharded_qaoa(
        g.edges, g.weights, n, gammas, betas, mesh, opt_steps=30
    )
    e_ramp = float(np.asarray(r_ramp.expectation).reshape(-1)[0])
    e_opt = float(np.asarray(r_opt.expectation).reshape(-1)[0])
    out["ascent_beats_ramp"] = bool(e_opt >= e_ramp)
    # the sharded ascent must land where the single-device optimizer lands
    cfg = qaoa_mod.QAOAConfig(n_qubits=n, p_layers=3, opt_steps=30)
    p_flat = qaoa_mod.optimize_params(cutv, n, cfg)
    out["ascent_matches_flat_optimum"] = bool(
        all(
            np.allclose(np.asarray(a), np.asarray(b), atol=1e-4)
            for a, b in zip(p_flat, (r_opt.gammas, r_opt.betas))
        )
    )
    return out


def check_engine_interpret():
    """The sharded hot loop under `ops.using_implementation` — proves
    every phase/mixer/cutvals/expectation op goes through the
    `kernels.ops` dispatch per shard (no direct `ref.*` calls), and that
    the `pallas_interpret` and `xla` paths agree.

    Agreement grading: the cut tables are bitwise identical (integer-
    valued sums); the evolved state is ulp-tight but *not* bitwise —
    the mixer kernels generate RX^{⊗k} via runtime `pow` (MXU-friendly,
    no gather) while `ref.rx_kron_parts` uses cumprod tables, a
    deliberate last-ulp divergence (see kernels/mixer.py)."""
    # imported to *instrument* the impl modules (wrap + count calls) and
    # prove dispatch reaches them — the exception that tests the rule
    import repro.kernels.cutvals as cutvals_mod  # reprolint: disable=dispatch-purity
    import repro.kernels.fused_layer as fused_mod  # reprolint: disable=dispatch-purity
    import repro.kernels.mixer as mixer_mod  # reprolint: disable=dispatch-purity
    import repro.kernels.phase as phase_mod  # reprolint: disable=dispatch-purity
    from repro.kernels import ops

    hits = {}

    def wrap(mod, name):
        orig = getattr(mod, name)

        def wrapped(*a, **k):
            hits[name] = hits.get(name, 0) + 1
            return orig(*a, **k)

        setattr(mod, name, wrapped)

    wrap(fused_mod, "fused_phase_mixer_group")
    wrap(mixer_mod, "mixer_group_matmul")
    wrap(mixer_mod, "mixer_group_strided")
    wrap(cutvals_mod, "cutvals_at")
    wrap(phase_mod, "expectation")

    n = 8
    g = Graph.erdos_renyi(n, 0.5, seed=5)  # unit weights: exact cut sums
    gammas = jnp.asarray([0.4, 0.3], jnp.float32)
    betas = jnp.asarray([0.9, 0.5], jnp.float32)
    mesh = compat.make_mesh((4,), ("model",))

    out = {}
    for schedule in ("faithful", "alternating"):
        res_x = dist.sharded_qaoa(
            g.edges, g.weights, n, gammas, betas, mesh, schedule=schedule
        )
        before = dict(hits)
        with ops.using_implementation("pallas_interpret"):
            res_p = dist.sharded_qaoa(
                g.edges, g.weights, n, gammas, betas, mesh, schedule=schedule
            )
        fired = {k: hits.get(k, 0) - before.get(k, 0) for k in hits}
        key = schedule
        out[f"{key}_dispatch_fused_layer"] = fired.get(
            "fused_phase_mixer_group", 0
        ) > 0
        # either mixer launcher counts: mid-state groups take the fused
        # strided-BlockSpec kernel, trailing (y == 1) groups the matmul
        out[f"{key}_dispatch_mixer"] = (
            fired.get("mixer_group_matmul", 0)
            + fired.get("mixer_group_strided", 0)
        ) > 0
        out[f"{key}_dispatch_cutvals_at"] = fired.get("cutvals_at", 0) > 0
        out[f"{key}_dispatch_expectation"] = fired.get("expectation", 0) > 0
        out[f"{key}_probs_close"] = bool(
            np.allclose(
                np.asarray(res_x.probs), np.asarray(res_p.probs), atol=1e-7
            )
        )
        out[f"{key}_exp_close"] = bool(
            np.allclose(
                np.asarray(res_x.expectation),
                np.asarray(res_p.expectation),
                atol=1e-5,
            )
        )

    # regression: opt_steps > 0 must work under non-xla dispatch too —
    # the ascent pins its gradient trace to the xla path (Pallas kernels
    # have no AD rule), so pallas_interpret + ascent lands on the same
    # optimized parameters as the xla run
    with ops.using_implementation("pallas_interpret"):
        r_opt_p = dist.sharded_qaoa(
            g.edges, g.weights, n, gammas, betas, mesh, opt_steps=3
        )
    with ops.using_implementation("xla"):
        r_opt_x = dist.sharded_qaoa(
            g.edges, g.weights, n, gammas, betas, mesh, opt_steps=3
        )
    out["opt_runs_under_interpret"] = bool(
        np.allclose(
            np.asarray(r_opt_p.gammas), np.asarray(r_opt_x.gammas), atol=1e-6
        )
        and np.allclose(
            np.asarray(r_opt_p.betas), np.asarray(r_opt_x.betas), atol=1e-6
        )
    )

    # cut tables bitwise: pallas_interpret cutvals_at == ref, per layout
    from repro.core import engine

    layout = engine.ShardedLayout(n=n, axis="model", axis_size=4)
    bitwise = []
    for d in range(4):
        idx_a, idx_b = engine.layout_index_maps(layout, d)
        for idx in (idx_a, idx_b):
            idx = jnp.asarray(idx, jnp.int32)
            with ops.using_implementation("pallas_interpret"):
                got = ops.cutvals_at(idx, g.edges, g.weights)
            bitwise.append(
                np.array_equal(
                    np.asarray(got),
                    np.asarray(ref.cutvals_at(idx, g.edges, g.weights)),
                )
            )
    out["cut_tables_bitwise"] = bool(all(bitwise))
    return out


def check_solve_distributed():
    """End-to-end `solve_distributed` vs single-device `solve` parity.

    Two regimes (DESIGN.md §2.4):
      - data-only mesh: identical partition + the same compiled pool
        program + provably-exhaustive striped merge ⇒ cut values equal;
      - data+model mesh at opt_steps=0: oversized subgraphs route
        through the sharded statevector at the same linear-ramp
        parameters the (lifted-budget) single-device pool uses ⇒ equal.
    """
    import dataclasses

    from repro.core import paraqaoa as para_mod
    from repro.core import distributed as dist_mod
    from repro.core.partition import partition_for_solver

    g = Graph.erdos_renyi(48, 0.3, seed=7)
    cfg = para_mod.ParaQAOAConfig(
        n_qubits=8, top_k=2, p_layers=2, opt_steps=10
    )
    want = para_mod.solve(g, cfg)
    got = dist_mod.solve_distributed(g, cfg, {"data": 4})
    out = {
        "pool_cut_matches_single": bool(got.cut_value == want.cut_value),
        "striped_merge_engaged": bool(got.report.extra["merge_shards"] == 4),
        "assignments_consistent": bool(
            float(cut_value(g, jnp.asarray(got.assignment))) == got.cut_value
        ),
    }

    cfg0 = dataclasses.replace(cfg, opt_steps=0)
    part = partition_for_solver(g, 10)  # budget lifted by log2(model)=2
    want0 = para_mod.solve(
        g, dataclasses.replace(cfg0, n_qubits=10), partition=part
    )
    got0 = dist_mod.solve_distributed(g, cfg0, {"data": 2, "model": 4})
    out["model_cut_matches_lifted_single"] = bool(
        got0.cut_value == want0.cut_value
    )
    out["model_routed_subproblems"] = bool(
        got0.report.extra["sharded_subproblems"] > 0
    )
    return out


def check_problem_distributed():
    """QUBO/MIS linear terms through the distributed paths (DESIGN.md §9):
    `solve_distributed` on a data mesh must match single-device `solve`
    on the same `Problem` exactly (same pool program keyed has_lin=True,
    same linear-aware striped merge), and the MIS result must be a valid
    independent set."""
    from repro.core import paraqaoa as para_mod
    from repro.core import distributed as dist_mod
    from repro.core.graph import Problem, independent_set_violations

    rng = np.random.default_rng(17)
    n = 48
    e = np.array(
        [(i, j) for i in range(n) for j in range(i + 1, n)
         if rng.random() < 0.15],
        dtype=np.int32,
    )
    q = rng.normal(size=e.shape[0]).astype(np.float32)
    h = rng.normal(size=n).astype(np.float32)
    prob = Problem.qubo(n, e, q, linear=h, offset=0.25)
    cfg = para_mod.ParaQAOAConfig(
        n_qubits=8, top_k=2, p_layers=2, opt_steps=10
    )
    want = para_mod.solve(prob, cfg)
    got = dist_mod.solve_distributed(prob, cfg, {"data": 4})
    out = {
        "qubo_cut_matches_single": bool(got.cut_value == want.cut_value),
        "qubo_assignments_equal": bool(
            np.array_equal(got.assignment, want.assignment)
        ),
    }

    import dataclasses

    # beam-pruned MIS solves can leave violations; the 1-flip refinement
    # provably clears them (dropping a violating vertex gains >= P-1 > 0)
    g = Graph.erdos_renyi(40, 0.12, seed=9)
    mis = Problem.mis(g)
    cfg_r = dataclasses.replace(cfg, refine_steps=60)
    want_m = para_mod.solve(mis, cfg_r)
    got_m = dist_mod.solve_distributed(mis, cfg_r, {"data": 4})
    out["mis_cut_matches_single"] = bool(got_m.cut_value == want_m.cut_value)
    out["mis_valid_independent_set"] = bool(
        independent_set_violations(g, got_m.assignment) == 0
    )
    return out


def check_service_mesh():
    """Service-backend parity (DESIGN.md §6.5): the same request mix
    through the single-device `LocalBackend` and through `MeshBackend`
    (solve_pool over an emulated 4-device `data` mesh) must produce
    bit-identical per-request cuts and assignments — and non-cached
    requests must stay bit-identical to solo `core.solve` on their own
    planned knobs. Recalibration is pinned off so both services plan
    identically (knob choice is time-dependent with it on)."""
    from repro.core import paraqaoa as para_mod
    from repro.service import SLA, ServiceConfig, SolveService
    from repro.service.workload import request_mix, tenant_mix

    graphs = request_mix(6, (30, 60), 0.2, 0.25, seed=3)
    tenants = tenant_mix(6, 2, seed=3)
    sla = SLA(deadline_s=20.0)

    def run_service(mesh):
        svc = SolveService(ServiceConfig(
            batch_slots=8, max_qubits=8, mesh=mesh, max_inflight=2,
            recalibrate=False,
        ))
        rids = [svc.submit(g, sla, tenant=t)
                for g, t in zip(graphs, tenants)]
        svc.drain()
        return svc, rids

    svc_l, rids_l = run_service(None)
    svc_m, rids_m = run_service("data=4")

    out = {"backends_parity": True, "solo_parity": True}
    for g, rl, rm in zip(graphs, rids_l, rids_m):
        ra, rb = svc_l.results[rl], svc_m.results[rm]
        out["backends_parity"] &= bool(
            ra.cut_value == rb.cut_value
            and np.array_equal(ra.assignment, rb.assignment)
        )
        if not ra.cached:
            solo = para_mod.solve(g, ra.plan.to_config())
            out["solo_parity"] &= bool(ra.cut_value == solo.cut_value)
    out["mesh_backend_engaged"] = bool(
        svc_m.backend.describe()["devices"] == 4
        and svc_m.stats.dispatches > 0
    )
    out["tenants_accounted"] = bool(
        set(svc_m.stats.tenants) == set(tenants)
        and sum(t.completed for t in svc_m.stats.tenants.values()) == 6
    )
    out["async_window_used"] = bool(svc_m.stats.max_inflight_seen >= 2)
    return out


def main():
    checks = {
        "solve_pool": check_solve_pool,
        "sharded_qaoa": check_sharded_qaoa,
        "merge_sharded": check_merge_sharded,
        "engine_grad": check_engine_grad,
        "engine_interpret": check_engine_interpret,
        "solve_distributed": check_solve_distributed,
        "problem_distributed": check_problem_distributed,
        "service_mesh": check_service_mesh,
    }
    which = sys.argv[1] if len(sys.argv) > 1 else ""
    if which not in checks:
        print(f"usage: python -m repro.core._dist_checks {{{'|'.join(checks)}}}")
        raise SystemExit(2)
    print(json.dumps(checks[which]()))


if __name__ == "__main__":
    main()

"""Mamba2 / SSD (state-space duality) block, chunked matmul form.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): the
sequence is processed in chunks of Q tokens; within a chunk the recurrence
is materialized as a (Q, Q) lower-triangular attention-like matmul (MXU
food), and across chunks a small lax.scan carries the (H, N, P) state.
Per-step recurrence (for decode) and the chunked form are tested to agree.

Block structure follows Mamba2: in_proj → causal depthwise conv on
(x, B, C) → SSD → gated RMSNorm → out_proj.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def ssm_init(key, d_model: int, d_inner: int, n_heads: int, d_state: int,
             conv_width: int, dtype):
    ks = jax.random.split(key, 6)
    p_head = d_inner // n_heads
    conv_ch = d_inner + 2 * d_state
    return {
        # projects to [z, x, B, C, dt]
        "in_proj": L.normal_init(
            ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads), dtype
        ),
        "conv_w": L.normal_init(ks[1], (conv_width, conv_ch), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, float(n_heads), n_heads).astype(jnp.float32)
        ),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": L.normal_init(ks[2], (d_inner, d_model), dtype),
    }


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    x = proj[..., d_inner : 2 * d_inner]
    b = proj[..., 2 * d_inner : 2 * d_inner + d_state]
    c = proj[..., 2 * d_inner + d_state : 2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state :]
    return z, x, b, c, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,C), w (W,C) → (B,S,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4: unrolled taps
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P) values; dt: (B,S,H) step sizes (post-softplus);
    a: (H,) negative decay rates; b_mat/c_mat: (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = chunk
    s_pad = ((s + q - 1) // q) * q
    nc = s_pad // q

    def pad(t):
        if s_pad == s:
            return t
        widths = [(0, 0), (0, s_pad - s)] + [(0, 0)] * (t.ndim - 2)
        return jnp.pad(t, widths)

    # zero-dt padding is exact: decay = exp(a·0) = 1 and the update term
    # carries a dt factor, so padded steps leave the state untouched.
    xf = pad(x.astype(jnp.float32))
    dtf = pad(dt.astype(jnp.float32))
    bf = pad(b_mat.astype(jnp.float32))
    cf = pad(c_mat.astype(jnp.float32))

    # chunk views
    xc = xf.reshape(bsz, nc, q, h, p)
    dtc = dtf.reshape(bsz, nc, q, h)
    bc = bf.reshape(bsz, nc, q, n)
    cc = cf.reshape(bsz, nc, q, n)
    s = s_pad  # trimmed again on return

    l = a[None, None, None, :] * dtc  # (B,nc,Q,H) log-decay per step
    lc = jnp.cumsum(l, axis=2)  # inclusive cumulative log decay
    ltot = lc[:, :, -1:, :]  # (B,nc,1,H)

    # ---- intra-chunk (quadratic-in-Q matmul form) -------------------------
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,nc,Q,Q)
    seg = lc[:, :, :, None, :] - lc[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask *inside* the exp: for j > i the log-decay difference is positive
    # and can exceed ln(f32 max) (≈88.7 already at H=16, Q=8, dt≈0.7), so
    # exp overflows to inf; masking after the multiply then backprops
    # 0·inf = NaN through the where. -1e9 underflows to exactly 0.
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e9)
    decay = jnp.exp(seg)
    m = cb[:, :, :, :, None] * decay * dtc[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # ---- chunk summaries and inter-chunk scan -----------------------------
    w_sum = jnp.exp(ltot - lc) * dtc  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_sum, bc, xc)  # (B,nc,H,N,P)
    g_chunk = jnp.exp(ltot[:, :, 0, :])  # (B,nc,H) total chunk decay

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def scan_fn(h_prev, inp):
        s_c, g_c = inp  # (B,H,N,P), (B,H)
        h_in = h_prev  # state entering this chunk
        h_next = g_c[:, :, None, None] * h_prev + s_c
        return h_next, h_in

    (h_final, h_ins) = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(g_chunk, 1, 0)),
    )
    h_ins = jnp.moveaxis(h_ins, 0, 1)  # (B,nc,H,N,P) state at chunk start

    y_inter = jnp.einsum(
        "bcin,bchnp,bcih->bcihp", cc, h_ins, jnp.exp(lc)
    )
    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, : x.shape[1]]
    return y.astype(x.dtype), h_final


def ssd_step(h, x_t, dt_t, a, b_t, c_t):
    """Single-token recurrence: h (B,H,N,P); x_t (B,H,P); dt_t (B,H);
    b_t/c_t (B,N). Returns (y_t (B,H,P), h')."""
    g = jnp.exp(a[None, :] * dt_t)  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
    h_new = g[:, :, None, None] * h + upd
    y = jnp.einsum("bn,bhnp->bhp", c_t, h_new)
    return y, h_new


class SSMState(NamedTuple):
    h: jnp.ndarray  # (B, H, N, P) float32
    conv: jnp.ndarray  # (B, W-1, conv_channels) rolling conv inputs


def ssm_block(params, x, cfg, h0=None):
    """Full Mamba2 block over a sequence. x: (B,S,D) → (B,S,D)."""
    d_inner, d_state, n_heads = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p_head = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xin, b_mat, c_mat, dt = _split_proj(proj, d_inner, d_state, n_heads)

    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :d_inner]
    b_mat = conv_out[..., d_inner : d_inner + d_state]
    c_mat = conv_out[..., d_inner + d_state :]

    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = xin.reshape(*xin.shape[:2], n_heads, p_head)
    y, h_fin = ssd_chunked(xh, dtp, a, b_mat, c_mat, cfg.ssm_chunk, h0)
    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(*x.shape[:2], d_inner)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"]), h_fin


def ssm_decode_step(params, x, state: SSMState, cfg):
    """One-token Mamba2 step. x: (B,1,D) → ((B,1,D), new state)."""
    d_inner, d_state, n_heads = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p_head = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]
    z, xin, b_mat, c_mat, dt = _split_proj(proj, d_inner, d_state, n_heads)

    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)  # (B,C)
    width = params["conv_w"].shape[0]
    hist = jnp.concatenate([state.conv, conv_in[:, None, :]], axis=1)  # (B,W,C)
    conv_out = (
        jnp.einsum("bwc,wc->bc", hist, params["conv_w"]) + params["conv_b"]
    )
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :d_inner]
    b_t = conv_out[..., d_inner : d_inner + d_state]
    c_t = conv_out[..., d_inner + d_state :]

    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    xh = xin.reshape(-1, n_heads, p_head)
    y, h_new = ssd_step(state.h, xh, dtp, a, b_t, c_t)
    y = y + params["d_skip"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)  # f32 SSD state → act dtype
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z)[:, None, :], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out.astype(x.dtype), SSMState(h=h_new, conv=hist[:, 1:, :])

"""Architecture configuration schema.

One frozen dataclass describes every assigned architecture (LM-family
transformers, MoE, SSM/hybrid, encoder-decoder, VLM). `src/repro/configs/`
holds one instance per assigned arch; reduced variants power the CPU smoke
tests while the full configs are exercised abstractly by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False  # qwen1.5 uses QKV bias
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, whisper)

    # -- attention pattern ---------------------------------------------------
    sliding_window: Optional[int] = None  # window for local layers
    global_every: int = 0  # gemma3: every k-th layer is global (5:1 → k=6)

    # -- mixture of experts ----------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel w/ MoE
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # -- state-space (Mamba2 / SSD) -------------------------------------------
    ssm_state: int = 0  # N (d_state)
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2  # d_inner = expand * d_model
    ssm_chunk: int = 64  # SSD chunk length
    ssm_conv_width: int = 4
    attn_every: int = 0  # zamba2: shared attention block every k layers

    # -- encoder-decoder (whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # precomputed conv-frame count (stub frontend)

    # -- modality frontend stubs -------------------------------------------------
    frontend: Optional[str] = None  # vision_stub | audio_stub
    frontend_seq: int = 0  # patches / frames supplied by input_specs
    frontend_dim: int = 0  # stub embedding dim (== d_model)

    max_seq: int = 131_072
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' | 'ssm' (decoder stack)."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.family == "hybrid":
            k = self.attn_every or 6
            return tuple(
                "ssm_attn" if (i % k == k - 1) else "ssm"
                for i in range(self.n_layers)
            )
        return tuple("attn" for _ in range(self.n_layers))

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (0 = full/global attention)."""
        if self.sliding_window is None:
            return tuple(0 for _ in range(self.n_layers))
        k = self.global_every or 0
        return tuple(
            0 if (k and (i % k == k - 1)) else self.sliding_window
            for i in range(self.n_layers)
        )

    def n_params(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_ if self.n_heads else 0
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = (
            d * self.n_heads * hd
            + 2 * d * self.n_kv_heads * hd
            + self.n_heads * hd * d
        ) if self.n_heads else 0
        if self.act == "silu":
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        if self.n_experts:
            per_mlp = self.n_experts * (3 * d * f) + d * self.n_experts
            if self.moe_dense_residual:
                per_mlp += 3 * d * self.d_ff_dense
        per_ssm = (
            d * (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads)
            + self.d_inner * d
            + self.ssm_conv_width * (self.d_inner + 2 * self.ssm_state)
        )
        total = emb
        for kind in self.layer_kinds():
            if kind == "attn":
                total += per_attn + per_mlp + 2 * d
            elif kind == "ssm":
                total += per_ssm + 2 * d
            else:  # ssm_attn: ssm block + shared attn counted once below
                total += per_ssm + 2 * d
        if self.family == "hybrid":
            total += per_attn + 2 * d  # one shared attention block
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attention
            total += self.encoder_layers * (per_attn + per_mlp + 2 * d)
            total += self.n_layers * (per_attn + d)  # cross-attn per decoder layer
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_total = self.n_params()
        moe_all = self.n_layers * self.n_experts * 3 * d * f
        moe_active = self.n_layers * self.experts_per_token * 3 * d * f
        return int(dense_total - moe_all + moe_active)

    @property
    def d_ff_dense(self) -> int:
        """Arctic-style dense residual FFN width (when moe_dense_residual)."""
        return self.d_ff


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 6),
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else None,
        d_ff=256 if not cfg.n_experts else 64,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.experts_per_token
        else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=8,
        sliding_window=16 if cfg.sliding_window else None,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=24 if cfg.is_encoder_decoder else cfg.encoder_seq,
        frontend_seq=16 if cfg.frontend else 0,
        frontend_dim=128 if cfg.frontend else 0,
        max_seq=256,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

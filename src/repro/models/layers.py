"""Primitive layers: norms, RoPE, initializers, MLPs.

Pure-functional: every layer is (init(key, ...) -> params) plus
(apply(params, x, ...) -> y). Parameters live in nested dicts; block
parameters are stacked on a leading layer axis and driven by lax.scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ------------------------------------------------- activation shard hints --
# The launcher/dry-run configures the mesh axis names once; model code then
# drops with_sharding_constraint hints that are exact no-ops in single-
# device tests. This is how we pin (B, S, V) logits to (dp, None, "model")
# instead of letting GSPMD replicate the vocab axis (150 GB/device temp).
_HINT_AXES: frozenset = frozenset()


def configure_shard_hints(axis_names) -> None:
    global _HINT_AXES
    _HINT_AXES = frozenset(axis_names or ())


def shard_hint(x, *spec):
    """with_sharding_constraint against configured mesh axes; no-op when
    unconfigured. Tuple entries keep only the axes present in the mesh."""
    if not _HINT_AXES:
        return x
    parts = []
    for s in spec:
        if s is None:
            parts.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in _HINT_AXES)
            parts.append(kept if kept else None)
        else:
            parts.append(s if s in _HINT_AXES else None)
    return jax.lax.with_sharding_constraint(x, P(*parts))


DP = ("pod", "data")  # batch-parallel axis group


# ----------------------------------------------------------------- inits --
def normal_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


# ----------------------------------------------------------------- norms --
def rmsnorm_init(dtype):
    def init(key, d):
        return {"scale": jnp.ones((d,), dtype)}

    return init


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(key, d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (
        y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    ).astype(dt)


# ------------------------------------------------------------------ RoPE --
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    Rotate-half form via jnp.roll with full-width cos/sin tables rather
    than split+concatenate along hd: slice-then-concatenate on the last
    axis produces wrong results under GSPMD when hd is sharded (observed
    on jax 0.4.37 CPU SPMD; see docs/TESTING.md). The roll form is
    bitwise-identical unsharded and partitions correctly.
    """
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.tile(jnp.cos(angles), 2)[..., None, :]  # (..., S, 1, hd)
    sin = jnp.tile(jnp.sin(angles), 2)[..., None, :]
    sign = jnp.concatenate(
        [-jnp.ones(hd // 2, jnp.float32), jnp.ones(hd // 2, jnp.float32)]
    )
    xf = x.astype(jnp.float32)
    rot = jnp.roll(xf, hd // 2, axis=-1) * sign  # [-x2, x1]
    return (xf * cos + rot * sin).astype(x.dtype)


# ------------------------------------------------------------------- MLP --
def mlp_init(key, d: int, f: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU
        return {
            "w_gate": normal_init(ks[0], (d, f), dtype),
            "w_up": normal_init(ks[1], (d, f), dtype),
            "w_down": normal_init(ks[2], (f, d), dtype),
        }
    return {
        "w_up": normal_init(ks[0], (d, f), dtype),
        "b_up": jnp.zeros((f,), dtype),
        "w_down": normal_init(ks[1], (f, d), dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def mlp_apply(params, x, act: str):
    if act == "silu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, params["w_down"])
    h = jnp.einsum("...d,df->...f", x, params["w_up"]) + params["b_up"]
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["w_down"]) + params["b_down"]


# ------------------------------------------------------------- embedding --
def embedding_init(key, vocab: int, d: int, dtype):
    return {"table": normal_init(key, (vocab, d), dtype, scale=0.01)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x, tied_table=None):
    table = tied_table if tied_table is not None else params["table"]
    return jnp.einsum("...d,vd->...v", x, table)

from repro.models.config import ModelConfig, reduced
from repro.models.model import Model, build_model

__all__ = ["ModelConfig", "reduced", "Model", "build_model"]

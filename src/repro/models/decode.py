"""Serving paths: prefill (build caches over a prompt) and single-token
decode steps, for every architecture family.

Cache layout (stacked on a leading layer axis, scanned like the blocks):
  attn families:  DecodeState.kv      (L, B, S_max, Hkv, hd) ×2
  ssm/hybrid:     DecodeState.ssm     (L, B, H, N, P) + conv history;
                  hybrid adds shared-attention KV per *application*
                  (n_apps, B, S_max, Hkv, hd) — Zamba2 shares weights
                  across applications but each application has its own KV.
  audio (enc-dec): self-KV per decoder layer + precomputed cross-K/V.

`decode_32k` / `long_500k` lower exactly these functions: one new token
against a seq_len-sized cache. The cache sequence axis is the
sequence-parallel shard axis for the 500k single-request shapes.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.config import ModelConfig


class DecodeState(NamedTuple):
    kv_k: Optional[jnp.ndarray] = None  # (L, B, S_max, Hkv, hd)
    kv_v: Optional[jnp.ndarray] = None
    ssm_h: Optional[jnp.ndarray] = None  # (L, B, H, N, P)
    ssm_conv: Optional[jnp.ndarray] = None  # (L, B, W-1, C)
    shared_k: Optional[jnp.ndarray] = None  # (n_apps, B, S_max, Hkv, hd)
    shared_v: Optional[jnp.ndarray] = None
    cross_k: Optional[jnp.ndarray] = None  # (L, B, T_enc, Hkv, hd)
    cross_v: Optional[jnp.ndarray] = None
    pos: Optional[jnp.ndarray] = None  # (B,) tokens cached so far


def n_attn_apps(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k == "ssm_attn")


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int) -> DecodeState:
    """Empty caches (used directly by the decode-shape dry-runs)."""
    dt = cfg.cdtype
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_ if cfg.n_heads else 0
    state = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        state["kv_k"] = jnp.zeros((cfg.n_layers, batch, s_max, hkv, hd), dt)
        state["kv_v"] = jnp.zeros((cfg.n_layers, batch, s_max, hkv, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        state["ssm_h"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        )
        state["ssm_conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv_width - 1, conv_ch), dt
        )
    if cfg.family == "hybrid":
        apps = n_attn_apps(cfg)
        state["shared_k"] = jnp.zeros((apps, batch, s_max, hkv, hd), dt)
        state["shared_v"] = jnp.zeros((apps, batch, s_max, hkv, hd), dt)
    if cfg.family == "audio":
        state["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.encoder_seq, hkv, hd), dt
        )
        state["cross_v"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.encoder_seq, hkv, hd), dt
        )
    state["pos"] = jnp.zeros((batch,), jnp.int32)
    return DecodeState(**state)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def prefill(params, batch, cfg: ModelConfig, s_max: int):
    """Run the prompt through the model, returning (last-token logits,
    DecodeState with caches filled for positions [0, S))."""
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.cdtype)
    enc_out = None
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(cfg.cdtype), x], axis=1)
    if cfg.family == "audio":
        enc_out = T.encoder_forward(params, batch["frames"].astype(cfg.cdtype), cfg)

    if cfg.family in ("ssm", "hybrid"):
        out, state = _prefill_ssm(params, x, cfg, s_max)
    else:
        out, state = _prefill_attn(params, x, cfg, s_max, enc_out)

    h = L.rmsnorm(params["final_norm"], out[:, -1:, :], cfg.norm_eps)
    table = (
        params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]
    )
    logits = jnp.einsum("bsd,vd->bsv", h, table.astype(h.dtype))
    seq_len = x.shape[1]
    state = state._replace(pos=jnp.full((bsz,), seq_len, jnp.int32))
    return logits.astype(jnp.float32), state


def _pad_cache(kv, s_max):
    l, b, s, hkv, hd = kv.shape
    return jnp.zeros((l, b, s_max, hkv, hd), kv.dtype).at[:, :, :s].set(kv)


def _prefill_attn(params, x, cfg, s_max, enc_out):
    out = T._attn_stack(params, x, cfg, enc_out=enc_out, collect_kv=True)
    k, v = out.kv
    state_kwargs = dict(
        kv_k=_pad_cache(k.astype(cfg.cdtype), s_max),
        kv_v=_pad_cache(v.astype(cfg.cdtype), s_max),
    )
    if cfg.family == "audio":
        ck, cv = jax.vmap(lambda bp: A.precompute_cross_kv(bp, enc_out))(
            params["blocks"]["cross"]
        )
        state_kwargs["cross_k"] = ck.astype(cfg.cdtype)
        state_kwargs["cross_v"] = cv.astype(cfg.cdtype)
    return out.x, DecodeState(**state_kwargs)


def _prefill_ssm(params, x, cfg, s_max):
    """SSM/hybrid prefill: run per-layer blocks collecting final SSM states
    (and shared-attention KV for hybrid)."""
    kinds = cfg.layer_kinds()
    is_attn = jnp.asarray([k == "ssm_attn" for k in kinds], jnp.bool_)
    shared = params.get("shared_attn")
    bsz, s, _ = x.shape
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    w = cfg.ssm_conv_width

    def body(carry, xs):
        x, aux = carry
        bp, attn_here = xs
        kv = None
        if shared is not None:
            def with_attn(x):
                h, (k, v) = A.attention(
                    shared["attn"],
                    L.rmsnorm(shared["ln1"], x, cfg.norm_eps),
                    rope_theta=cfg.rope_theta,
                    window=jnp.int32(0),
                    causal=True,
                    return_kv=True,
                )
                x = x + h
                y = L.mlp_apply(
                    shared["mlp"],
                    L.rmsnorm(shared["ln2"], x, cfg.norm_eps),
                    cfg.act,
                )
                return x + y, k, v

            def without(x):
                z = jnp.zeros((bsz, s, cfg.n_kv_heads, cfg.head_dim_), x.dtype)
                return x, z, z

            x, k, v = jax.lax.cond(attn_here, with_attn, without, x)
            kv = (k, v)
        xn = L.rmsnorm(bp["ln1"], x, cfg.norm_eps)
        # conv history: last W-1 pre-conv channel inputs
        proj = jnp.einsum("bsd,de->bse", xn, bp["ssm"]["in_proj"])
        _, xin, b_mat, c_mat, _ = S._split_proj(
            proj, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        )
        conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
        hist = jnp.pad(conv_in, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1) :, :]
        y, h_fin = S.ssm_block(bp["ssm"], xn, cfg)
        return (x + y, aux), (h_fin, hist, kv)

    (x, _), (h_fins, hists, kvs) = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["blocks"], is_attn),
        unroll=T._unroll(cfg.n_layers),
    )
    state_kwargs = dict(ssm_h=h_fins, ssm_conv=hists.astype(cfg.cdtype))
    if shared is not None:
        k, v = kvs
        apps_idx = np.nonzero(np.asarray([k_ == "ssm_attn" for k_ in kinds]))[0]
        state_kwargs["shared_k"] = _pad_cache(
            k[apps_idx].astype(cfg.cdtype), s_max
        )
        state_kwargs["shared_v"] = _pad_cache(
            v[apps_idx].astype(cfg.cdtype), s_max
        )
    return x, DecodeState(**state_kwargs)


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------
def decode_step(params, token, state: DecodeState, cfg: ModelConfig):
    """One token in, one token's logits out. token: (B,) int32."""
    x = L.embed(params["embed"], token[:, None]).astype(cfg.cdtype)
    pos = state.pos

    if cfg.family in ("ssm", "hybrid"):
        x, state = _decode_ssm(params, x, state, cfg)
    else:
        x, state = _decode_attn(params, x, state, cfg)

    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = (
        params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]
    )
    logits = jnp.einsum("bsd,vd->bsv", h, table.astype(h.dtype))
    state = state._replace(pos=pos + 1)
    return logits[:, 0].astype(jnp.float32), state


def _decode_attn(params, x, state: DecodeState, cfg):
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    is_moe = bool(cfg.n_experts)
    is_cross = cfg.family == "audio"
    pos = state.pos

    def body(x, xs):
        if is_cross:
            bp, window, ck, cv, xk, xv = xs
        else:
            bp, window, ck, cv = xs
        h, new_cache = A.decode_attention(
            bp["attn"],
            L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            A.KVCache(ck, cv),
            pos,
            rope_theta=cfg.rope_theta,
            window=window,
        )
        x = x + h
        if is_cross:
            c = A.cross_decode_attention(
                bp["cross"],
                L.rmsnorm(bp["ln_cross"], x, cfg.norm_eps),
                xk.astype(x.dtype),
                xv.astype(x.dtype),
                rope_theta=cfg.rope_theta,
            )
            x = x + c
        xn = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if is_moe:
            y, _ = M.moe_apply(
                bp["moe"], xn, k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor,
                dense_residual=cfg.moe_dense_residual,
            )
        else:
            y = L.mlp_apply(bp["mlp"], xn, cfg.act)
        return x + y, (new_cache.k, new_cache.v)

    xs = (params["blocks"], windows, state.kv_k, state.kv_v)
    if is_cross:
        xs = xs + (state.cross_k, state.cross_v)
    x, (nk, nv) = jax.lax.scan(body, x, xs, unroll=T._unroll(cfg.n_layers))
    return x, state._replace(kv_k=nk, kv_v=nv)


def _decode_ssm(params, x, state: DecodeState, cfg):
    kinds = cfg.layer_kinds()
    is_attn = jnp.asarray([k == "ssm_attn" for k in kinds], jnp.bool_)
    shared = params.get("shared_attn")
    pos = state.pos

    # SSM per-layer states travel as scan xs/ys; shared KV travels in carry.
    def body2(carry, xs):
        x, app_i, sk, sv = carry
        (bp, attn_here, h_l, conv_l) = xs
        if shared is not None:
            def with_attn(op):
                x, app_i, sk, sv = op
                ck = jax.lax.dynamic_index_in_dim(sk, app_i, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(sv, app_i, 0, keepdims=False)
                h, new_cache = A.decode_attention(
                    shared["attn"],
                    L.rmsnorm(shared["ln1"], x, cfg.norm_eps),
                    A.KVCache(ck, cv),
                    pos,
                    rope_theta=cfg.rope_theta,
                    window=jnp.int32(0),
                )
                sk = jax.lax.dynamic_update_index_in_dim(sk, new_cache.k, app_i, 0)
                sv = jax.lax.dynamic_update_index_in_dim(sv, new_cache.v, app_i, 0)
                x = x + h
                y = L.mlp_apply(
                    shared["mlp"],
                    L.rmsnorm(shared["ln2"], x, cfg.norm_eps),
                    cfg.act,
                )
                return x + y, app_i + 1, sk, sv

            x, app_i, sk, sv = jax.lax.cond(
                attn_here, with_attn, lambda op: op, (x, app_i, sk, sv)
            )
        y, new_state = S.ssm_decode_step(
            bp["ssm"],
            L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            S.SSMState(h=h_l, conv=conv_l),
            cfg,
        )
        return (x + y, app_i, sk, sv), (new_state.h, new_state.conv)

    sk = state.shared_k if state.shared_k is not None else jnp.zeros((1,))
    sv = state.shared_v if state.shared_v is not None else jnp.zeros((1,))
    (x, _, sk, sv), (nh, nconv) = jax.lax.scan(
        body2,
        (x, jnp.int32(0), sk, sv),
        (params["blocks"], is_attn, state.ssm_h, state.ssm_conv),
        unroll=T._unroll(cfg.n_layers),
    )
    new = state._replace(ssm_h=nh, ssm_conv=nconv)
    if shared is not None:
        new = new._replace(shared_k=sk, shared_v=sv)
    return x, new

"""Model facade: build once from a ModelConfig, get init/apply/serve fns."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def forward(self, params, batch, remat: bool = False):
        return T.forward(params, batch, self.cfg, remat=remat)

    def prefill(self, params, batch, s_max: int):
        return D.prefill(params, batch, self.cfg, s_max)

    def decode_step(self, params, token, state):
        return D.decode_step(params, token, state, self.cfg)

    def init_decode_state(self, batch: int, s_max: int):
        return D.init_decode_state(self.cfg, batch, s_max)

    def param_shapes(self, key=None):
        """Abstract parameter pytree (no allocation) for the dry-run."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: T.init_params(k, self.cfg), key)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

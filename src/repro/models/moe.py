"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is sort-based (dropping, Switch/GShard-style): token→expert
assignments are sorted by expert id, each expert takes up to C slots, and
overflow tokens fall back to the residual path. Expert weights carry a
leading E axis that shards over the `model` mesh axis (expert parallelism);
the per-expert compute is a batched einsum on the MXU.

Supports the two assigned MoE archs:
  - moonshot-v1-16b-a3b: 64 experts, top-6
  - arctic-480b: 128 experts, top-2, plus a *dense residual* FFN in
    parallel (Snowflake's dense-MoE hybrid) — `dense_residual=True`.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

# Perf knob (EXPERIMENTS.md §Perf, arctic cell): shard the dispatched
# capacity axis over the data axes so (E, C, D) activations scale with the
# full mesh instead of only the expert axis.
_CAP_SHARD = False


def set_capacity_sharding(on: bool) -> None:
    global _CAP_SHARD
    _CAP_SHARD = bool(on)


def moe_init(key, d: int, f: int, n_experts: int, dtype,
             dense_residual: bool = False, f_dense: Optional[int] = None):
    ks = jax.random.split(key, 5)
    p = {
        "router": L.normal_init(ks[0], (d, n_experts), dtype, scale=0.01),
        "w_gate": L.normal_init(ks[1], (n_experts, d, f), dtype),
        "w_up": L.normal_init(ks[2], (n_experts, d, f), dtype),
        "w_down": L.normal_init(ks[3], (n_experts, f, d), dtype),
    }
    if dense_residual:
        p["dense"] = L.mlp_init(ks[4], d, f_dense or f, "silu", dtype)
    return p


def moe_apply(
    params,
    x,  # (B, S, D)
    *,
    k: int,
    capacity_factor: float = 1.25,
    dense_residual: bool = False,
):
    """Returns (y, aux_loss). aux_loss is the load-balancing loss."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style) ---------------------
    me = jnp.mean(probs, axis=0)  # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], e)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----------------------------------------------
    cap = int(np.ceil(t * k / e * capacity_factor))
    cap = max(cap, 1)
    ea = expert_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(ea, stable=True)
    sorted_e = ea[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first  # slot within expert

    # slot table: (E*C,) of flat assignment ids; sentinel = t*k (dropped)
    slot_idx = sorted_e * cap + rank
    valid = rank < cap
    table = jnp.full((e * cap,), t * k, dtype=jnp.int32)
    table = table.at[jnp.where(valid, slot_idx, e * cap)].set(
        order, mode="drop"
    )

    token_of = jnp.where(table < t * k, table // k, t)  # t = zero-pad row
    gate_of = jnp.where(
        table < t * k, gate_vals.reshape(-1)[jnp.minimum(table, t * k - 1)], 0.0
    )

    xp = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    x_e = xp[token_of].reshape(e, cap, d)  # (E, C, D)
    cap_ax = ("pod", "data") if _CAP_SHARD else None
    x_e = L.shard_hint(x_e, "model", cap_ax, None)  # expert-parallel dispatch

    g = jnp.einsum("ecd,edf->ecf", x_e, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_e, params["w_up"])
    h = jax.nn.silu(g) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, D)
    y_e = L.shard_hint(y_e, "model", cap_ax, None)

    y_flat = y_e.reshape(e * cap, d) * gate_of[:, None].astype(y_e.dtype)
    y = jnp.zeros((t + 1, d), y_e.dtype).at[token_of].add(y_flat)[:t]
    y = y.reshape(b, s, d)

    if dense_residual:
        y = y + L.mlp_apply(params["dense"], x, "silu")
    return y.astype(x.dtype), aux

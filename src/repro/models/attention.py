"""Attention blocks: GQA with RoPE, causal/sliding-window masks, cross
attention (encoder-decoder), and single-token decode against a KV cache.

The sliding window is a *traced* scalar (0 = global/full attention), so a
layer stack with mixed local/global layers (gemma3's 5:1 pattern) runs as a
single scanned program — no per-layer retracing or lax.cond.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype,
              bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.normal_init(ks[0], (d, n_heads, head_dim), dtype),
        "wk": L.normal_init(ks[1], (d, n_kv, head_dim), dtype),
        "wv": L.normal_init(ks[2], (d, n_kv, head_dim), dtype),
        "wo": L.normal_init(ks[3], (n_heads, head_dim, d), dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def _project_qkv(params, x, x_kv=None):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _gqa_scores(q, k):
    """(B,S,H,hd) × (B,T,Hkv,hd) → (B, Hkv, H/Hkv, S, T)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, s, hkv, h // hkv, hd)
    return jnp.einsum("bsgrd,btgd->bgrst", qg, k)


def _gqa_out(weights, v):
    """(B,G,R,S,T) × (B,T,G,hd) → (B,S,H,hd)."""
    b, g, r, s, t = weights.shape
    out = jnp.einsum("bgrst,btgd->bsgrd", weights, v)
    return out.reshape(b, s, g * r, -1)


def attention(
    params,
    x,
    *,
    rope_theta: float,
    window,  # traced scalar: 0 = full attention
    causal: bool = True,
    x_kv=None,
    positions=None,
    kv_positions=None,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill).

    x: (B, S, D). Returns (B, S, D), or (out, (k, v)) with *rotated* keys
    when return_kv (what a decode-time KV cache must hold).
    Cross-attention when x_kv is given (no RoPE, whisper-style).
    """
    b, s, _ = x.shape
    is_cross = x_kv is not None
    q, k, v = _project_qkv(params, x, x_kv)
    t = k.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if not is_cross:
        q = L.apply_rope(q, positions, rope_theta)
        k = L.apply_rope(
            k, positions if kv_positions is None else kv_positions, rope_theta
        )
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = _gqa_scores(q, k).astype(jnp.float32) * scale  # (B,G,R,S,T)

    qi = positions[:, None, None, :, None]  # (B,1,1,S,1)
    ki = (
        jnp.arange(t, dtype=jnp.int32)
        if kv_positions is None
        else kv_positions[0]
    )[None, None, None, None, :]
    mask = jnp.ones((b, 1, 1, s, t), dtype=bool)
    if causal and not is_cross:
        mask = mask & (ki <= qi)
        w = jnp.asarray(window, jnp.int32)
        mask = mask & ((w == 0) | (qi - ki < w))
    scores = jnp.where(mask, scores, -1e30)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(weights, v)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S_max, Hkv, hd)
    v: jnp.ndarray  # (B, S_max, Hkv, hd)


def decode_attention(
    params,
    x,  # (B, 1, D) current token activations
    cache: KVCache,
    pos,  # (B,) int32 current position (number of tokens already cached)
    *,
    rope_theta: float,
    window,
):
    """One decode step: append this token's K/V, attend over the cache.

    The cache sequence axis is shardable (sequence-parallel decode for the
    500k-token shapes): the only cross-shard ops are the softmax reductions.
    """
    b, one, d = x.shape
    q, k_new, v_new = _project_qkv(params, x)
    q = L.apply_rope(q, pos[:, None], rope_theta)
    k_new = L.apply_rope(k_new, pos[:, None], rope_theta)

    k = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))(
        cache.k, k_new.astype(cache.k.dtype), pos
    )
    v = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))(
        cache.v, v_new.astype(cache.v.dtype), pos
    )

    s_max = k.shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = _gqa_scores(q, k.astype(q.dtype)).astype(jnp.float32) * scale
    ki = jnp.arange(s_max, dtype=jnp.int32)[None, None, None, None, :]
    qi = pos[:, None, None, None, None]
    mask = ki <= qi
    w = jnp.asarray(window, jnp.int32)
    mask = mask & ((w == 0) | (qi - ki < w))
    scores = jnp.where(mask, scores, -1e30)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(weights, v.astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, KVCache(k, v)


def cross_decode_attention(params, x, enc_k, enc_v, *, rope_theta):
    """Decode-time cross attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = _gqa_scores(q, enc_k).astype(jnp.float32) * scale
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(weights, enc_v)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def precompute_cross_kv(params, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v

"""Decoder stacks: dense/MoE transformers, SSM (Mamba2), hybrid (Zamba2),
and encoder-decoder (Whisper) assembly.

All homogeneous per-layer parameters are *stacked on a leading layer axis*
and driven by `jax.lax.scan` — one traced block regardless of depth, which
keeps HLO size and compile time flat across the 24–62 layer archs, and
makes activation rematerialization a single `jax.checkpoint` around the
block body. Heterogeneity (gemma3 local/global windows, zamba2's periodic
shared attention) is expressed as *data* (per-layer scalars scanned
alongside), never as per-layer Python branches.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig

# ----------------------------------------------------------- layer unroll --
# cost_analysis() counts a while-loop body ONCE regardless of trip count, so
# the dry-run compiles every cell twice (unroll=1, unroll=2) and solves
# total = a + L·b for the true per-step totals. This global sets the scan
# unroll for all layer stacks (1 everywhere except inside the dry-run).
_LAYER_UNROLL = 1
_REMAT_POLICY = "batch_dots"  # batch_dots | dots | everything | off
_SEQ_PARALLEL = False  # shard the residual stream's seq axis over `model`


def set_layer_unroll(n: int) -> None:
    global _LAYER_UNROLL
    _LAYER_UNROLL = max(1, int(n))


def set_remat_policy(name: str) -> None:
    global _REMAT_POLICY
    assert name in ("batch_dots", "dots", "everything", "off"), name
    _REMAT_POLICY = name


def set_seq_parallel(on: bool) -> None:
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = bool(on)


def _maybe_remat(body, remat: bool):
    if not remat or _REMAT_POLICY == "off":
        return body
    if _REMAT_POLICY == "everything":
        return jax.checkpoint(body)
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if _REMAT_POLICY == "batch_dots"
        else jax.checkpoint_policies.checkpoint_dots
    )
    return jax.checkpoint(body, policy=policy)


def _residual_hint(x):
    """Megatron-style sequence parallelism: between blocks the residual
    stream is sharded over `model` on the sequence axis; GSPMD inserts the
    all-gather before attention and the reduce-scatter after projections,
    halving all-reduce bytes and cutting pointwise-op traffic TP-fold."""
    if _SEQ_PARALLEL:
        return L.shard_hint(x, L.DP, "model", None)
    return L.shard_hint(x, L.DP, None, None)


def _unroll(length: int) -> int:
    return min(_LAYER_UNROLL, length)


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------
def _attn_block_init(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {
        "ln1": {"scale": jnp.ones((d,), cfg.pdtype)},
        "attn": A.attn_init(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, cfg.pdtype,
            bias=cfg.qkv_bias,
        ),
        "ln2": {"scale": jnp.ones((d,), cfg.pdtype)},
    }
    if cfg.n_experts:
        p["moe"] = M.moe_init(
            ks[1], d, cfg.d_ff, cfg.n_experts, cfg.pdtype,
            dense_residual=cfg.moe_dense_residual, f_dense=cfg.d_ff_dense,
        )
    else:
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.act, cfg.pdtype)
    if cross:
        p["ln_cross"] = {"scale": jnp.ones((d,), cfg.pdtype)}
        p["cross"] = A.attn_init(
            ks[2], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, cfg.pdtype,
            bias=cfg.qkv_bias,
        )
    return p


def _ssm_block_init(key, cfg: ModelConfig):
    return {
        "ln1": {"scale": jnp.ones((cfg.d_model,), cfg.pdtype)},
        "ssm": S.ssm_init(
            key, cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state,
            cfg.ssm_conv_width, cfg.pdtype,
        ),
    }


def _stack(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params = {"embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.pdtype)}
    d = cfg.d_model

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = _stack(
            ks[1], cfg.n_layers, lambda k: _attn_block_init(k, cfg)
        )
    elif cfg.family in ("ssm", "hybrid"):
        params["blocks"] = _stack(
            ks[1], cfg.n_layers, lambda k: _ssm_block_init(k, cfg)
        )
        if cfg.family == "hybrid":
            # one full transformer block (attn + MLP), re-applied with the
            # *same weights* every attn_every layers — Zamba2's shared block
            params["shared_attn"] = _attn_block_init(ks[2], cfg)
    elif cfg.family == "audio":  # encoder-decoder
        params["enc_blocks"] = _stack(
            ks[1], cfg.encoder_layers, lambda k: _attn_block_init(k, cfg)
        )
        params["enc_norm"] = {"scale": jnp.ones((d,), cfg.pdtype)}
        params["blocks"] = _stack(
            ks[3], cfg.n_layers, lambda k: _attn_block_init(k, cfg, cross=True)
        )
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = {"scale": jnp.ones((d,), cfg.pdtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = L.embedding_init(
            ks[4], cfg.vocab_size, cfg.d_model, cfg.pdtype
        )
    return params


# ---------------------------------------------------------------------------
# forward passes (training / prefill)
# ---------------------------------------------------------------------------
class StackOut(NamedTuple):
    x: jnp.ndarray
    aux_loss: jnp.ndarray
    kv: Optional[tuple]  # (L, B, S, Hkv, hd) ×2 when collect_kv


def _attn_stack(params, x, cfg: ModelConfig, *, enc_out=None, positions=None,
                collect_kv: bool = False, remat: bool = False):
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    is_moe = bool(cfg.n_experts)
    is_cross = enc_out is not None

    def body(carry, xs):
        x, aux = carry
        bp, window = xs
        res = A.attention(
            bp["attn"],
            L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            rope_theta=cfg.rope_theta,
            window=window,
            causal=True,
            positions=positions,
            return_kv=collect_kv,
        )
        h, kv = res if collect_kv else (res, None)
        x = x + h
        if is_cross:
            c = A.attention(
                bp["cross"],
                L.rmsnorm(bp["ln_cross"], x, cfg.norm_eps),
                rope_theta=cfg.rope_theta,
                window=jnp.int32(0),
                causal=False,
                x_kv=enc_out,
            )
            x = x + c
        xn = L.rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if is_moe:
            y, a = M.moe_apply(
                bp["moe"], xn, k=cfg.experts_per_token,
                capacity_factor=cfg.moe_capacity_factor,
                dense_residual=cfg.moe_dense_residual,
            )
            aux = aux + a
        else:
            y = L.mlp_apply(bp["mlp"], xn, cfg.act)
        out = _residual_hint(x + y)
        return (out, aux), kv

    body = _maybe_remat(body, remat)

    (x, aux), kvs = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["blocks"], windows),
        unroll=_unroll(cfg.n_layers),
    )
    return StackOut(x, aux, kvs if collect_kv else None)


def _ssm_stack(params, x, cfg: ModelConfig, *, positions=None,
               collect_kv: bool = False, remat: bool = False):
    """Mamba2 / Zamba2 stack. Shared attention handled as scanned data: the
    per-layer flag picks whether the (single, closure-captured) shared
    attention block contributes before the SSM mixer."""
    kinds = cfg.layer_kinds()
    is_attn = jnp.asarray([k == "ssm_attn" for k in kinds], jnp.bool_)
    shared = params.get("shared_attn")

    def body(carry, xs):
        x, aux = carry
        bp, attn_here = xs
        kv = None
        if shared is not None:
            b, s, _ = x.shape
            kv_shape = (b, s, cfg.n_kv_heads, cfg.head_dim_)

            def with_attn(x):
                h, (k, v) = A.attention(
                    shared["attn"],
                    L.rmsnorm(shared["ln1"], x, cfg.norm_eps),
                    rope_theta=cfg.rope_theta,
                    window=jnp.int32(0),
                    causal=True,
                    positions=positions,
                    return_kv=True,
                )
                x = x + h
                y = L.mlp_apply(
                    shared["mlp"], L.rmsnorm(shared["ln2"], x, cfg.norm_eps), cfg.act
                )
                return x + y, k, v

            def without_attn(x):
                z = jnp.zeros(kv_shape, x.dtype)
                return x, z, z

            x, k, v = jax.lax.cond(attn_here, with_attn, without_attn, x)
            if collect_kv:
                kv = (k, v)
        y, _ = S.ssm_block(bp["ssm"], L.rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
        return (x + y, aux), kv

    body = _maybe_remat(body, remat)

    (x, aux), kvs = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["blocks"], is_attn),
        unroll=_unroll(cfg.n_layers),
    )
    return StackOut(x, aux, kvs if collect_kv else None)


def encoder_forward(params, frames, cfg: ModelConfig):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""

    def body(x, bp):
        h = A.attention(
            bp["attn"],
            L.rmsnorm(bp["ln1"], x, cfg.norm_eps),
            rope_theta=cfg.rope_theta,
            window=jnp.int32(0),
            causal=False,
        )
        x = x + h
        y = L.mlp_apply(bp["mlp"], L.rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)
        return x + y, None

    x, _ = jax.lax.scan(
        body, frames, params["enc_blocks"], unroll=_unroll(cfg.encoder_layers)
    )
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig, *, remat: bool = False):
    """Training forward: returns (logits, aux_loss).

    batch: {"tokens": (B,S)} plus family extras:
      vlm:   {"patches": (B,P,D)} — prepended to the token embeddings
      audio: {"frames": (B,T,D)} — encoder input (stub conv frontend)
    """
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.cdtype)
    x = L.shard_hint(x, L.DP, None, None)
    positions = None
    enc_out = None

    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.cdtype)
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.family == "audio":
        enc_out = encoder_forward(params, batch["frames"].astype(cfg.cdtype), cfg)

    if cfg.family in ("ssm", "hybrid"):
        out = _ssm_stack(params, x, cfg, remat=remat)
    else:
        out = _attn_stack(params, x, cfg, enc_out=enc_out, remat=remat)

    h = L.rmsnorm(params["final_norm"], out.x, cfg.norm_eps)
    if cfg.family == "vlm":  # only text positions produce logits
        h = h[:, batch["patches"].shape[1] :, :]
    table = (
        params["embed"]["table"]
        if cfg.tie_embeddings
        else params["unembed"]["table"]
    )
    # logits stay in activation dtype: a (B, S, V) f32 copy of a 262k-vocab
    # model would dominate HBM; the loss upcasts inside fused reductions.
    logits = jnp.einsum("bsd,vd->bsv", h, table.astype(h.dtype))
    logits = L.shard_hint(logits, L.DP, None, "model")
    return logits, out.aux_loss

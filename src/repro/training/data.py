"""Deterministic, shardable synthetic data pipeline.

Tokens are a pure function of (seed, step, position) — threefry-hashed on
device — so any host can regenerate any step's batch without coordination:
restart-safe (fault tolerance), skew-free (no shared queue ⇒ no straggler
head-of-line blocking), and elastic (a re-meshed job re-derives its shards
from the same function). A per-host slice view supports multi-host loading.

Real-corpus training would swap `synthetic_batch` for a tokenized-shard
reader with the same (seed, step) → batch contract; everything downstream
(train loop, checkpoint/restart) is contract-typed, not loader-typed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq: int = 128
    # multi-host slicing
    host_id: int = 0
    n_hosts: int = 1


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int):
    """Batch for one step. Same (seed, step) ⇒ same batch, forever.

    Tokens are Zipfian (inverse-CDF of a log-uniform draw), like natural
    text, not uniform: a uniform stream's next-token CE is irreducibly
    ln(V), so no optimizer-convergence test could ever observe progress.
    With a skewed marginal the model's CE drops toward the unigram entropy
    (≈ ln ln V nats lower) as soon as it learns the frequency bias.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    ks = jax.random.split(key, 4)
    b, s = dcfg.batch, dcfg.seq
    u = jax.random.uniform(ks[0], (b, s), jnp.float32)
    # (V+1)**u spans [1, V+1), so ids cover the full vocab [0, V-1]
    # (with V**u the last id would never be emitted and row V-1 of the
    # embedding would receive no gradient, ever)
    tokens = jnp.clip(
        ((cfg.vocab_size + 1.0) ** u).astype(jnp.int32) - 1,
        0,
        cfg.vocab_size - 1,
    )
    # next-token LM objective: labels are tokens shifted left
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), jnp.int32)], axis=1
    )
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[1], (b, cfg.frontend_seq, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (b, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    if dcfg.n_hosts > 1:
        lo = dcfg.host_id * b // dcfg.n_hosts
        hi = (dcfg.host_id + 1) * b // dcfg.n_hosts
        batch = jax.tree.map(lambda x: x[lo:hi], batch)
    return batch


def iterate(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0) -> Iterator:
    """Restartable iterator: resume from any checkpointed step."""
    step = start_step
    while True:
        yield step, synthetic_batch(cfg, dcfg, step)
        step += 1

"""Training step: loss, gradients, optimizer update, optional gradient
compression hook. One jit-able function parameterized by (model, opt cfg).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    remat: bool = True
    z_loss: float = 1e-4
    aux_loss_weight: float = 0.01  # MoE load-balance
    grad_compression: str = "none"  # none | int8  (error-feedback int8)


class TrainState(NamedTuple):
    params: dict
    opt: opt.AdamWState
    ef: Optional[dict]  # error-feedback residuals (grad compression)


def init_state(model: Model, key, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    ef = None
    if tcfg.grad_compression == "int8":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt.init(params), ef=ef)


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """Token-mean CE with optional z-loss. labels < 0 are masked out.

    The gold-logit gather is a one-hot contraction (not take_along_axis):
    it fuses into a sharded reduction when the vocab axis is
    tensor-parallel, instead of all-gathering (B, S, V).
    """
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    vocab = logits.shape[-1]
    onehot = labels[..., None] == jnp.arange(vocab, dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    ce = (logz - gold) * mask
    total = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(ce) / total
    if z_loss:
        loss = loss + z_loss * jnp.sum((logz * mask) ** 2) / total
    return loss


def loss_fn(params, batch, model: Model, tcfg: TrainConfig):
    logits, aux = model.forward(params, batch, remat=tcfg.remat)
    loss = cross_entropy(logits, batch["labels"], tcfg.z_loss)
    if model.cfg.n_experts:
        loss = loss + tcfg.aux_loss_weight * aux
    return loss, {"ce": loss, "aux": aux}


def _compress_int8(grads, ef):
    """Error-feedback int8 compression of the gradient all-reduce payload.

    Simulates: q = round(g+e / s) clipped to int8; residual e' = (g+e) - s*q.
    The all-reduce then moves 1/4 the bytes (int8 vs f32). On the roofline
    this divides the gradient-sync collective term by 4; convergence is
    preserved by the error feedback (tested).
    """
    def comp(g, e):
        x = g.astype(jnp.float32) + e
        s = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(x / s), -127, 127)
        deq = (q * s).astype(g.dtype)
        return deq, x - q * s

    flat = jax.tree.map(comp, grads, ef)
    g = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return g, e


def train_step(state: TrainState, batch, model: Model, tcfg: TrainConfig):
    """Pure function: (state, batch) → (state, metrics). Shard with pjit."""
    (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state.params, batch, model, tcfg
    )
    ef = state.ef
    if tcfg.grad_compression == "int8":
        grads, ef = _compress_int8(grads, ef)
    params, opt_state, om = opt.apply(tcfg.adamw, state.params, grads, state.opt)
    metrics = {"loss": loss, **parts, **om}
    return TrainState(params=params, opt=opt_state, ef=ef), metrics


def make_train_step(model: Model, tcfg: TrainConfig):
    return functools.partial(train_step, model=model, tcfg=tcfg)

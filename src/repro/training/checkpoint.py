"""Checkpoint manager: atomic, async, restart- and reshard-safe.

Design (fault-tolerance substrate, DESIGN.md §5):
  - atomic: write to <dir>/.tmp-<step>, fsync, rename — a crash mid-write
    never corrupts the latest checkpoint;
  - async: the device→host copy is synchronous (cheap) but serialization
    happens on a writer thread so the train loop isn't blocked;
  - restart: `latest_step` + `restore` resume exactly (params, optimizer
    moments, data-pipeline step — the data pipeline is a pure function of
    step, so no loader state is needed);
  - elastic reshard: checkpoints are stored *unsharded* (host numpy); a
    restore under a different mesh just applies the new shardings — tested
    in tests/test_fault_tolerance.py by saving from one mesh and restoring
    into another;
  - retention: keep the last `keep` checkpoints.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif tree is None:
        pass  # None leaves (e.g. disabled optional state) are structural
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{k}/")
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        return type(template)(
            **{
                k: _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
                for k in template._fields
            }
        )
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template)
        )
    if template is None:
        return None
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_write
        self._error: Optional[BaseException] = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ api
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Snapshot state (device→host now, disk write maybe async)."""
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        payload = (step, host_state, extra or {})
        if self._async:
            self._q.put(payload)
        else:
            self._write(*payload)

    def wait(self):
        """Block until pending async writes land (call before exit)."""
        if self._async:
            self._q.join()
        if self._error:
            raise self._error

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("-")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step-") and not d.startswith(".")
        ]
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Load a checkpoint into `template`'s structure. If `shardings` is
        given, leaves are device_put with those shardings (elastic
        re-mesh path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step-{step}")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            flat = {k: data[k] for k in data.files}
        state = _unflatten_into(template, flat)
        with open(os.path.join(path, "meta.json")) as f:
            extra = json.load(f)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return step, state, extra

    # ------------------------------------------------------------- internals
    def _worker(self):
        while True:
            payload = self._q.get()
            try:
                self._write(*payload)
            except BaseException as e:  # surfaced on wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_state, extra: dict):
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **extra}, f)
        with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("-")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step-")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"), ignore_errors=True)

"""Fault-tolerance substrate for pod-scale runs.

Pieces (each tested in tests/test_fault_tolerance.py):

  1. checkpoint/restart  — CheckpointManager (atomic rename + async writer)
     plus `resume_or_init`: the standard "crash anywhere, rerun the same
     command" loop contract. The data pipeline is a pure function of step,
     so a restart replays no data and skips none.

  2. elastic re-mesh     — `reshard_state`: load a checkpoint taken on one
     mesh into a different mesh (scale 512→256 after losing a pod, or up
     again). Checkpoints are stored unsharded, so resharding is just
     device_put with the new shardings; parameter *math* is unchanged.

  3. straggler mitigation — structural, not reactive: CPP partitioning
     yields equal-size subgraphs (static balance, §3.2); the merge beam is
     an equal-rows stripe; the data pipeline is queue-free. For the
     remaining tail risk (slow host), `HeartbeatMonitor` detects stalled
     steps and triggers checkpoint-and-restart rather than waiting.

  4. gradient compression — int8 + error feedback (training/train_step.py),
     cutting the gradient all-reduce bytes 4× (see EXPERIMENTS.md §Perf);
     convergence parity is tested on a small model.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.training.checkpoint import CheckpointManager


def resume_or_init(ckpt: Optional[CheckpointManager], init_fn: Callable[[], object]):
    """Standard restart contract: latest checkpoint if present, else init."""
    if ckpt is not None and ckpt.latest_step() is not None:
        template = init_fn()
        step, state, _ = ckpt.restore(template)
        return step, state, True
    return 0, init_fn(), False


def reshard_state(state, shardings):
    """Elastic re-mesh: place (host or differently-sharded) state onto new
    shardings. Works across device counts because checkpoints are stored
    unsharded numpy."""
    host = jax.tree.map(np.asarray, jax.device_get(state))
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host, shardings)


@dataclasses.dataclass
class HeartbeatMonitor:
    """Detects stalled training steps (straggling/hung host).

    The train loop calls beat(step) after every step; a watcher thread
    flags (and optionally calls on_stall) if no beat arrives within
    `timeout_s`. In a real deployment on_stall checkpoints and exits
    non-zero so the scheduler restarts the job on healthy nodes.
    """

    timeout_s: float = 300.0
    on_stall: Optional[Callable[[int], None]] = None

    def __post_init__(self):
        self._last_beat = time.monotonic()
        self._last_step = -1
        self._stalled = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def beat(self, step: int):
        self._last_beat = time.monotonic()
        self._last_step = step

    @property
    def stalled(self) -> bool:
        return self._stalled

    def stop(self):
        self._stop.set()

    def _watch(self):
        while not self._stop.wait(min(self.timeout_s / 10, 1.0)):
            if time.monotonic() - self._last_beat > self.timeout_s:
                self._stalled = True
                if self.on_stall:
                    self.on_stall(self._last_step)
                return

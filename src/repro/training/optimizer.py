"""AdamW from scratch (no optax): pytree-structured moments, bias
correction, decoupled weight decay, global-norm clipping, cosine schedule
with linear warmup. Moments are float32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: dict  # first moments (f32)
    nu: dict  # second moments (f32)


def init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    floor = cfg.min_lr_ratio
    return cfg.learning_rate * warm * (floor + (1.0 - floor) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, grads, state: AdamWState, *,
          decay_mask=None):
    """One AdamW update. decay_mask: pytree of bools — True = apply WD
    (defaults to ndim >= 2, i.e. matrices but not norms/biases)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, g, m, v, dm):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + jnp.where(dm, cfg.weight_decay, 0.0) * p.astype(
                jnp.float32
            )
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu, decay_mask)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), {
        "grad_norm": gnorm,
        "lr": lr,
    }

"""End-to-end LM training driver on the shared distribution substrate:
trains a reduced qwen1.5 config for a few hundred steps with AdamW,
cosine schedule, remat, checkpointing and restart.

  PYTHONPATH=src python examples/train_lm.py --steps 200

(The full-size configs train through the identical code path on the
production mesh; see src/repro/launch/dryrun.py for the lowered proof.)
"""

import sys

from repro.launch.train import run

args = [
    "--arch", "qwen1_5_0_5b", "--reduced",
    "--steps", "200", "--batch", "4", "--seq", "64",
    "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_train_ckpt",
    "--ckpt-every", "50", "--log-every", "20",
] + sys.argv[1:]
losses = run(args)
assert losses[-1] < losses[0], "loss did not decrease"
print(f"loss {losses[0]:.3f} → {losses[-1]:.3f} over the run: OK")

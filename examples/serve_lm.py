"""Batched serving example: prefill a batch of prompts, then decode new
tokens step by step against the KV cache (greedy sampling).

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2_1_3b
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.model import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1_5_0_5b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--new-tokens", type=int, default=24)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

prompts = jax.random.randint(
    jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
)
batch = {"tokens": prompts}
if cfg.family == "vlm":
    batch["patches"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, cfg.frontend_seq, cfg.d_model)
    )
if cfg.family == "audio":
    batch["frames"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq, cfg.d_model)
    )

extra = cfg.frontend_seq if cfg.family == "vlm" else 0
s_max = args.prompt_len + extra + args.new_tokens + 1
logits, state = jax.jit(
    lambda p, b: model.prefill(p, b, s_max=s_max)
)(params, batch)

decode = jax.jit(model.decode_step)
tok = jnp.argmax(logits[:, 0], axis=-1)
generated = [tok]
for _ in range(args.new_tokens - 1):
    logits, state = decode(params, tok, state)
    tok = jnp.argmax(logits, axis=-1)
    generated.append(tok)

out = jnp.stack(generated, axis=1)
print(f"arch={cfg.name} generated {out.shape} tokens:")
for row in out[:2]:
    print("  ", row[:16].tolist(), "...")
print("serving OK")

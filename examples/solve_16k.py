"""End-to-end driver for the paper's headline: a >10,000-vertex Max-Cut
instance solved by the full ParaQAOA pipeline (partition → batched QAOA
pool → level-aware merge → refinement), with stage timings.

  PYTHONPATH=src python examples/solve_16k.py            # 16,000 vertices
  PYTHONPATH=src python examples/solve_16k.py --n 2000   # smaller/faster

The paper solves 16k vertices in 19 min on 2×RTX4090; this container is a
single CPU core, so default edge probability is reduced (0.01 ≈ 1.3M
edges). The code path is identical to the pod-scale one — on TPU the same
pipeline runs through core/distributed.py (solver pool over `data`,
statevector over `model`).
"""

import argparse
import time

from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import local_search
from repro.core.graph import Graph

ap = argparse.ArgumentParser()
ap.add_argument("--n", type=int, default=16_000)
ap.add_argument("--p", type=float, default=0.01)
ap.add_argument("--qubits", type=int, default=10)
ap.add_argument("--k", type=int, default=1)
ap.add_argument("--opt-steps", type=int, default=10)
ap.add_argument("--refine", type=int, default=200)
args = ap.parse_args()

t0 = time.time()
print(f"generating G({args.n}, {args.p}) ...", flush=True)
graph = Graph.erdos_renyi(args.n, args.p, seed=0)
print(f"  {graph.n_edges} edges ({time.time()-t0:.1f}s)")

cfg = ParaQAOAConfig(
    n_qubits=args.qubits, top_k=args.k, p_layers=2,
    opt_steps=args.opt_steps, beam_width=64, refine_steps=args.refine,
)
out = solve(graph, cfg)
print(f"ParaQAOA cut = {out.cut_value:.0f} on {args.n} vertices")
for stage, t in out.timings.items():
    print(f"  {stage:12s} {t:.1f}s")

# classical sanity reference at the same scale
_, ls_cut, ls_rep = local_search(graph, restarts=1, steps=300)
print(f"local-search reference: {ls_cut:.0f} ({ls_rep.runtime_s:.1f}s)")
print(f"total weight: {float(graph.total_weight()):.0f} "
      f"(random-cut expectation = {float(graph.total_weight())/2:.0f})")

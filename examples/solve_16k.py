"""End-to-end driver for the paper's headline: a >10,000-vertex Max-Cut
instance solved by the full ParaQAOA pipeline (partition → batched QAOA
pool → level-aware merge → refinement), with stage timings.

  PYTHONPATH=src python examples/solve_16k.py            # 16,000 vertices
  PYTHONPATH=src python examples/solve_16k.py --n 2000   # smaller/faster
  PYTHONPATH=src python examples/solve_16k.py --n 2000 --mesh data=4

The paper solves 16k vertices in 19 min on 2×RTX4090; this container is a
single CPU core, so default edge probability is reduced (0.01 ≈ 1.3M
edges). Without ``--mesh`` the pipeline runs single-device. With
``--mesh data=N[,model=M]`` it runs through the distributed runtime in
core/distributed.py — the solver pool shard_mapped over `data`,
oversized subproblems' statevectors over `model`, and the merge frontier
striped per `--merge` policy (docs/DESIGN.md §2). On a single-CPU host
the mesh devices are emulated (docs/TESTING.md); on a real accelerator
mesh the same flags drive the pod-scale layout.
"""

import argparse
import time

ap = argparse.ArgumentParser(
    description="ParaQAOA headline instance: >10k-vertex Max-Cut, "
    "optionally through the distributed mesh runtime."
)
ap.add_argument("--n", type=int, default=16_000,
                help="vertex count (paper headline: 16,000)")
ap.add_argument("--p", type=float, default=0.01,
                help="Erdős-Rényi edge probability (CPU-scaled default)")
ap.add_argument("--qubits", type=int, default=10,
                help="per-device qubit budget; a model mesh axis lifts it "
                "by log2(model)")
ap.add_argument("--k", type=int, default=1,
                help="top-K candidates kept per subgraph")
ap.add_argument("--opt-steps", type=int, default=10,
                help="Adam steps per subgraph QAOA")
ap.add_argument("--refine", type=int, default=200,
                help="1-flip local-search sweeps on the merged cut")
ap.add_argument("--mesh", type=str, default=None, metavar="SPEC",
                help="device mesh spec, e.g. 'data=4' or 'data=2,model=4' "
                "— enables the core/distributed.py pipeline (emulated "
                "devices on a single-CPU host)")
ap.add_argument("--merge", choices=("auto", "striped", "single"),
                default="auto", dest="merge_mode",
                help="distributed merge policy (see solve_maxcut --help)")
ap.add_argument("--sharded-opt-steps", type=int, default=0,
                help="Adam steps on oversized (model-sharded) subproblem "
                "parameters, run through the sharded evolution "
                "(DESIGN.md §2.6); 0 keeps the linear ramp")
ap.add_argument("--kernel-tuning", action="store_true",
                help="resolve Pallas block shapes from the committed "
                "autotune cache (src/repro/kernels/tuning_cache.json, "
                "DESIGN.md §2.7) instead of the hard-coded defaults; "
                "regenerate with benchmarks/kernel_autotune.py "
                "--write-cache")
args = ap.parse_args()

mesh_spec = None
if args.mesh:
    # parse + arrange device emulation before the first jax backend touch
    from repro import compat
    from repro.launch.mesh import mesh_spec_size, parse_mesh_spec

    mesh_spec = parse_mesh_spec(args.mesh)
    compat.ensure_host_device_count(mesh_spec_size(mesh_spec))

from repro.core import ParaQAOAConfig, solve, solve_distributed
from repro.core.baselines import local_search
from repro.core.graph import Graph
from repro.kernels import tuning

if args.kernel_tuning:
    tuning.set_enabled(True)

t0 = time.time()
print(f"generating G({args.n}, {args.p}) ...", flush=True)
graph = Graph.erdos_renyi(args.n, args.p, seed=0)
print(f"  {graph.n_edges} edges ({time.time()-t0:.1f}s)")

cfg = ParaQAOAConfig(
    n_qubits=args.qubits, top_k=args.k, p_layers=2,
    opt_steps=args.opt_steps, beam_width=64, refine_steps=args.refine,
    sharded_opt_steps=args.sharded_opt_steps,
)
if mesh_spec is not None:
    out = solve_distributed(graph, cfg, mesh_spec, merge_mode=args.merge_mode)
    extra = out.report.extra
    print(f"mesh {extra['mesh']}: {extra['merge_shards']} merge shards "
          f"({extra['merge_mode']}), "
          f"{extra['sharded_subproblems']} model-sharded subproblems "
          f"(sharded_opt_steps={extra['sharded_opt_steps']})")
else:
    out = solve(graph, cfg)
print(f"ParaQAOA cut = {out.cut_value:.0f} on {args.n} vertices")
for stage, t in out.timings.items():
    print(f"  {stage:12s} {t:.1f}s")

# classical sanity reference at the same scale
_, ls_cut, ls_rep = local_search(graph, restarts=1, steps=300)
print(f"local-search reference: {ls_cut:.0f} ({ls_rep.runtime_s:.1f}s)")
print(f"total weight: {float(graph.total_weight()):.0f} "
      f"(random-cut expectation = {float(graph.total_weight())/2:.0f})")

"""Quickstart: solve a Max-Cut instance with ParaQAOA and score it with
the paper's PEI metric against the GW baseline.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ParaQAOAConfig, solve
from repro.core.baselines import goemans_williamson
from repro.core.graph import Graph
from repro.core.pei import pei

# a 120-vertex Erdős-Rényi instance (paper §4.1 generator, seed-stable)
graph = Graph.erdos_renyi(n=120, p=0.3, seed=0)

# hardware-dependent: solver qubits; tunable: K (quality) / beam (merge)
cfg = ParaQAOAConfig(n_qubits=10, top_k=2, p_layers=3, opt_steps=30)
out = solve(graph, cfg)

print(f"ParaQAOA cut = {out.cut_value:.0f}  "
      f"(M={out.partition.m} subgraphs, {out.report.runtime_s:.2f}s)")
for stage, t in out.timings.items():
    print(f"  {stage:12s} {t:.3f}s")

assignment, gw_cut, gw_rep = goemans_williamson(graph, steps=250, rounds=64)
print(f"GW reference cut = {gw_cut:.0f} ({gw_rep.runtime_s:.2f}s)")
print(f"AR vs GW = {out.cut_value / gw_cut:.3f}")
print(f"PEI (GW baseline) = "
      f"{pei(out.cut_value, gw_cut, out.report.runtime_s, gw_rep.runtime_s):.1f}")
